//! Sparsity-pattern exploration (the paper's first use-case, Sec. VII-B):
//! sweeps the Table II patterns across ratios on ResNet50 dims and prints
//! the Fig. 8 series. Accuracy columns come from the mini-model artifacts
//! when available (substitution documented in DESIGN.md §3).
//!
//! ```sh
//! cargo run --release --example sparsity_explorer [-- <model>]
//! ```

use ciminus::explore::sparsity_study::{fig8_patterns, run_fig8, run_fig9a, RATIOS};
use ciminus::pruning::workflow::PruningWorkflow;
use ciminus::report;
use ciminus::runtime::{Artifacts, ModelSession, Runtime};
use ciminus::workload::zoo;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "resnet50".into());
    let net = zoo::by_name(&model, 32, 100)?;
    println!("sweeping {} patterns x {} ratios on {}...", fig8_patterns(0.8).len(), RATIOS.len(), net.name);
    let mut pts = run_fig8(&net, &RATIOS, 0)?;

    // attach accuracy from the mini counterpart if artifacts exist
    let dir = Artifacts::default_dir();
    if Artifacts::available(&dir) {
        let mini_name = match model.as_str() {
            "resnet50" | "resnet18" | "resnet_mini" => "resnet_mini",
            "vgg16" | "vgg_mini" => "vgg_mini",
            _ => "mobilenet_mini",
        };
        println!("accuracy axis: {mini_name} on SynthCIFAR via PJRT (see DESIGN.md §3)");
        let arts = Artifacts::load(&dir)?;
        let rt = Runtime::cpu()?;
        let session = ModelSession::new(&rt, &arts, mini_name)?;
        let mini = zoo::by_name(mini_name, 32, 100)?;
        let wf = PruningWorkflow::default();
        for p in pts.iter_mut() {
            let fb = fig8_patterns(p.ratio)
                .into_iter()
                .find(|f| f.name == p.pattern)
                .expect("pattern roundtrip");
            p.accuracy = Some(session.prune_and_eval(&mini, &fb, &wf)?.accuracy);
        }
    } else {
        println!("(artifacts missing — accuracy column omitted; run `make artifacts`)");
    }

    println!("{}", report::sparsity_table(&format!("Fig. 8: {}", net.name), &pts).render());

    let pts9 = run_fig9a(&net, 0)?;
    println!("{}", report::sparsity_table("Fig. 9(a): block sizes @80%", &pts9).render());
    Ok(())
}
