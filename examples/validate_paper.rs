//! Validation against published CIM designs (Sec. VI, Fig. 6): runs the
//! MARS and SDP scenarios of Table I and compares CIMinus estimates with
//! the transcribed published results.
//!
//! ```sh
//! cargo run --release --example validate_paper
//! ```

use ciminus::report;
use ciminus::validate::{correlation, error_stats, run_validation, sdp_power_breakdown};

fn main() -> anyhow::Result<()> {
    println!("{}", report::tab1().render());
    println!("{}", report::tab2().render());

    println!("running MARS + SDP validation scenarios (4 workloads x dense/sparse)...\n");
    let points = run_validation()?;
    println!("{}", report::fig6_table(&points).render());
    let (mean, max) = error_stats(&points);
    let r = correlation(&points);
    println!(
        "Fig. 6(a): pearson r = {r:.3}; margin: mean {mean:.2}%, max {max:.2}% \
         (paper reports all points within 5.27%)\n"
    );

    let bd = sdp_power_breakdown()?;
    println!("{}", report::fig6c_table(&bd).render());
    Ok(())
}
