//! End-to-end driver: the full three-layer stack on a real (small)
//! workload, proving all layers compose.
//!
//! Pipeline (requires `make artifacts` first):
//! 1. load the JAX-trained resnet_mini weights (L2 artifact);
//! 2. prune them in rust with a FlexBlock pattern (Eq. 1/2 selection);
//! 3. evaluate pruned accuracy on SynthCIFAR via PJRT — the L2 graph
//!    embeds the L1 Pallas FlexBlock-matmul kernel;
//! 4. profile real activation bit-planes via PJRT (input sparsity);
//! 5. run the CIMinus cycle simulation with the *measured* masks and
//!    profiles, reporting the paper's headline metrics.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use ciminus::eval::{Evaluator, Scenario};
use ciminus::hw::presets;
use ciminus::pruning::workflow::PruningWorkflow;
use ciminus::runtime::{input_profiles_for, Artifacts, ModelSession, Runtime};
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::workload::zoo;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = Artifacts::default_dir();
    anyhow::ensure!(
        Artifacts::available(&dir),
        "artifacts missing — run `make artifacts` first"
    );
    let arts = Artifacts::load(&dir)?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    let model = "resnet_mini";
    let net = zoo::by_name(model, 32, 100)?;
    let t0 = Instant::now();
    let session = ModelSession::new(&rt, &arts, model)?;
    println!("compiled fwd+acts executables in {:?}\n", t0.elapsed());

    // dense reference accuracy (recompute through PJRT, sanity vs manifest)
    let ma = arts.model(model)?;
    let dense_acc = session.eval_blob(&ma.blob)?;
    println!(
        "dense accuracy: {:.2}% (manifest: {:.2}%)",
        dense_acc * 100.0,
        ma.dense_eval_acc * 100.0
    );

    // activation profiling on the calibration batch (L1 bitplane path)
    let profiles_by_name = session.profile_activations(&ma.blob, 8)?;
    let profiles = input_profiles_for(&net, &profiles_by_name);

    // one evaluator for the whole sweep: the measured profiles are a
    // Provided artifact, and the dense baseline plans exactly once
    let evaluator = Evaluator::new();
    let net = Arc::new(net);
    let profiles = Arc::new(profiles);
    let wf = PruningWorkflow::default();
    let dense_arch = presets::usecase_dense_baseline(4, (2, 2));
    let dense_sim = evaluator.evaluate(
        &Scenario::new(dense_arch, net.clone()).provided_profiles(profiles.clone()),
    )?;

    println!(
        "\n{:<22} {:>7} {:>9} {:>9} {:>8} {:>7}",
        "pattern", "acc%", "speedup", "energyx", "util%", "skip%"
    );
    for fb in [
        FlexBlock::row_wise(0.8),
        FlexBlock::row_block(16, 0.8),
        FlexBlock::column_wise(0.8),
        FlexBlock::hybrid(2, 16, 0.8),
        FlexBlock::hybrid(4, 16, 0.8),
    ] {
        // 1-2: prune with importance selection + evaluate via PJRT
        let ev = session.prune_and_eval(&net, &fb, &wf)?;
        // 5: simulate with the measured masks and profiles as Provided
        // artifacts (the evaluator skips its synthetic prune/profile
        // stages entirely)
        let arch = presets::usecase_arch(4, (2, 2));
        let rep = evaluator.evaluate(
            &Scenario::new(arch, net.clone())
                .prune_provided(Arc::new(ev.plan.clone()))
                .provided_profiles(profiles.clone()),
        )?;
        println!(
            "{:<22} {:>6.2} {:>8.2}x {:>8.2}x {:>7.1} {:>6.1}",
            fb.name,
            ev.accuracy * 100.0,
            rep.speedup_vs(&dense_sim),
            rep.energy_saving_vs(&dense_sim),
            rep.mean_utilization * 100.0,
            rep.mean_skip_ratio * 100.0
        );
    }
    println!(
        "\nheadline: coarse patterns trade accuracy for efficiency; hybrids \
         balance both (paper Finding 1). Record in EXPERIMENTS.md."
    );
    Ok(())
}
