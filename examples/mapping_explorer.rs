//! Mapping-strategy exploration (the paper's second use-case,
//! Sec. VII-C): spatial mapping vs. weight duplication across 16-macro
//! organizations (Fig. 11) and the rearrangement study (Fig. 12).
//!
//! ```sh
//! cargo run --release --example mapping_explorer
//! ```

use ciminus::explore::mapping_study::{run_fig11, run_fig12};
use ciminus::report;
use ciminus::workload::zoo;

fn main() -> anyhow::Result<()> {
    let r50 = zoo::resnet50(32, 100);
    let v16 = zoo::vgg16(32, 100);

    println!("Fig. 11: 16 macros, orgs 8x2 / 4x4 / 2x8, hybrid Intra(2,1)+Full(2,16)@0.8\n");
    let pts = run_fig11(&[&r50, &v16], 0)?;
    println!("{}", report::mapping_table(&pts).render());

    // the paper's observations, checked live:
    let best = pts
        .iter()
        .filter(|p| p.model.starts_with("resnet50"))
        .min_by(|a, b| a.energy_pj.partial_cmp(&b.energy_pj).unwrap())
        .unwrap();
    println!(
        "lowest-energy resnet50 config: {} / {} (paper: 4x4 + duplication)\n",
        best.org, best.strategy
    );

    println!("Fig. 12: rearrangement on/off, 4x4 org\n");
    let pts12 = run_fig12(&r50, 0)?;
    println!("{}", report::rearrange_table(&pts12).render());
    for p in &pts12 {
        println!(
            "  {} rearranged={}: buffer energy {:.3} uJ of {:.3} uJ total",
            p.strategy,
            p.rearranged,
            p.buffer_energy_pj / 1e6,
            p.energy_pj / 1e6
        );
    }
    println!("\nFinding 2: utilization rises with rearrangement, but buffer overhead can negate the gain.");
    Ok(())
}
