//! Quickstart: describe a workload + architecture + sparsity, simulate,
//! and read the cost report. Run with:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ciminus::hw::presets;
use ciminus::sim::engine::simulate_network_default;
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::workload::zoo;

fn main() -> anyhow::Result<()> {
    // 1. A workload from the zoo (or build your own via Network's
    //    builder / JSON import — see workload::import).
    let net = zoo::resnet18(32, 100);
    println!("{}", net.describe());

    // 2. An architecture: the paper's 4-macro use-case config
    //    (1024x32 macros, 32x32 sub-arrays, 2x2 organization).
    let arch = presets::usecase_arch(4, (2, 2));
    println!("{}\n", arch.describe());

    // 3. A FlexBlock sparsity description: 80% row-block sparsity.
    let fb = FlexBlock::row_block(16, 0.8);
    println!("sparsity: {} = {}\n", fb.name, fb.representation());

    // 4. Simulate sparse vs. the dense baseline (no sparsity hardware).
    let dense_arch = presets::usecase_dense_baseline(4, (2, 2));
    let dense = simulate_network_default(&dense_arch, &net, None)?;
    let sparse = simulate_network_default(&arch, &net, Some(&fb))?;

    println!("{}", dense.summary());
    println!("{}", sparse.summary());
    println!(
        "speedup {:.2}x   energy saving {:.2}x",
        sparse.speedup_vs(&dense),
        sparse.energy_saving_vs(&dense)
    );
    println!("\nenergy breakdown (sparse):\n{}", sparse.energy_table().render());
    Ok(())
}
