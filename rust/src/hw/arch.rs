//! The full architecture description (Sec. IV-C ②): macro geometry +
//! organization + buffers + sparsity-support units + energy table.

use super::buffer::Buffer;
use super::cim_macro::CimMacro;
use super::energy::EnergyTable;
use super::faults::FaultModel;
use super::org::MacroOrg;
use crate::util::json::Json;

/// Sparsity-support hardware configuration (Sec. IV-C ② ③).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparsitySupport {
    /// Block-index memories + compressed-weight handling.
    pub weight_indexing: bool,
    /// Mux-based input routing for IntraBlock / vertical packing.
    pub weight_routing: bool,
    /// Zero-bit detection + skip logic in pre-processing units.
    pub input_skipping: bool,
}

impl SparsitySupport {
    pub fn none() -> Self {
        Self {
            weight_indexing: false,
            weight_routing: false,
            input_skipping: false,
        }
    }

    pub fn full() -> Self {
        Self {
            weight_indexing: true,
            weight_routing: true,
            input_skipping: true,
        }
    }

    pub fn weight_only() -> Self {
        Self {
            weight_indexing: true,
            weight_routing: true,
            input_skipping: false,
        }
    }
}

/// A complete CIM accelerator description.
#[derive(Debug, Clone, PartialEq)]
pub struct Architecture {
    pub name: String,
    /// Clock frequency in GHz (cycle time = 1/clock ns).
    pub clock_ghz: f64,
    /// Input (activation) bit width for bit-serial processing.
    pub input_bits: usize,
    /// Weight bit width.
    pub weight_bits: usize,
    pub cim: CimMacro,
    pub org: MacroOrg,
    /// Input-feature global buffer.
    pub global_in_buf: Buffer,
    /// Output-feature global buffer.
    pub global_out_buf: Buffer,
    /// Weight global buffer (may be the same physical buffer in some
    /// designs; modeled separately with combined capacity if so).
    pub weight_buf: Buffer,
    /// Per-macro local buffer.
    pub local_buf: Buffer,
    /// Index memory for sparsity support.
    pub index_mem: Buffer,
    pub energy: EnergyTable,
    pub sparsity: SparsitySupport,
    /// Injected silicon faults (the all-zero default is fault-free and
    /// guaranteed not to perturb any result).
    pub faults: FaultModel,
}

impl Architecture {
    pub fn validate(&self) -> anyhow::Result<()> {
        self.cim.validate()?;
        self.org.validate()?;
        if self.clock_ghz <= 0.0 {
            anyhow::bail!("clock must be positive");
        }
        if !(1..=16).contains(&self.input_bits) || !(1..=16).contains(&self.weight_bits) {
            anyhow::bail!(
                "bit widths must be in 1..=16 (input {}, weight {})",
                self.input_bits,
                self.weight_bits
            );
        }
        for b in [
            &self.global_in_buf,
            &self.global_out_buf,
            &self.weight_buf,
            &self.local_buf,
            &self.index_mem,
        ] {
            if b.size_bytes == 0 || b.bandwidth_bytes_cycle <= 0.0 {
                anyhow::bail!("buffer `{}` must have positive size and bandwidth", b.name);
            }
        }
        self.faults.validate()?;
        Ok(())
    }

    /// A copy of the architecture with simulation-only knobs forced to
    /// canonical values: input skipping off and every buffer's
    /// ping-pong off. The mapping planner never reads these knobs, so
    /// two architectures that differ only in them produce identical
    /// plans — the eval layer hashes this view for its planning-stage
    /// cache key so such pairs (e.g. fig11's skip on/off) share one
    /// cached `MappingPlan`.
    pub fn planning_view(&self) -> Architecture {
        let mut a = self.clone();
        a.sparsity.input_skipping = false;
        for b in [
            &mut a.global_in_buf,
            &mut a.global_out_buf,
            &mut a.weight_buf,
            &mut a.local_buf,
            &mut a.index_mem,
        ] {
            b.ping_pong = false;
        }
        a
    }

    /// Total weight words storable across all macros.
    pub fn total_weight_capacity_words(&self) -> usize {
        self.org.n_macros() * self.cim.capacity_words()
    }

    /// Cycle time in nanoseconds.
    pub fn cycle_ns(&self) -> f64 {
        1.0 / self.clock_ghz
    }

    /// One-paragraph description for reports (Table I style).
    pub fn describe(&self) -> String {
        format!(
            "{}: macro {}x{} (sub {}x{}), org {} ({} macros), in-buf {} KB{}, out-buf {} KB, w-buf {} KB, {}b/{}b, {} GHz",
            self.name,
            self.cim.rows,
            self.cim.cols,
            self.cim.sub_rows,
            self.cim.sub_cols,
            self.org.label(),
            self.org.n_macros(),
            self.global_in_buf.size_bytes / 1024,
            if self.global_in_buf.ping_pong { " (ping-pong)" } else { "" },
            self.global_out_buf.size_bytes / 1024,
            self.weight_buf.size_bytes / 1024,
            self.input_bits,
            self.weight_bits,
            self.clock_ghz,
        )
    }

    /// Parse an architecture from a JSON config (the user-facing hardware
    /// description interface). Missing fields default to the 4-macro
    /// use-case architecture's values.
    pub fn from_json(j: &Json) -> anyhow::Result<Architecture> {
        let base = super::presets::usecase_arch(4, (2, 2));
        let mut a = base;
        if let Some(name) = j.get("name").and_then(|v| v.as_str()) {
            a.name = name.to_string();
        }
        a.clock_ghz = j.opt_f64("clock_ghz", a.clock_ghz);
        a.input_bits = j.opt_usize("input_bits", a.input_bits);
        a.weight_bits = j.opt_usize("weight_bits", a.weight_bits);
        if let Some(m) = j.get("macro") {
            a.cim = CimMacro::new(
                m.opt_usize("rows", a.cim.rows),
                m.opt_usize("cols", a.cim.cols),
                m.opt_usize("sub_rows", a.cim.sub_rows),
                m.opt_usize("sub_cols", a.cim.sub_cols),
            );
        }
        if let Some(o) = j.get("org").and_then(|v| v.as_arr()) {
            a.org = MacroOrg {
                dims: o
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad org dim")))
                    .collect::<anyhow::Result<_>>()?,
            };
        }
        for (key, slot) in [
            ("global_in_buf", &mut a.global_in_buf),
            ("global_out_buf", &mut a.global_out_buf),
            ("weight_buf", &mut a.weight_buf),
            ("local_buf", &mut a.local_buf),
            ("index_mem", &mut a.index_mem),
        ] {
            if let Some(b) = j.get(key) {
                let size = b.opt_usize("size_kb", slot.size_bytes / 1024) * 1024;
                let width = b.opt_usize("width_bits", slot.width_bits);
                let pp = b.opt_bool("ping_pong", slot.ping_pong);
                let mut nb = Buffer::new(&slot.name, size, width, pp);
                nb.bandwidth_bytes_cycle =
                    b.opt_f64("bandwidth_bytes_cycle", nb.bandwidth_bytes_cycle);
                nb.read_pj = b.opt_f64("read_pj", nb.read_pj);
                nb.write_pj = b.opt_f64("write_pj", nb.write_pj);
                *slot = nb;
            }
        }
        if let Some(e) = j.get("energy") {
            a.energy = a.energy.from_json_overlay(e)?;
        }
        if let Some(s) = j.get("sparsity") {
            a.sparsity.weight_indexing = s.opt_bool("weight_indexing", a.sparsity.weight_indexing);
            a.sparsity.weight_routing = s.opt_bool("weight_routing", a.sparsity.weight_routing);
            a.sparsity.input_skipping = s.opt_bool("input_skipping", a.sparsity.input_skipping);
        }
        if let Some(f) = j.get("faults") {
            a.faults = FaultModel::from_json(f)?;
        }
        a.validate()?;
        Ok(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn presets_validate() {
        presets::mars().validate().unwrap();
        presets::sdp().validate().unwrap();
        presets::usecase_arch(4, (2, 2)).validate().unwrap();
        presets::usecase_arch(16, (4, 4)).validate().unwrap();
    }

    #[test]
    fn describe_mentions_key_dims() {
        let a = presets::mars();
        let d = a.describe();
        assert!(d.contains("1024x64"));
        assert!(d.contains("2x4"));
    }

    #[test]
    fn json_overrides() {
        let j = Json::parse(
            r#"{
                "name": "custom",
                "clock_ghz": 0.5,
                "macro": {"rows": 256, "cols": 32, "sub_rows": 32, "sub_cols": 32},
                "org": [2, 2],
                "global_in_buf": {"size_kb": 64, "ping_pong": true},
                "sparsity": {"input_skipping": false}
            }"#,
        )
        .unwrap();
        let a = Architecture::from_json(&j).unwrap();
        assert_eq!(a.name, "custom");
        assert_eq!(a.clock_ghz, 0.5);
        assert_eq!(a.cim.rows, 256);
        assert_eq!(a.global_in_buf.size_bytes, 64 * 1024);
        assert!(a.global_in_buf.ping_pong);
        assert!(!a.sparsity.input_skipping);
        assert_eq!(a.org.n_macros(), 4);
    }

    #[test]
    fn json_faults_overlay() {
        let j = Json::parse(
            r#"{"faults": {"seed": 9, "stuck_cell_rate": 0.01, "spatial": "cluster"}}"#,
        )
        .unwrap();
        let a = Architecture::from_json(&j).unwrap();
        assert_eq!(a.faults.seed, 9);
        assert_eq!(a.faults.spatial, crate::hw::faults::FaultSpatial::Cluster);
        assert!(!a.faults.is_zero());
        // default architectures are fault-free
        let clean = Architecture::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(clean.faults.is_zero());
        // out-of-range rates rejected
        let bad = Json::parse(r#"{"faults": {"dead_macro_rate": 2.0}}"#).unwrap();
        assert!(Architecture::from_json(&bad).is_err());
    }

    #[test]
    fn json_invalid_rejected() {
        let j = Json::parse(r#"{"macro": {"rows": 100, "sub_rows": 64}}"#).unwrap();
        assert!(Architecture::from_json(&j).is_err());
    }

    #[test]
    fn capacity_math() {
        let a = presets::mars();
        // 8 macros × 1024×64 words
        assert_eq!(a.total_weight_capacity_words(), 8 * 1024 * 64);
    }
}
