//! Memory-unit descriptions (Sec. IV-C ②): global/local buffers and
//! index memories, with capacity, port width, bandwidth and per-access
//! energies (defaulted from the analytical SRAM model, overridable).

use super::energy::{sram_access_pj, sram_static_pj_cycle};

/// One buffer / memory structure.
#[derive(Debug, Clone, PartialEq)]
pub struct Buffer {
    pub name: String,
    pub size_bytes: usize,
    /// Port width in bits (one access moves this many bits).
    pub width_bits: usize,
    /// Sustained bandwidth, bytes per cycle (ports × width).
    pub bandwidth_bytes_cycle: f64,
    /// Double-buffered (ping-pong): loads overlap compute (Sec. IV-C ②).
    pub ping_pong: bool,
    pub read_pj: f64,
    pub write_pj: f64,
    pub static_pj_cycle: f64,
}

impl Buffer {
    /// Build with energies from the analytical SRAM model.
    pub fn new(name: &str, size_bytes: usize, width_bits: usize, ping_pong: bool) -> Self {
        let acc = sram_access_pj(size_bytes, width_bits);
        Self {
            name: name.to_string(),
            size_bytes,
            width_bits,
            bandwidth_bytes_cycle: width_bits as f64 / 8.0,
            ping_pong,
            read_pj: acc,
            write_pj: acc * 1.1, // writes slightly costlier
            static_pj_cycle: sram_static_pj_cycle(size_bytes),
        }
    }

    pub fn with_bandwidth(mut self, bytes_per_cycle: f64) -> Self {
        self.bandwidth_bytes_cycle = bytes_per_cycle;
        self
    }

    /// Cycles to move `bytes` through this buffer's port.
    pub fn transfer_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        (bytes as f64 / self.bandwidth_bytes_cycle).ceil() as u64
    }

    /// Number of port accesses to move `bytes`.
    pub fn accesses_for(&self, bytes: u64) -> u64 {
        (bytes * 8).div_ceil(self.width_bits as u64)
    }

    /// Effective capacity available to one pipeline stage: half for
    /// ping-pong buffers (the other half is being filled).
    pub fn stage_capacity(&self) -> usize {
        if self.ping_pong {
            self.size_bytes / 2
        } else {
            self.size_bytes
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_and_access_counts() {
        let b = Buffer::new("gbuf", 128 * 1024, 64, false);
        assert_eq!(b.bandwidth_bytes_cycle, 8.0);
        assert_eq!(b.transfer_cycles(64), 8);
        assert_eq!(b.transfer_cycles(0), 0);
        assert_eq!(b.accesses_for(64), 8);
        assert_eq!(b.accesses_for(1), 1); // partial word still one access
    }

    #[test]
    fn ping_pong_halves_capacity() {
        let pp = Buffer::new("pp", 128 * 1024, 64, true);
        assert_eq!(pp.stage_capacity(), 64 * 1024);
        let flat = Buffer::new("f", 128 * 1024, 64, false);
        assert_eq!(flat.stage_capacity(), 128 * 1024);
    }

    #[test]
    fn energy_scales_with_size() {
        let small = Buffer::new("s", 4 * 1024, 64, false);
        let big = Buffer::new("b", 256 * 1024, 64, false);
        assert!(big.read_pj > small.read_pj);
        assert!(big.static_pj_cycle > small.static_pj_cycle);
        assert!(small.write_pj > small.read_pj);
    }

    #[test]
    fn custom_bandwidth() {
        let b = Buffer::new("x", 1024, 64, false).with_bandwidth(32.0);
        assert_eq!(b.transfer_cycles(64), 2);
    }
}
