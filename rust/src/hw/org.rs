//! Macro organization (Sec. IV-C ②, the `organization` parameter): a
//! variable-length list of dimensions describing how macros are laid
//! out. Two dimensions in practice — (row-parallel, column-parallel) —
//! where the row dimension spatially unrolls weight-matrix rows and the
//! column dimension unrolls columns or duplicates weights (Fig. 11's
//! 8×2 / 4×4 / 2×8 organizations).

/// Macro grid organization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MacroOrg {
    /// Organization dims, outermost first. Length 1 or 2 supported.
    pub dims: Vec<usize>,
}

impl MacroOrg {
    pub fn grid(rows: usize, cols: usize) -> Self {
        Self {
            dims: vec![rows, cols],
        }
    }

    pub fn linear(n: usize) -> Self {
        Self { dims: vec![n] }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.dims.is_empty() || self.dims.len() > 2 {
            anyhow::bail!(
                "organization must have 1 or 2 dims, got {}",
                self.dims.len()
            );
        }
        if self.dims.iter().any(|&d| d == 0) {
            anyhow::bail!("organization dims must be positive: {:?}", self.dims);
        }
        Ok(())
    }

    pub fn n_macros(&self) -> usize {
        self.dims.iter().product()
    }

    /// Macros along the weight-row unrolling direction.
    pub fn row_dim(&self) -> usize {
        self.dims[0]
    }

    /// Macros along the column/duplication direction.
    pub fn col_dim(&self) -> usize {
        if self.dims.len() > 1 {
            self.dims[1]
        } else {
            1
        }
    }

    pub fn label(&self) -> String {
        self.dims
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_orgs() {
        let o = MacroOrg::grid(4, 4);
        o.validate().unwrap();
        assert_eq!(o.n_macros(), 16);
        assert_eq!((o.row_dim(), o.col_dim()), (4, 4));
        assert_eq!(o.label(), "4x4");
    }

    #[test]
    fn linear_org() {
        let o = MacroOrg::linear(8);
        o.validate().unwrap();
        assert_eq!(o.n_macros(), 8);
        assert_eq!(o.col_dim(), 1);
    }

    #[test]
    fn rejects_bad_dims() {
        assert!(MacroOrg { dims: vec![] }.validate().is_err());
        assert!(MacroOrg { dims: vec![1, 2, 3] }.validate().is_err());
        assert!(MacroOrg { dims: vec![0, 2] }.validate().is_err());
    }
}
