//! Fault injection for CIM macros: a deterministic, seedable model of
//! the silicon-degradation mechanisms that matter for SRAM-based CIM —
//! stuck-at weight cells (with uniform / row / column / cluster spatial
//! distributions), dead ADC/mux columns, and whole-macro failures.
//!
//! The model is *capacity-oriented*: faults are reduced to per-macro
//! rectangular damage (quarantined rows + lost columns + dead macros),
//! the repair granularity real designs use (spare rows/columns, macro
//! disable fuses). The mapping planner consumes the resulting
//! [`FaultMap`] to shrink the usable geometry and spill work into extra
//! rounds; the simulator charges the repair-write traffic.
//!
//! Determinism contract: for a fixed seed, the fault set grows
//! monotonically with each rate — every macro consumes a *fixed* number
//! of RNG draws regardless of the rates, and each draw is compared
//! against a threshold monotone in the rate. Raising a rate can only
//! convert healthy draws to faulty ones, never the reverse. This is what
//! makes resilience curves monotone and reproducible.

use super::cim_macro::CimMacro;
use super::org::MacroOrg;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Stream-id base for fault instantiation, so fault draws never collide
/// with mask-generation or sweep streams derived from the same seed.
const FAULT_STREAM: u64 = 0xFA_017_5EED;

/// Spatial distribution of stuck-at weight cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSpatial {
    /// Independent cell faults; a row is quarantined if any cell in it
    /// is stuck (p_row = 1 - (1-p)^cols).
    Uniform,
    /// Whole-row defects (wordline driver / row periphery): each row is
    /// quarantined with probability p.
    Row,
    /// Column-correlated defects (bitline / ADC drift): each column is
    /// lost with probability p, on top of `dead_column_rate`.
    Column,
    /// Clustered blobs at sub-array granularity: one defect takes out a
    /// sub-array, quarantining its whole row group
    /// (p_group = 1 - (1-p)^(cols/sub_cols)).
    Cluster,
}

impl FaultSpatial {
    pub fn parse(s: &str) -> anyhow::Result<FaultSpatial> {
        Ok(match s {
            "uniform" => FaultSpatial::Uniform,
            "row" => FaultSpatial::Row,
            "column" => FaultSpatial::Column,
            "cluster" => FaultSpatial::Cluster,
            other => anyhow::bail!(
                "unknown fault spatial distribution `{other}` (uniform|row|column|cluster)"
            ),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            FaultSpatial::Uniform => "uniform",
            FaultSpatial::Row => "row",
            FaultSpatial::Column => "column",
            FaultSpatial::Cluster => "cluster",
        }
    }
}

/// Seedable fault model attached to an [`crate::hw::arch::Architecture`].
/// All rates are probabilities in [0, 1]; the all-zero model is the
/// fault-free default and is guaranteed not to perturb any result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultModel {
    pub seed: u64,
    /// Per-cell stuck-at probability (interpretation depends on
    /// `spatial`; see [`FaultSpatial`]).
    pub stuck_cell_rate: f64,
    pub spatial: FaultSpatial,
    /// Probability that a column's ADC/mux path is dead.
    pub dead_column_rate: f64,
    /// Probability that an entire macro is fused off.
    pub dead_macro_rate: f64,
    /// Per-macro spare-row repair budget: up to this many quarantined
    /// rows are remapped onto spares instead of shrinking the usable
    /// geometry. Repaired rows cost repair-write traffic, not capacity.
    pub spare_rows: usize,
    /// Per-macro spare-column repair budget (same semantics).
    pub spare_cols: usize,
}

impl FaultModel {
    /// The fault-free model (the default for every preset).
    pub fn none() -> FaultModel {
        FaultModel {
            seed: 0,
            stuck_cell_rate: 0.0,
            spatial: FaultSpatial::Uniform,
            dead_column_rate: 0.0,
            dead_macro_rate: 0.0,
            spare_rows: 0,
            spare_cols: 0,
        }
    }

    /// A single-knob model for resilience sweeps: stuck cells at `rate`,
    /// dead columns at `rate/4`, dead macros at `rate/8` — all monotone
    /// in `rate`, so the induced fault map grows with it.
    pub fn scaled(rate: f64, spatial: FaultSpatial, seed: u64) -> FaultModel {
        FaultModel {
            seed,
            stuck_cell_rate: rate,
            spatial,
            dead_column_rate: rate / 4.0,
            dead_macro_rate: rate / 8.0,
            spare_rows: 0,
            spare_cols: 0,
        }
    }

    /// The same model with per-macro spare-row/column repair budgets.
    pub fn with_spares(mut self, spare_rows: usize, spare_cols: usize) -> FaultModel {
        self.spare_rows = spare_rows;
        self.spare_cols = spare_cols;
        self
    }

    pub fn is_zero(&self) -> bool {
        self.stuck_cell_rate == 0.0 && self.dead_column_rate == 0.0 && self.dead_macro_rate == 0.0
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, r) in [
            ("stuck_cell_rate", self.stuck_cell_rate),
            ("dead_column_rate", self.dead_column_rate),
            ("dead_macro_rate", self.dead_macro_rate),
        ] {
            if !(0.0..=1.0).contains(&r) || r.is_nan() {
                anyhow::bail!("fault {name} must be in [0, 1], got {r}");
            }
        }
        Ok(())
    }

    /// Parse from the `"faults"` object of a JSON architecture config.
    pub fn from_json(j: &Json) -> anyhow::Result<FaultModel> {
        let fm = FaultModel {
            seed: j.opt_usize("seed", 0) as u64,
            stuck_cell_rate: j.opt_f64("stuck_cell_rate", 0.0),
            spatial: FaultSpatial::parse(j.opt_str("spatial", "uniform"))?,
            dead_column_rate: j.opt_f64("dead_column_rate", 0.0),
            dead_macro_rate: j.opt_f64("dead_macro_rate", 0.0),
            spare_rows: j.opt_usize("spare_rows", 0),
            spare_cols: j.opt_usize("spare_cols", 0),
        };
        fm.validate()?;
        Ok(fm)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("seed", Json::Num(self.seed as f64))
            .set("stuck_cell_rate", Json::Num(self.stuck_cell_rate))
            .set("spatial", Json::Str(self.spatial.label().into()))
            .set("dead_column_rate", Json::Num(self.dead_column_rate))
            .set("dead_macro_rate", Json::Num(self.dead_macro_rate))
            .set("spare_rows", Json::Num(self.spare_rows as f64))
            .set("spare_cols", Json::Num(self.spare_cols as f64));
        j
    }

    /// Instantiate the concrete fault map for one chip: deterministic in
    /// (seed, geometry), monotone in each rate (see module docs).
    pub fn instantiate(&self, cim: &CimMacro, org: &MacroOrg) -> FaultMap {
        let n = org.n_macros();
        let mut macros = Vec::with_capacity(n);
        for m in 0..n {
            // independent per-macro stream: adding macros never perturbs
            // the fault draws of existing ones
            let mut rng = Pcg32::with_stream(
                self.seed,
                FAULT_STREAM ^ (m as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            let dead = rng.next_f64() < self.dead_macro_rate;
            let mut lost_cols = 0usize;
            for _ in 0..cim.cols {
                if rng.next_f64() < self.dead_column_rate {
                    lost_cols += 1;
                }
            }
            let p = self.stuck_cell_rate;
            let mut lost_rows = 0usize;
            match self.spatial {
                FaultSpatial::Uniform => {
                    let p_row = 1.0 - (1.0 - p).powi(cim.cols as i32);
                    for _ in 0..cim.rows {
                        if rng.next_f64() < p_row {
                            lost_rows += 1;
                        }
                    }
                }
                FaultSpatial::Row => {
                    for _ in 0..cim.rows {
                        if rng.next_f64() < p {
                            lost_rows += 1;
                        }
                    }
                }
                FaultSpatial::Column => {
                    for _ in 0..cim.cols {
                        if rng.next_f64() < p {
                            lost_cols += 1;
                        }
                    }
                }
                FaultSpatial::Cluster => {
                    let groups = (cim.rows / cim.sub_rows.max(1)).max(1);
                    let subs_per_group = (cim.cols / cim.sub_cols.max(1)).max(1);
                    let p_group = 1.0 - (1.0 - p).powi(subs_per_group as i32);
                    for _ in 0..groups {
                        if rng.next_f64() < p_group {
                            lost_rows += cim.sub_rows;
                        }
                    }
                }
            }
            let lost_rows = lost_rows.min(cim.rows);
            let lost_cols = lost_cols.min(cim.cols);
            // spares repair damage up to the budget (applied after all
            // draws, so the draw order — and thus monotonicity in each
            // rate — is unchanged); a fused-off macro is beyond repair
            let (repaired_rows, repaired_cols) = if dead {
                (0, 0)
            } else {
                (
                    lost_rows.min(self.spare_rows),
                    lost_cols.min(self.spare_cols),
                )
            };
            macros.push(MacroHealth {
                dead,
                lost_rows: lost_rows - repaired_rows,
                lost_cols: lost_cols - repaired_cols,
                repaired_rows,
                repaired_cols,
            });
        }
        FaultMap {
            macros,
            rows: cim.rows,
            cols: cim.cols,
            sub_rows: cim.sub_rows,
            sub_cols: cim.sub_cols,
        }
    }
}

impl Default for FaultModel {
    fn default() -> Self {
        FaultModel::none()
    }
}

/// One macro's rectangular damage summary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacroHealth {
    /// Whole macro fused off.
    pub dead: bool,
    /// Rows quarantined by stuck cells after spare-row repair
    /// (spare-row repair granularity).
    pub lost_rows: usize,
    /// Columns lost to dead ADC/mux paths or column-correlated faults,
    /// after spare-column repair.
    pub lost_cols: usize,
    /// Rows remapped onto spares — full geometry kept, but the row's
    /// weights must be rewritten (repair traffic).
    pub repaired_rows: usize,
    /// Columns remapped onto spares (same semantics).
    pub repaired_cols: usize,
}

impl MacroHealth {
    /// No *residual* damage: the usable geometry is the full geometry.
    /// Repaired rows/columns do not make a macro unhealthy — they cost
    /// repair writes, not capacity (see [`FaultMap::has_repairs`]).
    pub fn is_healthy(&self) -> bool {
        !self.dead && self.lost_rows == 0 && self.lost_cols == 0
    }

    /// Cells rewritten onto spare resources for a macro of the given
    /// full geometry; row/column overlap is counted once.
    pub fn repaired_cells(&self, rows: usize, cols: usize) -> usize {
        self.repaired_rows * cols + self.repaired_cols * rows.saturating_sub(self.repaired_rows)
    }
}

/// A concrete instantiation of a [`FaultModel`] on one chip.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultMap {
    pub macros: Vec<MacroHealth>,
    /// Full (fault-free) macro geometry the damage is relative to.
    pub rows: usize,
    pub cols: usize,
    pub sub_rows: usize,
    pub sub_cols: usize,
}

impl FaultMap {
    /// No faults at all — guaranteed bit-identical behavior to the
    /// fault-free path. A map whose damage was fully repaired by spares
    /// is *clean* geometrically but still carries repair traffic; check
    /// [`FaultMap::has_repairs`] for that.
    pub fn is_clean(&self) -> bool {
        self.macros.iter().all(|h| h.is_healthy())
    }

    /// Any spare-row/column repairs anywhere on the chip.
    pub fn has_repairs(&self) -> bool {
        self.macros
            .iter()
            .any(|h| h.repaired_rows > 0 || h.repaired_cols > 0)
    }

    /// Fraction of total weight capacity remapped onto spare rows and
    /// columns — data that must be rewritten at deployment (charged as
    /// repair writes by the planner) even though it costs no capacity.
    pub fn repair_fraction(&self) -> f64 {
        let total = (self.rows * self.cols) as f64 * self.macros.len() as f64;
        if total == 0.0 {
            return 0.0;
        }
        let repaired: f64 = self
            .macros
            .iter()
            .map(|h| h.repaired_cells(self.rows, self.cols) as f64)
            .sum();
        repaired / total
    }

    /// One macro's usable geometry, floored to sub-array multiples (the
    /// sub-array is the adder-tree granularity; partial sub-arrays cannot
    /// be salvaged). `None` when the macro is dead or the damage consumes
    /// a full dimension — such a macro is fused off like a dead one, so a
    /// single bad column in a one-sub-array-wide macro degrades the chip
    /// by one macro instead of bricking it.
    pub fn macro_geometry(&self, h: &MacroHealth) -> Option<(usize, usize)> {
        if h.dead {
            return None;
        }
        let good_r = (self.rows - h.lost_rows) / self.sub_rows * self.sub_rows;
        let good_c = (self.cols - h.lost_cols) / self.sub_cols * self.sub_cols;
        if good_r == 0 || good_c == 0 {
            return None;
        }
        Some((good_r, good_c))
    }

    /// Macros that can still hold weights (non-zero usable geometry).
    pub fn usable_macros(&self) -> usize {
        self.macros
            .iter()
            .filter(|h| self.macro_geometry(h).is_some())
            .count()
    }

    /// The common usable geometry across all usable macros (the
    /// uniform-tile mapping abstraction needs one geometry, so the
    /// weakest surviving macro governs). `(0, 0)` when no macro survives.
    pub fn effective_geometry(&self) -> (usize, usize) {
        let mut eff_r = usize::MAX;
        let mut eff_c = usize::MAX;
        let mut any = false;
        for h in &self.macros {
            if let Some((good_r, good_c)) = self.macro_geometry(h) {
                any = true;
                eff_r = eff_r.min(good_r);
                eff_c = eff_c.min(good_c);
            }
        }
        if !any {
            return (0, 0);
        }
        (eff_r, eff_c)
    }

    /// Fraction of total weight capacity lost to faults, counting each
    /// macro's floored usable geometry (what the mapping can actually
    /// use). Monotone in the fault set: damage only shrinks per-macro
    /// geometry, and crossing the fused-off threshold is one-way.
    pub fn capacity_loss(&self) -> f64 {
        let per = (self.rows * self.cols) as f64;
        let total = per * self.macros.len() as f64;
        if total == 0.0 {
            return 0.0;
        }
        let good: f64 = self
            .macros
            .iter()
            .filter_map(|h| self.macro_geometry(h))
            .map(|(r, c)| (r * c) as f64)
            .sum();
        1.0 - good / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    fn geom() -> (CimMacro, MacroOrg) {
        let a = presets::usecase_arch(4, (2, 2));
        (a.cim, a.org)
    }

    #[test]
    fn zero_model_yields_clean_map() {
        let (cim, org) = geom();
        let m = FaultModel::none();
        assert!(m.is_zero());
        let map = m.instantiate(&cim, &org);
        assert!(map.is_clean());
        assert_eq!(map.usable_macros(), 4);
        assert_eq!(map.effective_geometry(), (cim.rows, cim.cols));
        assert_eq!(map.capacity_loss(), 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let (cim, org) = geom();
        let m = FaultModel::scaled(0.05, FaultSpatial::Uniform, 42);
        assert_eq!(m.instantiate(&cim, &org), m.instantiate(&cim, &org));
        let other = FaultModel::scaled(0.05, FaultSpatial::Uniform, 43);
        assert_ne!(m.instantiate(&cim, &org), other.instantiate(&cim, &org));
    }

    #[test]
    fn fault_map_grows_monotonically_with_rate() {
        let (cim, org) = geom();
        for spatial in [
            FaultSpatial::Uniform,
            FaultSpatial::Row,
            FaultSpatial::Column,
            FaultSpatial::Cluster,
        ] {
            let mut prev_loss = -1.0;
            let mut prev_usable = usize::MAX;
            for rate in [0.0, 0.005, 0.02, 0.08, 0.3] {
                let map = FaultModel::scaled(rate, spatial, 7).instantiate(&cim, &org);
                let loss = map.capacity_loss();
                assert!(
                    loss >= prev_loss,
                    "{}: loss {loss} < {prev_loss} at rate {rate}",
                    spatial.label()
                );
                assert!(map.usable_macros() <= prev_usable);
                prev_loss = loss;
                prev_usable = map.usable_macros();
            }
        }
    }

    #[test]
    fn cluster_quarantines_whole_row_groups() {
        let (cim, org) = geom();
        let map = FaultModel {
            seed: 3,
            stuck_cell_rate: 0.5,
            spatial: FaultSpatial::Cluster,
            dead_column_rate: 0.0,
            dead_macro_rate: 0.0,
            spare_rows: 0,
            spare_cols: 0,
        }
        .instantiate(&cim, &org);
        for h in &map.macros {
            assert_eq!(h.lost_rows % cim.sub_rows, 0, "row-group granularity");
        }
        assert!(map.macros.iter().any(|h| h.lost_rows > 0));
    }

    #[test]
    fn effective_geometry_is_subarray_aligned() {
        let (cim, org) = geom();
        let map = FaultModel::scaled(0.03, FaultSpatial::Uniform, 11).instantiate(&cim, &org);
        let (r, c) = map.effective_geometry();
        assert_eq!(r % cim.sub_rows, 0);
        assert_eq!(c % cim.sub_cols, 0);
        assert!(r < cim.rows, "uniform faults at 3% quarantine some rows");
    }

    #[test]
    fn all_macros_dead_gives_zero_geometry() {
        let (cim, org) = geom();
        let map = FaultModel {
            seed: 1,
            stuck_cell_rate: 0.0,
            spatial: FaultSpatial::Uniform,
            dead_column_rate: 0.0,
            dead_macro_rate: 1.0,
            spare_rows: 4,
            spare_cols: 4,
        }
        .instantiate(&cim, &org);
        assert_eq!(map.usable_macros(), 0);
        assert_eq!(map.effective_geometry(), (0, 0));
        assert!((map.capacity_loss() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let m = FaultModel::scaled(0.01, FaultSpatial::Cluster, 99);
        let j = m.to_json();
        let m2 = FaultModel::from_json(&j).unwrap();
        assert_eq!(m, m2);
        let bad = Json::parse(r#"{"stuck_cell_rate": 1.5}"#).unwrap();
        assert!(FaultModel::from_json(&bad).is_err());
        let bad_spatial = Json::parse(r#"{"spatial": "diagonal"}"#).unwrap();
        assert!(FaultModel::from_json(&bad_spatial).is_err());
    }

    #[test]
    fn spares_repair_damage_and_charge_repair_traffic() {
        let (cim, org) = geom();
        let base = FaultModel {
            seed: 7,
            stuck_cell_rate: 0.08,
            spatial: FaultSpatial::Row,
            dead_column_rate: 0.0,
            dead_macro_rate: 0.0,
            spare_rows: 0,
            spare_cols: 0,
        };
        let unrepaired = base.instantiate(&cim, &org);
        assert!(!unrepaired.is_clean(), "8% row faults damage some macro");
        assert!(!unrepaired.has_repairs());
        assert_eq!(unrepaired.repair_fraction(), 0.0);
        // a budget as large as the macro repairs everything
        let repaired = base.with_spares(cim.rows, cim.cols).instantiate(&cim, &org);
        assert!(repaired.is_clean(), "all damage fits the spare budget");
        assert!(repaired.has_repairs());
        assert!(repaired.repair_fraction() > 0.0);
        assert_eq!(repaired.capacity_loss(), 0.0);
        assert_eq!(repaired.effective_geometry(), (cim.rows, cim.cols));
        // total damage is conserved: net loss + repairs = raw loss
        for (u, r) in unrepaired.macros.iter().zip(&repaired.macros) {
            assert_eq!(u.lost_rows, r.lost_rows + r.repaired_rows);
            assert_eq!(u.lost_cols, r.lost_cols + r.repaired_cols);
        }
    }

    #[test]
    fn dead_macros_are_beyond_repair() {
        let (cim, org) = geom();
        let map = FaultModel {
            seed: 1,
            stuck_cell_rate: 0.0,
            spatial: FaultSpatial::Uniform,
            dead_column_rate: 0.0,
            dead_macro_rate: 1.0,
            spare_rows: cim.rows,
            spare_cols: cim.cols,
        }
        .instantiate(&cim, &org);
        assert_eq!(map.usable_macros(), 0);
        assert!(!map.has_repairs(), "spares cannot revive fused-off macros");
    }

    #[test]
    fn spares_json_roundtrip() {
        let m = FaultModel::scaled(0.02, FaultSpatial::Row, 5).with_spares(2, 1);
        let m2 = FaultModel::from_json(&m.to_json()).unwrap();
        assert_eq!(m, m2);
        assert_eq!(m2.spare_rows, 2);
        assert_eq!(m2.spare_cols, 1);
    }

    #[test]
    fn prop_spares_never_increase_loss_and_loss_stays_monotone() {
        use crate::util::proptest::{check, ensure, Gen};
        let (cim, org) = geom();
        check("spare-repair monotonicity", 60, 0xFA75, |g: &mut Gen| {
            let spatial = *g.choose(&[
                FaultSpatial::Uniform,
                FaultSpatial::Row,
                FaultSpatial::Column,
                FaultSpatial::Cluster,
            ]);
            let seed = g.usize_in(0, 1 << 20) as u64;
            let spare_rows = g.usize_in(0, cim.rows);
            let spare_cols = g.usize_in(0, cim.cols);
            let mut prev_loss = -1.0;
            let mut prev_repair = -1.0;
            for rate in [0.0, 0.01, 0.04, 0.12, 0.35] {
                let base = FaultModel::scaled(rate, spatial, seed);
                let with = base.with_spares(spare_rows, spare_cols).instantiate(&cim, &org);
                let without = base.instantiate(&cim, &org);
                ensure(
                    with.capacity_loss() <= without.capacity_loss() + 1e-12,
                    format!(
                        "spares increased loss at rate {rate} ({} vs {})",
                        with.capacity_loss(),
                        without.capacity_loss()
                    ),
                )?;
                ensure(
                    with.usable_macros() >= without.usable_macros(),
                    format!("spares lost usable macros at rate {rate}"),
                )?;
                let loss = with.capacity_loss();
                ensure(
                    loss >= prev_loss - 1e-12,
                    format!("repaired loss not monotone in rate at {rate}"),
                )?;
                prev_loss = loss;
                for h in &with.macros {
                    ensure(
                        h.repaired_rows <= spare_rows && h.repaired_cols <= spare_cols,
                        "repairs exceeded the spare budget",
                    )?;
                }
                // repair traffic is monotone in rate while macros stay
                // alive (a fused-off macro forfeits its repairs, so the
                // global fraction is only monotone without macro death)
                let nodead = FaultModel {
                    dead_macro_rate: 0.0,
                    ..base.with_spares(spare_rows, spare_cols)
                }
                .instantiate(&cim, &org);
                let repair = nodead.repair_fraction();
                ensure(
                    repair >= prev_repair - 1e-12,
                    format!("repair fraction not monotone in rate at {rate}"),
                )?;
                prev_repair = repair;
            }
            Ok(())
        });
    }

    #[test]
    fn spatial_parse_labels() {
        for s in ["uniform", "row", "column", "cluster"] {
            assert_eq!(FaultSpatial::parse(s).unwrap().label(), s);
        }
        assert!(FaultSpatial::parse("nope").is_err());
    }
}
