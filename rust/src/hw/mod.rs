//! Hardware description layer (Sec. IV-C ②): macro geometry,
//! organization, buffers, energy tables, sparsity-support units, and the
//! Table I / Sec. VII-A presets.

pub mod arch;
pub mod buffer;
pub mod cim_macro;
pub mod energy;
pub mod faults;
pub mod org;
pub mod presets;
pub mod units;

pub use arch::{Architecture, SparsitySupport};
pub use buffer::Buffer;
pub use cim_macro::CimMacro;
pub use energy::{EnergyTable, UnitEnergy};
pub use faults::{FaultMap, FaultModel, FaultSpatial, MacroHealth};
pub use org::MacroOrg;
pub use units::{UnitCounts, UnitKind};
