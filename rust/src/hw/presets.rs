//! Built-in architecture presets: the validation targets of Table I
//! (MARS [19], SDP [20]) and the common use-case architecture of
//! Sec. VII-A.

use super::arch::{Architecture, SparsitySupport};
use super::faults::FaultModel;
use super::buffer::Buffer;
use super::cim_macro::CimMacro;
use super::energy::EnergyTable;
use super::org::MacroOrg;

/// MARS (Table I): 1024×64 macros with 64×64 sub-arrays, 8 macros in a
/// 2×4 organization, 128 KB ping-pong global buffer, FullBlock(1,16)
/// sparsity, Conv layers only.
pub fn mars() -> Architecture {
    Architecture {
        name: "MARS".into(),
        clock_ghz: 0.5,
        input_bits: 8,
        weight_bits: 8,
        cim: CimMacro::new(1024, 64, 64, 64),
        org: MacroOrg::grid(2, 4),
        global_in_buf: Buffer::new("global_buf_in", 64 * 1024, 128, true),
        global_out_buf: Buffer::new("global_buf_out", 64 * 1024, 128, true),
        weight_buf: Buffer::new("weight_buf", 256 * 1024, 256, false),
        local_buf: Buffer::new("local_buf", 4 * 1024, 64, false),
        index_mem: Buffer::new("index_mem", 8 * 1024, 32, false),
        energy: EnergyTable::preset_28nm(),
        sparsity: SparsitySupport {
            // MARS's "index-aware optimizations" route inputs to packed
            // groups — routing support is present
            weight_routing: true,
            weight_indexing: true,
            input_skipping: true,
        },
        faults: FaultModel::none(),
    }
}

/// SDP (Table I): 32×64 macros with 1×64 sub-arrays (row-granular adder
/// trees), 512 macros in a 16×32 organization, 256 KB input / 128 KB
/// output buffers, Intra(2,1)+Full(2,8) sparsity, whole-network scope.
pub fn sdp() -> Architecture {
    Architecture {
        name: "SDP".into(),
        clock_ghz: 0.5,
        input_bits: 8,
        weight_bits: 8,
        cim: CimMacro::new(32, 64, 1, 64),
        org: MacroOrg::grid(16, 32),
        global_in_buf: Buffer::new("global_buf_in", 256 * 1024, 256, false),
        global_out_buf: Buffer::new("global_buf_out", 128 * 1024, 256, false),
        // 512 tiny macros need a highly banked weight/index distribution
        // network (bandwidths are the undisclosed-parameter calibration
        // the paper mentions in Sec. VI-A)
        weight_buf: Buffer::new("weight_buf", 512 * 1024, 256, false).with_bandwidth(512.0),
        local_buf: Buffer::new("local_buf", 2 * 1024, 64, false),
        index_mem: Buffer::new("index_mem", 16 * 1024, 32, false).with_bandwidth(128.0),
        energy: EnergyTable::preset_28nm(),
        sparsity: SparsitySupport::full(),
        faults: FaultModel::none(),
    }
}

/// Common use-case architecture (Sec. VII-A): 8-bit precision, macros of
/// 1024×32 with 32×32 sub-arrays, weight-stationary; `n_macros` macros in
/// the given organization, all sharing broadcast inputs from one input
/// buffer.
pub fn usecase_arch(n_macros: usize, org: (usize, usize)) -> Architecture {
    assert_eq!(
        org.0 * org.1,
        n_macros,
        "organization {}x{} != {n_macros} macros",
        org.0,
        org.1
    );
    Architecture {
        name: format!("usecase_{n_macros}m_{}x{}", org.0, org.1),
        clock_ghz: 0.5,
        input_bits: 8,
        weight_bits: 8,
        cim: CimMacro::new(1024, 32, 32, 32),
        org: MacroOrg::grid(org.0, org.1),
        global_in_buf: Buffer::new("global_buf_in", 128 * 1024, 128, true),
        global_out_buf: Buffer::new("global_buf_out", 128 * 1024, 128, true),
        weight_buf: Buffer::new("weight_buf", 512 * 1024, 256, false),
        local_buf: Buffer::new("local_buf", 4 * 1024, 64, false),
        index_mem: Buffer::new("index_mem", 16 * 1024, 32, false),
        energy: EnergyTable::preset_28nm(),
        sparsity: SparsitySupport::full(),
        faults: FaultModel::none(),
    }
}

/// The dense baseline of Sec. VII-A: same geometry, no sparsity-support
/// hardware at all.
pub fn usecase_dense_baseline(n_macros: usize, org: (usize, usize)) -> Architecture {
    let mut a = usecase_arch(n_macros, org);
    a.name = format!("{}_dense", a.name);
    a.sparsity = SparsitySupport::none();
    a
}

/// Preset lookup by name for the CLI.
pub fn by_name(name: &str) -> anyhow::Result<Architecture> {
    Ok(match name {
        "mars" => mars(),
        "sdp" => sdp(),
        "usecase4" => usecase_arch(4, (2, 2)),
        "usecase16" => usecase_arch(16, (4, 4)),
        other => anyhow::bail!("unknown architecture preset `{other}` (mars|sdp|usecase4|usecase16)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_parameters() {
        let m = mars();
        assert_eq!((m.cim.rows, m.cim.cols), (1024, 64));
        assert_eq!((m.cim.sub_rows, m.cim.sub_cols), (64, 64));
        assert_eq!(m.org.n_macros(), 8);
        assert!(m.global_in_buf.ping_pong);
        let s = sdp();
        assert_eq!((s.cim.rows, s.cim.cols), (32, 64));
        assert_eq!((s.cim.sub_rows, s.cim.sub_cols), (1, 64));
        assert_eq!(s.org.n_macros(), 512);
        assert_eq!(s.global_in_buf.size_bytes, 256 * 1024);
        assert_eq!(s.global_out_buf.size_bytes, 128 * 1024);
    }

    #[test]
    fn usecase_orgs() {
        for org in [(8, 2), (4, 4), (2, 8)] {
            let a = usecase_arch(16, org);
            a.validate().unwrap();
            assert_eq!(a.org.n_macros(), 16);
            assert_eq!((a.cim.rows, a.cim.cols), (1024, 32));
        }
    }

    #[test]
    #[should_panic]
    fn usecase_org_mismatch_panics() {
        usecase_arch(4, (4, 4));
    }

    #[test]
    fn dense_baseline_has_no_support() {
        let a = usecase_dense_baseline(4, (2, 2));
        assert!(!a.sparsity.weight_indexing);
        assert!(!a.sparsity.input_skipping);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("mars").is_ok());
        assert!(by_name("sdp").is_ok());
        assert!(by_name("nope").is_err());
    }
}
