//! Compute/memory unit inventory and automatic unit-count inference
//! (Sec. IV-C ②: "CIMinus automatically infers the number of units
//! required based on the CIM array size, unit size, and the organization
//! parameter"). Static energy is charged per instantiated unit.

use super::arch::Architecture;

/// Unit classes tracked by the simulator's access counters and energy
/// breakdown (Fig. 6(c)-style component split).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum UnitKind {
    CimArray,
    AdderTree,
    ShiftAdd,
    Accumulator,
    PreProc,
    ZeroDetect,
    Mux,
    PostProc,
    IndexMem,
    GlobalInBuf,
    GlobalOutBuf,
    WeightBuf,
    LocalBuf,
}

impl UnitKind {
    pub const ALL: [UnitKind; 13] = [
        UnitKind::CimArray,
        UnitKind::AdderTree,
        UnitKind::ShiftAdd,
        UnitKind::Accumulator,
        UnitKind::PreProc,
        UnitKind::ZeroDetect,
        UnitKind::Mux,
        UnitKind::PostProc,
        UnitKind::IndexMem,
        UnitKind::GlobalInBuf,
        UnitKind::GlobalOutBuf,
        UnitKind::WeightBuf,
        UnitKind::LocalBuf,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            UnitKind::CimArray => "cim_array",
            UnitKind::AdderTree => "adder_tree",
            UnitKind::ShiftAdd => "shift_add",
            UnitKind::Accumulator => "accumulator",
            UnitKind::PreProc => "preproc",
            UnitKind::ZeroDetect => "zero_detect",
            UnitKind::Mux => "mux",
            UnitKind::PostProc => "postproc",
            UnitKind::IndexMem => "index_mem",
            UnitKind::GlobalInBuf => "global_in_buf",
            UnitKind::GlobalOutBuf => "global_out_buf",
            UnitKind::WeightBuf => "weight_buf",
            UnitKind::LocalBuf => "local_buf",
        }
    }
}

/// Instantiated-unit counts inferred from the architecture description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnitCounts {
    pub macros: usize,
    pub subarrays: usize,
    pub adder_trees: usize,
    pub shift_adds: usize,
    pub accumulators: usize,
    pub preproc_lanes: usize,
    pub mux_lanes: usize,
    pub postproc_lanes: usize,
}

impl UnitCounts {
    pub fn infer(arch: &Architecture) -> Self {
        let macros = arch.org.n_macros();
        let per_macro_subs = arch.cim.n_subarrays();
        Self {
            macros,
            subarrays: macros * per_macro_subs,
            // one adder tree per sub-array
            adder_trees: macros * per_macro_subs,
            // one shift-add per macro column
            shift_adds: macros * arch.cim.cols,
            // one output accumulator per macro column (plus reuse for
            // misaligned partial sums; extras are modeled as accesses)
            accumulators: macros * arch.cim.cols,
            // one pre-processing lane per macro row-group feeding inputs
            preproc_lanes: macros * arch.cim.row_groups() * arch.cim.sub_rows,
            // mux-based indexing lanes sit between preproc and rows,
            // instantiated only when weight-sparsity routing is enabled
            mux_lanes: if arch.sparsity.weight_routing {
                macros * arch.cim.rows
            } else {
                0
            },
            postproc_lanes: macros, // one post-processing unit per macro
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn counts_scale_with_org() {
        let a4 = presets::usecase_arch(4, (2, 2));
        let a16 = presets::usecase_arch(16, (4, 4));
        let c4 = UnitCounts::infer(&a4);
        let c16 = UnitCounts::infer(&a16);
        assert_eq!(c4.macros, 4);
        assert_eq!(c16.macros, 16);
        assert_eq!(c16.adder_trees, 4 * c4.adder_trees);
        assert_eq!(c16.shift_adds, 4 * c4.shift_adds);
    }

    #[test]
    fn mux_lanes_only_with_routing() {
        let mut a = presets::usecase_arch(4, (2, 2));
        a.sparsity.weight_routing = false;
        assert_eq!(UnitCounts::infer(&a).mux_lanes, 0);
        a.sparsity.weight_routing = true;
        assert!(UnitCounts::infer(&a).mux_lanes > 0);
    }

    #[test]
    fn labels_unique() {
        let mut labels: Vec<&str> = UnitKind::ALL.iter().map(|k| k.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), UnitKind::ALL.len());
    }
}
