//! Per-unit energy parameters (Sec. V-A): dynamic energy per access and
//! static power per cycle for every compute/memory unit class.
//!
//! The paper obtains these from ASIC synthesis (Design Compiler + PTPX)
//! and PCACTI; neither is available here, so `EnergyTable::preset_28nm`
//! carries values consistent with published 28 nm digital-CIM silicon
//! (anchored on Yan et al., ISSCC'22 [24]: 27.4 TOPS/W signed-int8 →
//! ≈0.073 pJ per 8-bit MAC all-in) and PCACTI-class SRAM macros. The
//! paper's own headline numbers (speedup / energy saving) are *ratios*
//! under a fixed table, so calibration offsets cancel (DESIGN.md §3).
//!
//! Units: energy pJ, time cycles (clock carried by the Architecture).

use crate::util::json::Json;

/// Dynamic + static energy of one unit class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UnitEnergy {
    /// Energy per access (pJ).
    pub dynamic_pj: f64,
    /// Static energy per cycle per instantiated unit (pJ/cycle).
    pub static_pj_cycle: f64,
}

impl UnitEnergy {
    pub const fn new(dynamic_pj: f64, static_pj_cycle: f64) -> Self {
        Self {
            dynamic_pj,
            static_pj_cycle,
        }
    }
}

/// Energy table for all unit classes of the digital CIM paradigm.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyTable {
    /// CIM array: per weight-cell (8-bit word) per active bit-cycle.
    pub cim_cell: UnitEnergy,
    /// Adder tree: per sub-array column output per cycle.
    pub adder_tree: UnitEnergy,
    /// Shift-and-add unit: per column per bit-cycle (bit-serial weighting).
    pub shift_add: UnitEnergy,
    /// Output accumulator: per partial sum folded.
    pub accumulator: UnitEnergy,
    /// Pre-processing: bit-serial conversion, per input bit.
    pub preproc_bit: UnitEnergy,
    /// Zero-bit detection (OR-gate network), per group per bit position.
    pub zero_detect: UnitEnergy,
    /// Multiplexer-based indexing unit, per input selection.
    pub mux: UnitEnergy,
    /// Post-processing unit, per element operation.
    pub postproc: UnitEnergy,
    /// Index memory, per index read/write.
    pub index_mem: UnitEnergy,
}

impl EnergyTable {
    /// 28 nm digital-CIM-class preset (see module docs).
    pub fn preset_28nm() -> Self {
        Self {
            cim_cell: UnitEnergy::new(0.0045, 0.00002),
            adder_tree: UnitEnergy::new(0.012, 0.0001),
            shift_add: UnitEnergy::new(0.008, 0.00005),
            accumulator: UnitEnergy::new(0.010, 0.00005),
            preproc_bit: UnitEnergy::new(0.008, 0.00002),
            zero_detect: UnitEnergy::new(0.0008, 0.00001),
            mux: UnitEnergy::new(0.003, 0.00001),
            postproc: UnitEnergy::new(0.08, 0.0005),
            index_mem: UnitEnergy::new(0.6, 0.001),
        }
    }

    /// JSON overlay: any field present overrides the preset — the user's
    /// "provide per-access energy for your units" interface.
    pub fn from_json_overlay(&self, j: &Json) -> anyhow::Result<EnergyTable> {
        let mut t = self.clone();
        let fields: [(&str, &mut UnitEnergy); 9] = [
            ("cim_cell", &mut t.cim_cell),
            ("adder_tree", &mut t.adder_tree),
            ("shift_add", &mut t.shift_add),
            ("accumulator", &mut t.accumulator),
            ("preproc_bit", &mut t.preproc_bit),
            ("zero_detect", &mut t.zero_detect),
            ("mux", &mut t.mux),
            ("postproc", &mut t.postproc),
            ("index_mem", &mut t.index_mem),
        ];
        for (name, slot) in fields {
            if let Some(o) = j.get(name) {
                slot.dynamic_pj = o.opt_f64("dynamic_pj", slot.dynamic_pj);
                slot.static_pj_cycle = o.opt_f64("static_pj_cycle", slot.static_pj_cycle);
                if slot.dynamic_pj < 0.0 || slot.static_pj_cycle < 0.0 {
                    anyhow::bail!("energy field `{name}` must be non-negative");
                }
            }
        }
        Ok(t)
    }
}

/// Analytical SRAM access-energy model standing in for PCACTI: pJ per
/// access of `width_bits` from a macro of `size_bytes`. Fit through
/// PCACTI-class anchor points at 28 nm:
/// 4 KB/32 b ≈ 1.6 pJ, 32 KB/64 b ≈ 6 pJ, 128 KB/64 b ≈ 13 pJ,
/// 256 KB/128 b ≈ 28 pJ. Scales ~√size (bitline/wordline growth) and
/// linearly in word width beyond sense-amp sharing.
pub fn sram_access_pj(size_bytes: usize, width_bits: usize) -> f64 {
    let kb = (size_bytes as f64 / 1024.0).max(0.25);
    let base = 0.55 * kb.sqrt() + 0.35; // array + periphery
    let width_factor = (width_bits as f64 / 64.0).max(0.25);
    base * (0.55 + 0.45 * width_factor)
}

/// Static leakage of an SRAM macro (pJ/cycle), ~linear in capacity.
pub fn sram_static_pj_cycle(size_bytes: usize) -> f64 {
    0.012 * (size_bytes as f64 / 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_is_positive() {
        let t = EnergyTable::preset_28nm();
        for e in [
            t.cim_cell,
            t.adder_tree,
            t.shift_add,
            t.accumulator,
            t.preproc_bit,
            t.zero_detect,
            t.mux,
            t.postproc,
            t.index_mem,
        ] {
            assert!(e.dynamic_pj > 0.0 && e.static_pj_cycle > 0.0);
        }
    }

    #[test]
    fn preset_mac_energy_in_silicon_range() {
        // all-in 8-bit MAC energy: 8 bit-cycles of (cell + tree/64-share +
        // shift-add/64-share) should land near published 0.05–0.15 pJ/MAC
        let t = EnergyTable::preset_28nm();
        let per_mac = 8.0 * (t.cim_cell.dynamic_pj + t.adder_tree.dynamic_pj / 64.0 + t.shift_add.dynamic_pj / 64.0);
        assert!(
            (0.02..0.2).contains(&per_mac),
            "per-MAC {per_mac} pJ out of digital-CIM silicon range"
        );
    }

    #[test]
    fn sram_model_monotone_in_size_and_width() {
        let a = sram_access_pj(4 * 1024, 32);
        let b = sram_access_pj(128 * 1024, 32);
        let c = sram_access_pj(128 * 1024, 128);
        assert!(a < b && b < c, "{a} {b} {c}");
        // anchor sanity: 128 KB / 64 b within 2x of 13 pJ
        let anchor = sram_access_pj(128 * 1024, 64);
        assert!((6.0..26.0).contains(&anchor), "{anchor}");
    }

    #[test]
    fn json_overlay_overrides() {
        let t = EnergyTable::preset_28nm();
        let j = Json::parse(r#"{"mux": {"dynamic_pj": 0.5}}"#).unwrap();
        let t2 = t.from_json_overlay(&j).unwrap();
        assert_eq!(t2.mux.dynamic_pj, 0.5);
        assert_eq!(t2.mux.static_pj_cycle, t.mux.static_pj_cycle);
        assert_eq!(t2.cim_cell, t.cim_cell);
        let bad = Json::parse(r#"{"mux": {"dynamic_pj": -1}}"#).unwrap();
        assert!(t.from_json_overlay(&bad).is_err());
    }
}
