//! CIM macro geometry (Sec. II-A, Fig. 1(a)): a digital macro is an
//! array of SRAM weight cells partitioned into sub-arrays, each with its
//! own adder tree; shift-add units weight the bit-serial partial sums and
//! accumulators fold across sub-arrays and temporal rounds.
//!
//! Digital CIM activates *all rows simultaneously* — the property that
//! both enables full parallelism and imposes the paper's structural
//! constraints on sparsity (Sec. III-A).

/// Geometry of one CIM macro. Dimensions count 8-bit weight *words*
/// (each word is `weight_bits` physical bitcells along the column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CimMacro {
    /// Array rows (weight-matrix rows mapped here; inputs broadcast).
    pub rows: usize,
    /// Array columns (output channels; partial sums accumulate here).
    pub cols: usize,
    /// Sub-array rows (zero-skip / adder-tree granularity).
    pub sub_rows: usize,
    /// Sub-array columns.
    pub sub_cols: usize,
}

impl CimMacro {
    pub fn new(rows: usize, cols: usize, sub_rows: usize, sub_cols: usize) -> Self {
        Self {
            rows,
            cols,
            sub_rows,
            sub_cols,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.rows == 0 || self.cols == 0 || self.sub_rows == 0 || self.sub_cols == 0 {
            anyhow::bail!("macro dims must be positive: {self:?}");
        }
        if self.rows % self.sub_rows != 0 || self.cols % self.sub_cols != 0 {
            anyhow::bail!(
                "sub-array {}x{} must tile macro {}x{}",
                self.sub_rows,
                self.sub_cols,
                self.rows,
                self.cols
            );
        }
        Ok(())
    }

    /// Sub-arrays per macro (one adder tree each).
    pub fn n_subarrays(&self) -> usize {
        (self.rows / self.sub_rows) * (self.cols / self.sub_cols)
    }

    /// Sub-array row groups: the granularity at which input-sparsity
    /// zero-bit skipping applies (all inputs of a group must be zero at a
    /// bit position to skip its cycle — Sec. III-B).
    pub fn row_groups(&self) -> usize {
        self.rows / self.sub_rows
    }

    /// Weight words stored per macro.
    pub fn capacity_words(&self) -> usize {
        self.rows * self.cols
    }

    /// Weight storage in bytes for `weight_bits`-wide words.
    pub fn capacity_bytes(&self, weight_bits: usize) -> usize {
        self.capacity_words() * weight_bits / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mars_macro_geometry() {
        // MARS: 1024×64 macro, 64×64 sub-arrays (Table I)
        let m = CimMacro::new(1024, 64, 64, 64);
        m.validate().unwrap();
        assert_eq!(m.n_subarrays(), 16);
        assert_eq!(m.row_groups(), 16);
        assert_eq!(m.capacity_bytes(8), 64 * 1024);
    }

    #[test]
    fn sdp_macro_geometry() {
        // SDP: 32×64 macro, 1×64 sub-arrays (Table I)
        let m = CimMacro::new(32, 64, 1, 64);
        m.validate().unwrap();
        assert_eq!(m.n_subarrays(), 32);
        assert_eq!(m.row_groups(), 32);
    }

    #[test]
    fn invalid_tiling_rejected() {
        assert!(CimMacro::new(100, 64, 64, 64).validate().is_err());
        assert!(CimMacro::new(0, 64, 1, 64).validate().is_err());
    }
}
