//! PJRT runtime wrapper: loads AOT HLO-text artifacts and executes them
//! on the CPU PJRT client via the `xla` crate. This is the only bridge
//! between the rust coordinator and the (build-time-only) Python world.
//!
//! The `xla` dependency is optional: without the `pjrt` cargo feature
//! (the default), this module compiles to a stub whose constructors
//! report the runtime as unavailable. Everything that does not need live
//! inference — planning, simulation, exploration, fault studies — works
//! identically either way; only `Runtime::cpu()` callers see the error.

use anyhow::Result;
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::Context;

/// A host-side tensor argument: flat f32 data + dims.
#[derive(Debug, Clone)]
pub struct ArrayArg {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl ArrayArg {
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> Result<Self> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(
            n as usize == data.len(),
            "ArrayArg: {} elements vs dims {:?}",
            data.len(),
            dims
        );
        Ok(Self { data, dims })
    }
}

/// Wrapper over the PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo(&self, path: &Path) -> Result<LoadedExec> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedExec {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled executable ready to run.
#[cfg(feature = "pjrt")]
pub struct LoadedExec {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

#[cfg(feature = "pjrt")]
impl LoadedExec {
    /// Execute with f32 array inputs; returns all tuple outputs as flat
    /// f32 vectors (artifacts are lowered with return_tuple=True).
    pub fn run_f32(&self, inputs: &[ArrayArg]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for a in inputs {
            literals.push(
                xla::Literal::vec1(&a.data)
                    .reshape(&a.dims)
                    .with_context(|| format!("reshaping input to {:?}", a.dims))?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// Stub runtime compiled when the `pjrt` feature is off: construction
/// fails with a clear message instead of a missing-symbol build error.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        anyhow::bail!(
            "PJRT runtime unavailable: ciminus was built without the `pjrt` \
             feature (rebuild with `cargo build --features pjrt`)"
        )
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn load_hlo(&self, path: &Path) -> Result<LoadedExec> {
        anyhow::bail!(
            "cannot load `{}`: ciminus was built without the `pjrt` feature",
            path.display()
        )
    }
}

/// Stub executable handle matching the `pjrt` API surface.
#[cfg(not(feature = "pjrt"))]
pub struct LoadedExec {
    pub name: String,
}

#[cfg(not(feature = "pjrt"))]
impl LoadedExec {
    pub fn run_f32(&self, _inputs: &[ArrayArg]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!(
            "cannot execute `{}`: ciminus was built without the `pjrt` feature",
            self.name
        )
    }
}

#[cfg(test)]
mod tests {
    // PJRT execution is covered by rust/tests/integration_runtime.rs,
    // which gates on built artifacts; unit tests here only cover the
    // host-side argument plumbing.
    use super::*;

    #[test]
    fn array_arg_validates_dims() {
        assert!(ArrayArg::new(vec![0.0; 6], vec![2, 3]).is_ok());
        assert!(ArrayArg::new(vec![0.0; 5], vec![2, 3]).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
