//! Pre-simulation analysis via PJRT (Sec. IV-B): pruned-model accuracy
//! evaluation and input-activation profiling, run entirely from rust
//! against the AOT artifacts — Python is never on this path.

use super::artifacts::{Artifacts, ModelArtifacts};
use super::client::{ArrayArg, LoadedExec, Runtime};
use crate::pruning::criterion::WeightMatrix;
use crate::pruning::workflow::{PrunePlan, PruningWorkflow};
use crate::sim::input_sparsity::{ActivationProfile, InputProfiles};
use crate::sparsity::flexblock::FlexBlock;
use crate::util::bits::BitMatrix;
use crate::workload::graph::Network;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// A loaded model: compiled executables + datasets, reusable across many
/// pruning configurations (compilation is the expensive part).
pub struct ModelSession<'a> {
    pub arts: &'a Artifacts,
    pub ma: &'a ModelArtifacts,
    fwd: LoadedExec,
    acts: LoadedExec,
    eval_images: Vec<f32>,
    eval_labels: Vec<i32>,
    calib_images: Vec<f32>,
}

/// Result of pruning + accuracy evaluation for one configuration.
#[derive(Debug, Clone)]
pub struct PruneEval {
    pub accuracy: f64,
    pub dense_accuracy: f64,
    pub weight_sparsity: f64,
    /// Masks keyed by op name (for the simulator's mapping stage).
    pub masks_by_name: BTreeMap<String, BitMatrix>,
    pub plan: PrunePlan,
}

impl<'a> ModelSession<'a> {
    pub fn new(rt: &Runtime, arts: &'a Artifacts, model: &str) -> Result<ModelSession<'a>> {
        let ma = arts.model(model)?;
        let fwd = rt
            .load_hlo(&ma.fwd_hlo)
            .with_context(|| format!("loading fwd HLO for {model}"))?;
        let acts = rt
            .load_hlo(&ma.acts_hlo)
            .with_context(|| format!("loading acts HLO for {model}"))?;
        let (eval_images, eval_labels) = arts.eval_set()?;
        let calib_images = arts.calib_set()?;
        Ok(ModelSession {
            arts,
            ma,
            fwd,
            acts,
            eval_images,
            eval_labels,
            calib_images,
        })
    }

    /// Top-1 accuracy of the model with the given weights blob over the
    /// eval split (batched at the artifact's fwd batch size).
    pub fn eval_blob(&self, blob: &[f32]) -> Result<f64> {
        let b = self.arts.fwd_batch;
        let img_elems = self.arts.img * self.arts.img * 3;
        let n = self.arts.eval_n;
        anyhow::ensure!(n % b == 0, "eval_n {n} not a multiple of batch {b}");
        let weight_args = self.ma.args_from_blob(blob)?;
        let mut correct = 0usize;
        for batch_i in 0..n / b {
            let lo = batch_i * b * img_elems;
            let hi = lo + b * img_elems;
            let mut args = weight_args.clone();
            args.push(ArrayArg::new(
                self.eval_images[lo..hi].to_vec(),
                vec![b as i64, self.arts.img as i64, self.arts.img as i64, 3],
            )?);
            let outs = self.fwd.run_f32(&args)?;
            let logits = &outs[0];
            let c = self.arts.classes;
            for i in 0..b {
                let row = &logits[i * c..(i + 1) * c];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap();
                if pred as i32 == self.eval_labels[batch_i * b + i] {
                    correct += 1;
                }
            }
        }
        Ok(correct as f64 / n as f64)
    }

    /// Profile per-MVM-op input activations on the calibration batch,
    /// returning quantized bit-plane profiles keyed by op name.
    pub fn profile_activations(
        &self,
        blob: &[f32],
        bits: usize,
    ) -> Result<BTreeMap<String, ActivationProfile>> {
        let b = self.arts.acts_batch;
        let img_elems = self.arts.img * self.arts.img * 3;
        let mut args = self.ma.args_from_blob(blob)?;
        args.push(ArrayArg::new(
            self.calib_images[..b * img_elems].to_vec(),
            vec![b as i64, self.arts.img as i64, self.arts.img as i64, 3],
        )?);
        let outs = self.acts.run_f32(&args)?;
        // output 0 is the logits (kept to prevent XLA from pruning the
        // classifier parameters); taps follow in manifest order
        anyhow::ensure!(
            outs.len() == self.ma.taps.len() + 1,
            "acts returned {} outputs for {} taps (+logits)",
            outs.len(),
            self.ma.taps.len()
        );
        let mut profiles = BTreeMap::new();
        for (tap, values) in self.ma.taps.iter().zip(outs.iter().skip(1)) {
            profiles.insert(tap.clone(), ActivationProfile::from_values(values, bits));
        }
        Ok(profiles)
    }

    /// Run the pruning workflow with importance selection against the
    /// artifact weights, evaluate the pruned model, and return everything
    /// the simulator needs.
    pub fn prune_and_eval(
        &self,
        net: &Network,
        fb: &FlexBlock,
        wf: &PruningWorkflow,
    ) -> Result<PruneEval> {
        let weights_by_name = self.ma.weight_matrices()?;
        let weights_by_id = weights_by_id(net, &weights_by_name)?;
        let plan = wf.run_uniform(net, fb, Some(&weights_by_id))?;
        let mut masks_by_name = BTreeMap::new();
        for (&id, lp) in &plan.layers {
            masks_by_name.insert(net.ops[id].name.clone(), lp.mask.clone());
        }
        let blob = self.ma.masked_blob(&masks_by_name)?;
        let accuracy = self.eval_blob(&blob)?;
        Ok(PruneEval {
            accuracy,
            dense_accuracy: self.ma.dense_eval_acc,
            weight_sparsity: plan.overall_sparsity(),
            masks_by_name,
            plan,
        })
    }
}

/// Re-key artifact weight matrices from op names to the network's op ids.
pub fn weights_by_id(
    net: &Network,
    by_name: &BTreeMap<String, WeightMatrix>,
) -> Result<BTreeMap<usize, WeightMatrix>> {
    let mut out = BTreeMap::new();
    for (name, w) in by_name {
        let op = net
            .ops
            .iter()
            .find(|o| &o.name == name)
            .ok_or_else(|| anyhow::anyhow!("artifact param `{name}` has no graph op"))?;
        out.insert(op.id, w.clone());
    }
    Ok(out)
}

/// Convert name-keyed activation profiles into the simulator's id-keyed
/// [`InputProfiles`].
pub fn input_profiles_for(
    net: &Network,
    by_name: &BTreeMap<String, ActivationProfile>,
) -> InputProfiles {
    let mut per_layer = BTreeMap::new();
    for (name, p) in by_name {
        if let Some(op) = net.ops.iter().find(|o| &o.name == name) {
            per_layer.insert(op.id, p.clone());
        }
    }
    InputProfiles {
        per_layer,
        fallback: by_name.values().next().cloned(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn weights_by_id_rejects_unknown_names() {
        let net = zoo::resnet_mini();
        let mut by_name = BTreeMap::new();
        by_name.insert(
            "not_a_layer".to_string(),
            WeightMatrix::new(1, 2, vec![0.0, 0.0]).unwrap(),
        );
        assert!(weights_by_id(&net, &by_name).is_err());
    }

    #[test]
    fn profiles_rekey_by_op_id() {
        let net = zoo::resnet_mini();
        let mut by_name = BTreeMap::new();
        by_name.insert("stem".to_string(), ActivationProfile::dense(8));
        let p = input_profiles_for(&net, &by_name);
        let stem_id = net.ops.iter().find(|o| o.name == "stem").unwrap().id;
        assert!(p.per_layer.contains_key(&stem_id));
        assert!(p.fallback.is_some());
    }
}
