//! Runtime bridge (L3 ↔ AOT artifacts): PJRT client wrapper, artifact
//! manifest loading, and the pre-simulation analyses (accuracy
//! evaluation, activation profiling) of Sec. IV-B.

pub mod artifacts;
pub mod client;
pub mod infer;

pub use artifacts::{Artifacts, ModelArtifacts, ParamInfo};
pub use client::{ArrayArg, LoadedExec, Runtime};
pub use infer::{input_profiles_for, weights_by_id, ModelSession, PruneEval};
