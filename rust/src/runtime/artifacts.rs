//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime (weights blobs, HLO files, dataset splits).

use crate::pruning::criterion::WeightMatrix;
use crate::util::bits::BitMatrix;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One MVM op's parameter layout inside the weights blob.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamInfo {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub groups: usize,
    pub w_offset: usize,
    pub b_offset: usize,
}

/// One model's artifacts.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub name: String,
    pub params: Vec<ParamInfo>,
    /// The full weights blob (w/b interleaved per `params`).
    pub blob: Vec<f32>,
    pub fwd_hlo: PathBuf,
    pub acts_hlo: PathBuf,
    pub graph_json: PathBuf,
    pub dense_eval_acc: f64,
    pub taps: Vec<String>,
}

impl ModelArtifacts {
    /// Extract the reshaped 2-D weight matrices keyed by op name.
    pub fn weight_matrices(&self) -> Result<BTreeMap<String, WeightMatrix>> {
        let mut out = BTreeMap::new();
        for p in &self.params {
            let n = p.rows * p.cols;
            anyhow::ensure!(
                p.w_offset + n <= self.blob.len(),
                "param `{}` out of blob bounds",
                p.name
            );
            out.insert(
                p.name.clone(),
                WeightMatrix::new(
                    p.rows,
                    p.cols,
                    self.blob[p.w_offset..p.w_offset + n].to_vec(),
                )?,
            );
        }
        Ok(out)
    }

    /// Produce a blob copy with pruning masks applied (masks keyed by op
    /// name; ops absent stay dense). Biases untouched.
    pub fn masked_blob(&self, masks: &BTreeMap<String, BitMatrix>) -> Result<Vec<f32>> {
        let mut blob = self.blob.clone();
        for p in &self.params {
            if let Some(mask) = masks.get(&p.name) {
                anyhow::ensure!(
                    mask.rows() == p.rows && mask.cols() == p.cols,
                    "mask for `{}` is {}x{}, param is {}x{}",
                    p.name,
                    mask.rows(),
                    mask.cols(),
                    p.rows,
                    p.cols
                );
                for r in 0..p.rows {
                    for c in 0..p.cols {
                        if !mask.get(r, c) {
                            blob[p.w_offset + r * p.cols + c] = 0.0;
                        }
                    }
                }
            }
        }
        Ok(blob)
    }

    /// Assemble the flat HLO argument list (w, b per param in order) from
    /// a blob, ready to append the image batch.
    pub fn args_from_blob(
        &self,
        blob: &[f32],
    ) -> Result<Vec<crate::runtime::client::ArrayArg>> {
        use crate::runtime::client::ArrayArg;
        let mut args = Vec::with_capacity(self.params.len() * 2 + 1);
        for p in &self.params {
            let n = p.rows * p.cols;
            args.push(ArrayArg::new(
                blob[p.w_offset..p.w_offset + n].to_vec(),
                vec![p.rows as i64, p.cols as i64],
            )?);
            args.push(ArrayArg::new(
                blob[p.b_offset..p.b_offset + p.cols].to_vec(),
                vec![p.cols as i64],
            )?);
        }
        Ok(args)
    }
}

/// The whole artifacts directory.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub img: usize,
    pub classes: usize,
    pub fwd_batch: usize,
    pub acts_batch: usize,
    pub eval_n: usize,
    pub models: BTreeMap<String, ModelArtifacts>,
}

fn read_f32_bin(path: &Path) -> Result<Vec<f32>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{}: not f32-aligned", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_i32_bin(path: &Path) -> Result<Vec<i32>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{}: not i32-aligned", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl Artifacts {
    /// Default artifacts directory: `$CIMINUS_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("CIMINUS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// True if the manifest exists (used to gate integration tests).
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").exists()
    }

    pub fn load(dir: &Path) -> Result<Artifacts> {
        let manifest = Json::parse_file(&dir.join("manifest.json"))?;
        let mut models = BTreeMap::new();
        let models_j = manifest
            .get("models")
            .and_then(|m| m.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing `models`"))?;
        for (name, mj) in models_j {
            let mut params = Vec::new();
            for pj in mj.req_arr("params")? {
                params.push(ParamInfo {
                    name: pj.req_str("name")?.to_string(),
                    rows: pj.req_usize("rows")?,
                    cols: pj.req_usize("cols")?,
                    groups: pj.opt_usize("groups", 1),
                    w_offset: pj.req_usize("w_offset")?,
                    b_offset: pj.req_usize("b_offset")?,
                });
            }
            let blob = read_f32_bin(&dir.join(mj.req_str("weights_bin")?))?;
            anyhow::ensure!(
                blob.len() == mj.req_usize("total_floats")?,
                "model `{name}`: blob length mismatch"
            );
            models.insert(
                name.clone(),
                ModelArtifacts {
                    name: name.clone(),
                    params,
                    blob,
                    fwd_hlo: dir.join(mj.req_str("fwd_hlo")?),
                    acts_hlo: dir.join(mj.req_str("acts_hlo")?),
                    graph_json: dir.join(mj.req_str("graph_json")?),
                    dense_eval_acc: mj.req_f64("dense_eval_acc")?,
                    taps: mj
                        .req_arr("taps")?
                        .iter()
                        .map(|t| {
                            t.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| anyhow::anyhow!("bad tap name"))
                        })
                        .collect::<Result<_>>()?,
                },
            );
        }
        Ok(Artifacts {
            dir: dir.to_path_buf(),
            img: manifest.req_usize("img")?,
            classes: manifest.req_usize("classes")?,
            fwd_batch: manifest.req_usize("fwd_batch")?,
            acts_batch: manifest.req_usize("acts_batch")?,
            eval_n: manifest.req_usize("eval_n")?,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model `{name}` not in artifacts"))
    }

    /// Eval images as NHWC f32 (flat) + labels.
    pub fn eval_set(&self) -> Result<(Vec<f32>, Vec<i32>)> {
        Ok((
            read_f32_bin(&self.dir.join("eval_images.bin"))?,
            read_i32_bin(&self.dir.join("eval_labels.bin"))?,
        ))
    }

    /// Calibration images for activation profiling.
    pub fn calib_set(&self) -> Result<Vec<f32>> {
        read_f32_bin(&self.dir.join("calib_images.bin"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bin_roundtrip() {
        let tmp = std::env::temp_dir().join("ciminus_test_f32.bin");
        let data: Vec<f32> = vec![1.5, -2.25, 0.0, 3.0e7];
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&tmp, bytes).unwrap();
        assert_eq!(read_f32_bin(&tmp).unwrap(), data);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn masked_blob_zeroes_only_masked_weights() {
        let ma = ModelArtifacts {
            name: "t".into(),
            params: vec![ParamInfo {
                name: "fc".into(),
                rows: 2,
                cols: 2,
                groups: 1,
                w_offset: 0,
                b_offset: 4,
            }],
            blob: vec![1.0, 2.0, 3.0, 4.0, 9.0, 9.0],
            fwd_hlo: PathBuf::new(),
            acts_hlo: PathBuf::new(),
            graph_json: PathBuf::new(),
            dense_eval_acc: 1.0,
            taps: vec!["fc".into()],
        };
        let mut mask = BitMatrix::ones(2, 2);
        mask.set(0, 1, false);
        let mut masks = BTreeMap::new();
        masks.insert("fc".to_string(), mask);
        let blob = ma.masked_blob(&masks).unwrap();
        assert_eq!(blob, vec![1.0, 0.0, 3.0, 4.0, 9.0, 9.0]);
        // dims mismatch is rejected
        let mut bad = BTreeMap::new();
        bad.insert("fc".to_string(), BitMatrix::ones(3, 2));
        assert!(ma.masked_blob(&bad).is_err());
    }

    #[test]
    fn weight_matrices_extracted() {
        let ma = ModelArtifacts {
            name: "t".into(),
            params: vec![ParamInfo {
                name: "fc".into(),
                rows: 2,
                cols: 3,
                groups: 1,
                w_offset: 0,
                b_offset: 6,
            }],
            blob: vec![1., 2., 3., 4., 5., 6., 0., 0., 0.],
            fwd_hlo: PathBuf::new(),
            acts_hlo: PathBuf::new(),
            graph_json: PathBuf::new(),
            dense_eval_acc: 1.0,
            taps: vec![],
        };
        let ws = ma.weight_matrices().unwrap();
        assert_eq!(ws["fc"].get(1, 2), 6.0);
    }
}
