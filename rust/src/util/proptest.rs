//! Minimal property-based testing harness (no `proptest` crate offline).
//!
//! A property is a closure over a [`Gen`] (seeded PCG wrapper with
//! convenience samplers). [`check`] runs it for `cases` random cases and,
//! on failure, re-runs with the failing seed reported so the case can be
//! reproduced by `check_seed`. Coordinator invariants (routing, batching,
//! mask algebra, mapping legality) are property-tested with this.

use super::rng::Pcg32;

/// Random-input generator handed to properties.
pub struct Gen {
    pub rng: Pcg32,
    pub case: usize,
}

impl Gen {
    /// usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.index(hi - lo + 1)
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.index(xs.len())]
    }

    /// Biased coin.
    pub fn bool_with(&mut self, p_true: f64) -> bool {
        self.rng.next_f64() < p_true
    }

    /// A divisor of `n` chosen uniformly among all divisors.
    pub fn divisor_of(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let divs: Vec<usize> = (1..=n).filter(|d| n % d == 0).collect();
        *self.choose(&divs)
    }

    /// Vector of f32 weights with controllable magnitude spread.
    pub fn weights(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| (self.rng.next_f32() - 0.5) * 4.0).collect()
    }

    /// Printable-ASCII string of length ≤ `max_len` (includes `"` and
    /// `\`, so it exercises escaping paths).
    pub fn ascii_string(&mut self, max_len: usize) -> String {
        let n = self.usize_in(0, max_len);
        (0..n)
            .map(|_| self.usize_in(0x20, 0x7E) as u8 as char)
            .collect()
    }

    /// String of length ≤ `max_len` drawn uniformly from `charset`.
    pub fn string_from(&mut self, charset: &str, max_len: usize) -> String {
        let chars: Vec<char> = charset.chars().collect();
        let n = self.usize_in(0, max_len);
        (0..n).map(|_| *self.choose(&chars)).collect()
    }

    /// Random prefix of `s`, cut at a char boundary (possibly empty or
    /// the whole string) — the truncated-input fuzz primitive.
    pub fn prefix_of(&mut self, s: &str) -> String {
        let mut cut = self.usize_in(0, s.len());
        while !s.is_char_boundary(cut) {
            cut -= 1;
        }
        s[..cut].to_string()
    }
}

/// Outcome of a property: Ok(()) or an explanation of the violation.
pub type PropResult = Result<(), String>;

/// Run `prop` for `cases` random cases derived from `seed`.
///
/// Panics (test failure) with the case index and per-case seed on the
/// first violation.
pub fn check<F: FnMut(&mut Gen) -> PropResult>(name: &str, cases: usize, seed: u64, mut prop: F) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut g = Gen {
            rng: Pcg32::new(case_seed),
            case,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property `{name}` violated at case {case}/{cases} \
                 (reproduce with check_seed(\"{name}\", {case_seed}u64, ..)): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn check_seed<F: FnMut(&mut Gen) -> PropResult>(name: &str, case_seed: u64, mut prop: F) {
    let mut g = Gen {
        rng: Pcg32::new(case_seed),
        case: 0,
    };
    if let Err(msg) = prop(&mut g) {
        panic!("property `{name}` violated for seed {case_seed}: {msg}");
    }
}

/// Assertion helpers returning PropResult.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, ctx: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{ctx}: |{a} - {b}| > tol {tol}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add_commutes", 200, 1, |g| {
            let a = g.usize_in(0, 1000);
            let b = g.usize_in(0, 1000);
            ensure_eq(a + b, b + a, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "property `always_fails` violated")]
    fn failing_property_panics_with_seed() {
        check("always_fails", 10, 2, |_| Err("nope".into()));
    }

    #[test]
    fn divisor_of_divides() {
        check("divisor", 100, 3, |g| {
            let n = g.usize_in(1, 500);
            let d = g.divisor_of(n);
            ensure(n % d == 0, format!("{d} does not divide {n}"))
        });
    }

    #[test]
    fn string_generators_respect_bounds() {
        check("strings", 200, 7, |g| {
            let s = g.ascii_string(16);
            ensure(s.len() <= 16 && s.chars().all(|c| (' '..='~').contains(&c)), "ascii")?;
            let t = g.string_from("ab", 8);
            ensure(t.chars().all(|c| c == 'a' || c == 'b'), "charset")?;
            let src = "héllo wörld";
            let p = g.prefix_of(src);
            ensure(src.starts_with(&p), format!("`{p}` not a prefix"))
        });
    }

    #[test]
    fn ensure_close_tolerance() {
        assert!(ensure_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-9, "x").is_err());
    }

    #[test]
    fn gen_ranges() {
        check("ranges", 300, 4, |g| {
            let v = g.usize_in(3, 7);
            ensure(v >= 3 && v <= 7, format!("{v} out of [3,7]"))?;
            let f = g.f64_in(-1.0, 1.0);
            ensure((-1.0..1.0).contains(&f), format!("{f} out of [-1,1)"))
        });
    }
}
