//! Small statistics helpers for experiment reporting: the Fig. 6(a)
//! correlation plot needs Pearson r over reported-vs-estimated pairs,
//! and sweep summaries use the descriptive stats.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (sorted copy; NaNs not supported).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Pearson correlation coefficient of paired samples.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson needs paired samples");
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx).powi(2);
        dy += (y - my).powi(2);
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Least-squares slope+intercept of y on x.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx).powi(2);
    }
    let slope = if den == 0.0 { 0.0 } else { num / den };
    (slope, my - slope * mx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_descriptives() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((stddev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let inv = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &inv) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
    }

    #[test]
    fn fit_recovers_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 1.0).collect();
        let (m, b) = linear_fit(&xs, &ys);
        assert!((m - 3.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
    }
}
