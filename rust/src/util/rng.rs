//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, so CIMinus carries its own
//! small, well-tested generators: [`SplitMix64`] for seeding and
//! [`Pcg32`] (PCG-XSH-RR 64/32) as the workhorse stream used by mask
//! generation, synthetic workloads and the property-test harness.
//! Determinism matters here: every experiment in EXPERIMENTS.md is
//! reproducible from its seed.

/// SplitMix64: tiny, full-period 2^64 generator. Used to expand a user
/// seed into independent PCG streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32. Small state, excellent statistical quality, and —
/// unlike xorshift — no all-zero fixed point to worry about.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub const DEFAULT_STREAM: u64 = 0xda3e_39cb_94b9_5bdb;

    /// Construct from a seed; the stream id is fixed.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, Self::DEFAULT_STREAM)
    }

    /// Construct with an explicit stream (sequence) id. Distinct streams
    /// are statistically independent for the same seed.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let initstate = sm.next_u64();
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        let _ = rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        let _ = rng.next_u32();
        rng
    }

    /// Derive a child generator; used to give each experiment axis its own
    /// independent stream so adding a sweep point never perturbs others.
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg32::with_stream(seed, Self::DEFAULT_STREAM ^ tag)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's rejection method (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "next_below(0)");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64).wrapping_mul(bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[0, bound)`; bound must fit in u32 (all CIMinus
    /// index spaces do).
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        assert!(bound <= u32::MAX as usize);
        self.next_below(bound as u32) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast
    /// here, this is not on the simulation hot path).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (computed from the canonical
        // SplitMix64 algorithm).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn pcg_deterministic_and_distinct_streams() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        let xs: Vec<u32> = (0..16).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..16).map(|_| b.next_u32()).collect();
        assert_eq!(xs, ys);
        let mut c = Pcg32::with_stream(42, 7);
        let zs: Vec<u32> = (0..16).map(|_| c.next_u32()).collect();
        assert_ne!(xs, zs);
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut rng = Pcg32::new(99);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.next_normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffled");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::new(11);
        for _ in 0..50 {
            let k = rng.index(20);
            let s = rng.sample_indices(20, k);
            assert_eq!(s.len(), k);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), k, "indices distinct");
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn fork_produces_independent_streams() {
        let mut root = Pcg32::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(xs, ys);
    }
}
