//! Tiny benchmark harness (no criterion in the offline registry).
//!
//! Provides warm-up + repeated timed runs with mean / stddev / min
//! statistics and criterion-style output lines, used by every
//! `rust/benches/bench_*.rs` target (declared with `harness = false`).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn report_line(&self) -> String {
        format!(
            "bench {:<48} iters={:<4} mean={:>12?} min={:>12?} max={:>12?} stddev={:>10?}",
            self.name, self.iters, self.mean, self.min, self.max, self.stddev
        )
    }
}

/// Benchmark runner with a time budget: runs at least `min_iters`, at most
/// `max_iters`, stopping early once `budget` has elapsed.
pub struct Bencher {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: 1,
            min_iters: 3,
            max_iters: 30,
            budget: Duration::from_secs(5),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: 1,
            min_iters: 2,
            max_iters: 10,
            budget: Duration::from_secs(2),
        }
    }

    /// Time `f`, which must return some value to defeat dead-code
    /// elimination; the values are black-boxed.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        stats_from(name, &samples)
    }
}

fn stats_from(name: &str, samples: &[Duration]) -> BenchStats {
    let n = samples.len() as f64;
    let mean_s = samples.iter().map(|d| d.as_secs_f64()).sum::<f64>() / n;
    let var = samples
        .iter()
        .map(|d| (d.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / n;
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean: Duration::from_secs_f64(mean_s),
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: *samples.iter().min().unwrap(),
        max: *samples.iter().max().unwrap(),
    }
}

/// Opaque value sink (std::hint::black_box wrapper kept behind our own
/// name so benches don't import std::hint everywhere).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Standard header printed by every bench binary.
pub fn bench_header(what: &str) {
    println!("=== CIMinus bench: {what} ===");
    println!(
        "host: {} cores, release={}",
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        !cfg!(debug_assertions)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_at_least_min_iters() {
        let b = Bencher {
            warmup: 0,
            min_iters: 4,
            max_iters: 8,
            budget: Duration::from_millis(1),
        };
        let mut count = 0usize;
        let stats = b.run("t", || {
            count += 1;
            count
        });
        assert!(stats.iters >= 4);
        assert!(count >= 4);
        assert!(stats.min <= stats.mean && stats.mean <= stats.max + Duration::from_nanos(1));
    }

    #[test]
    fn respects_max_iters() {
        let b = Bencher {
            warmup: 0,
            min_iters: 1,
            max_iters: 3,
            budget: Duration::from_secs(60),
        };
        let stats = b.run("t", || 1 + 1);
        assert!(stats.iters <= 3);
    }

    #[test]
    fn report_line_contains_name() {
        let b = Bencher::quick();
        let s = b.run("my_bench", || 42);
        assert!(s.report_line().contains("my_bench"));
    }
}
