//! Compact bit containers used by sparsity masks and the bit-serial
//! input-sparsity model. A dense 2-D `u64`-backed bitmap is the storage
//! for FlexBlock masks: ResNet50's largest reshaped weight matrix is
//! 4608×512 ≈ 2.4 M bits ≈ 295 KB, so whole-model mask sets stay small.

/// Fixed-size bit vector backed by `u64` words.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitVec {
    len: usize,
    words: Vec<u64>,
}

impl BitVec {
    pub fn zeros(len: usize) -> Self {
        Self {
            len,
            words: vec![0; len.div_ceil(64)],
        }
    }

    pub fn ones(len: usize) -> Self {
        let mut v = Self {
            len,
            words: vec![u64::MAX; len.div_ceil(64)],
        };
        v.clear_tail();
        v
    }

    fn clear_tail(&mut self) {
        let tail = self.len % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i >> 6];
        if v {
            *w |= 1u64 << (i & 63);
        } else {
            *w &= !(1u64 << (i & 63));
        }
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// In-place OR with another vector of the same length.
    pub fn or_assign(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// In-place AND.
    pub fn and_assign(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// Set every bit in `[lo, hi)` to `v` (word-level).
    pub fn set_range(&mut self, lo: usize, hi: usize, v: bool) {
        debug_assert!(lo <= hi && hi <= self.len);
        if lo >= hi {
            return;
        }
        let (wl, bl) = (lo >> 6, lo & 63);
        let (wh, bh) = (hi >> 6, hi & 63);
        let head_mask = u64::MAX << bl;
        let tail_mask = if bh == 0 { 0 } else { u64::MAX >> (64 - bh) };
        if wl == wh {
            let m = head_mask & tail_mask;
            if v {
                self.words[wl] |= m;
            } else {
                self.words[wl] &= !m;
            }
            return;
        }
        if v {
            self.words[wl] |= head_mask;
            for w in &mut self.words[wl + 1..wh] {
                *w = u64::MAX;
            }
            if bh != 0 {
                self.words[wh] |= tail_mask;
            }
        } else {
            self.words[wl] &= !head_mask;
            for w in &mut self.words[wl + 1..wh] {
                *w = 0;
            }
            if bh != 0 {
                self.words[wh] &= !tail_mask;
            }
        }
    }

    /// Count set bits in `[lo, hi)` (word-level).
    pub fn count_range(&self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi && hi <= self.len);
        if lo >= hi {
            return 0;
        }
        let (wl, bl) = (lo >> 6, lo & 63);
        let (wh, bh) = (hi >> 6, hi & 63);
        let head_mask = u64::MAX << bl;
        let tail_mask = if bh == 0 { 0 } else { u64::MAX >> (64 - bh) };
        if wl == wh {
            return (self.words[wl] & head_mask & tail_mask).count_ones() as usize;
        }
        let mut n = (self.words[wl] & head_mask).count_ones() as usize;
        for w in &self.words[wl + 1..wh] {
            n += w.count_ones() as usize;
        }
        if bh != 0 {
            n += (self.words[wh] & tail_mask).count_ones() as usize;
        }
        n
    }

    /// Any set bit in `[lo, hi)`?
    pub fn any_range(&self, lo: usize, hi: usize) -> bool {
        debug_assert!(lo <= hi && hi <= self.len);
        if lo >= hi {
            return false;
        }
        let (wl, bl) = (lo >> 6, lo & 63);
        let (wh, bh) = (hi >> 6, hi & 63);
        let head_mask = u64::MAX << bl;
        let tail_mask = if bh == 0 { 0 } else { u64::MAX >> (64 - bh) };
        if wl == wh {
            return self.words[wl] & head_mask & tail_mask != 0;
        }
        if self.words[wl] & head_mask != 0 {
            return true;
        }
        if self.words[wl + 1..wh].iter().any(|&w| w != 0) {
            return true;
        }
        bh != 0 && self.words[wh] & tail_mask != 0
    }

    /// The backing `u64` words (tail bits beyond `len` are zero). Raw
    /// view used by the disk-cache serializer (`eval::serial`).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild a vector from its raw parts, validating the word count
    /// and that no bit beyond `len` is set (a corrupt serialized entry
    /// must fail loudly rather than yield a vector whose `count_ones`
    /// disagrees with its contents).
    pub fn from_raw(len: usize, words: Vec<u64>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            words.len() == len.div_ceil(64),
            "bit vector of length {len} needs {} words, got {}",
            len.div_ceil(64),
            words.len()
        );
        let tail = len % 64;
        if tail != 0 {
            if let Some(&last) = words.last() {
                anyhow::ensure!(
                    last & !((1u64 << tail) - 1) == 0,
                    "bit vector has bits set beyond its length {len}"
                );
            }
        }
        Ok(Self { len, words })
    }

    /// Iterate over set-bit indices.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

/// Row-major 2-D bit matrix. `true` = element present (non-zero).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    bits: BitVec,
}

impl BitMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            bits: BitVec::zeros(rows * cols),
        }
    }

    pub fn ones(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            bits: BitVec::ones(rows * cols),
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The row-major backing bit vector. Raw view used by the
    /// disk-cache serializer (`eval::serial`).
    #[inline]
    pub fn bit_vec(&self) -> &BitVec {
        &self.bits
    }

    /// Rebuild a matrix from its raw parts, validating that the bit
    /// vector's length matches the geometry.
    pub fn from_raw(rows: usize, cols: usize, bits: BitVec) -> anyhow::Result<Self> {
        let n = rows
            .checked_mul(cols)
            .ok_or_else(|| anyhow::anyhow!("bit matrix {rows}x{cols} overflows"))?;
        anyhow::ensure!(
            bits.len() == n,
            "bit matrix {rows}x{cols} needs {n} bits, got {}",
            bits.len()
        );
        Ok(Self { rows, cols, bits })
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols, "({r},{c}) out of {}x{}", self.rows, self.cols);
        self.bits.get(r * self.cols + c)
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        self.bits.set(r * self.cols + c, v);
    }

    pub fn count_ones(&self) -> usize {
        self.bits.count_ones()
    }

    /// Density of set bits in [0, 1]; 0 for an empty matrix.
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            0.0
        } else {
            self.count_ones() as f64 / (self.rows * self.cols) as f64
        }
    }

    /// Count of set bits in row `r`.
    pub fn row_count(&self, r: usize) -> usize {
        self.bits.count_range(r * self.cols, (r + 1) * self.cols)
    }

    /// Count of set bits in column `c`.
    pub fn col_count(&self, c: usize) -> usize {
        (0..self.rows).filter(|&r| self.get(r, c)).count()
    }

    /// Set row `r`'s columns `[c0, c1)` to `v` (word-level fast path).
    pub fn set_row_range(&mut self, r: usize, c0: usize, c1: usize, v: bool) {
        debug_assert!(r < self.rows && c1 <= self.cols);
        self.bits.set_range(r * self.cols + c0, r * self.cols + c1, v);
    }

    /// True if every bit in the rectangle [r0, r0+h) × [c0, c0+w) is zero.
    pub fn block_is_zero(&self, r0: usize, c0: usize, h: usize, w: usize) -> bool {
        for r in r0..r0 + h {
            if self.bits.any_range(r * self.cols + c0, r * self.cols + c0 + w) {
                return false;
            }
        }
        true
    }

    /// Count of set bits in the rectangle.
    pub fn block_count(&self, r0: usize, c0: usize, h: usize, w: usize) -> usize {
        let mut n = 0;
        for r in r0..r0 + h {
            n += self
                .bits
                .count_range(r * self.cols + c0, r * self.cols + c0 + w);
        }
        n
    }

    /// Element-wise AND, panics on shape mismatch.
    pub fn and(&self, other: &BitMatrix) -> BitMatrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        out.bits.and_assign(&other.bits);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitvec_set_get_roundtrip() {
        let mut v = BitVec::zeros(130);
        for i in [0usize, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!v.get(i));
            v.set(i, true);
            assert!(v.get(i));
        }
        assert_eq!(v.count_ones(), 8);
        v.set(64, false);
        assert_eq!(v.count_ones(), 7);
    }

    #[test]
    fn ones_respects_tail() {
        let v = BitVec::ones(67);
        assert_eq!(v.count_ones(), 67);
        let v = BitVec::ones(64);
        assert_eq!(v.count_ones(), 64);
        let v = BitVec::ones(0);
        assert_eq!(v.count_ones(), 0);
    }

    #[test]
    fn iter_ones_matches_get() {
        let mut v = BitVec::zeros(200);
        let idx = [3usize, 64, 65, 120, 199];
        for &i in &idx {
            v.set(i, true);
        }
        let got: Vec<usize> = v.iter_ones().collect();
        assert_eq!(got, idx);
    }

    #[test]
    fn or_and_assign() {
        let mut a = BitVec::zeros(100);
        let mut b = BitVec::zeros(100);
        a.set(5, true);
        b.set(6, true);
        a.or_assign(&b);
        assert!(a.get(5) && a.get(6));
        let mut c = BitVec::ones(100);
        c.and_assign(&a);
        assert_eq!(c.count_ones(), 2);
    }

    #[test]
    fn bitmatrix_block_ops() {
        let mut m = BitMatrix::zeros(8, 8);
        m.set(2, 3, true);
        m.set(3, 3, true);
        assert!(!m.block_is_zero(2, 2, 2, 2));
        assert!(m.block_is_zero(0, 0, 2, 8));
        assert_eq!(m.block_count(2, 3, 2, 1), 2);
        assert_eq!(m.row_count(2), 1);
        assert_eq!(m.col_count(3), 2);
        assert!((m.density() - 2.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn range_ops_match_scalar() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(42);
        for _ in 0..200 {
            let len = 1 + rng.index(300);
            let mut v = BitVec::zeros(len);
            for _ in 0..len / 3 {
                v.set(rng.index(len), true);
            }
            let lo = rng.index(len);
            let hi = lo + rng.index(len - lo + 1);
            let want_count = (lo..hi).filter(|&i| v.get(i)).count();
            assert_eq!(v.count_range(lo, hi), want_count, "count [{lo},{hi}) len {len}");
            assert_eq!(v.any_range(lo, hi), want_count > 0);
            let mut a = v.clone();
            a.set_range(lo, hi, true);
            for i in 0..len {
                let want = if (lo..hi).contains(&i) { true } else { v.get(i) };
                assert_eq!(a.get(i), want, "set_range true at {i}");
            }
            let mut b = v.clone();
            b.set_range(lo, hi, false);
            for i in 0..len {
                let want = if (lo..hi).contains(&i) { false } else { v.get(i) };
                assert_eq!(b.get(i), want, "set_range false at {i}");
            }
        }
    }

    #[test]
    fn bitmatrix_and() {
        let mut a = BitMatrix::ones(4, 4);
        let mut b = BitMatrix::zeros(4, 4);
        b.set(1, 1, true);
        a.set(1, 1, true);
        let c = a.and(&b);
        assert_eq!(c.count_ones(), 1);
        assert!(c.get(1, 1));
    }
}
