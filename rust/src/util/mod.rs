//! Self-contained utility layer: JSON, PRNG, bit containers, tables,
//! bench harness and a property-testing mini-framework. These exist
//! in-tree because the build environment resolves crates offline and only
//! the `xla` dependency closure is available (see DESIGN.md §3).

pub mod bench;
pub mod bits;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
