//! Minimal, dependency-free JSON implementation (the offline registry has
//! no `serde`). Used for: hardware/workload/mapping config files, the
//! Python→Rust model-graph interchange (`workload/import.rs`), the
//! artifact manifest, and figure-data dumps.
//!
//! Supports the full JSON grammar (RFC 8259) minus `\u` surrogate pairs
//! beyond the BMP being validated pairwise (lone surrogates are replaced).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so serialized
/// output is deterministic — important for artifact-manifest diffing.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---------- constructors ----------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---------- accessors ----------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as usize)
            }
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers that produce actionable error messages.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` must be a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` must be a non-negative integer"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` must be a number"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` must be an array"))
    }

    /// Optional field with default.
    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    /// Insert into an object value (panics if not an object — builder use).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(o) => {
                o.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---------- parse ----------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content after JSON value"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // ---------- serialize ----------
    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting the recursive-descent parser accepts.
/// Adversarial inputs like `"[".repeat(1 << 20)` must produce a
/// [`JsonError`], not a stack overflow; 128 levels is far beyond any
/// config/interchange file this crate reads.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    /// Enter one container level; errors beyond [`MAX_DEPTH`]. Matched by
    /// a `depth -= 1` on each successful container exit (error paths
    /// abort the whole parse, so they need no unwind).
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // handle surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if (0xDC00..0xE000).contains(&lo) {
                                        let c = 0x10000
                                            + ((cp - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        char::from_u32(c).unwrap_or('\u{FFFD}')
                                    } else {
                                        '\u{FFFD}'
                                    }
                                } else {
                                    '\u{FFFD}'
                                }
                            } else {
                                char::from_u32(cp).unwrap_or('\u{FFFD}')
                            };
                            s.push(ch);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError {
                pos: start,
                msg: format!("invalid number `{text}`"),
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"ciminus","dims":[1024,32],"ratio":0.8,"on":true,"note":null}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string();
        let j2 = Json::parse(&compact).unwrap();
        assert_eq!(j, j2);
        let pretty = j.pretty();
        let j3 = Json::parse(&pretty).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos >= 6, "pos={}", e.pos);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn accessors_and_defaults() {
        let j = Json::parse(r#"{"n": 5, "f": 1.5, "s": "x", "b": true, "a": [1]}"#).unwrap();
        assert_eq!(j.req_usize("n").unwrap(), 5);
        assert!(j.req_usize("f").is_err());
        assert_eq!(j.opt_f64("missing", 2.5), 2.5);
        assert_eq!(j.opt_str("s", "d"), "x");
        assert_eq!(j.opt_bool("b", false), true);
        assert_eq!(j.req_arr("a").unwrap().len(), 1);
        assert!(j.req("zz").is_err());
    }

    #[test]
    fn builder_set() {
        let mut j = Json::obj();
        j.set("x", Json::Num(1.0)).set("y", Json::Str("z".into()));
        assert_eq!(j.to_string(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn deterministic_key_order() {
        let j = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(j.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn large_ints_roundtrip() {
        let j = Json::parse("1099511627776").unwrap(); // 2^40
        assert_eq!(j.as_usize(), Some(1 << 40));
        assert_eq!(j.to_string(), "1099511627776");
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_crash() {
        // would overflow the stack without the MAX_DEPTH guard
        let e = Json::parse(&"[".repeat(10_000)).unwrap_err();
        assert!(e.msg.contains("nesting too deep"), "{}", e.msg);
        assert!(Json::parse(&"{\"a\":".repeat(10_000)).is_err());
        // mixed nesting hits the guard too
        assert!(Json::parse(&"[{\"a\":".repeat(5_000)).is_err());
        // depth within the limit still parses
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
        // the guard counts *nesting*, not total container count
        let wide = format!("[{}1]", "[1],".repeat(500));
        assert!(Json::parse(&wide).is_ok());
    }

    use crate::util::proptest::{check, ensure, Gen};

    /// Random JSON value with container nesting ≤ depth. Numbers are kept
    /// integral so serialize→parse is exact.
    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        let top = if depth == 0 { 3 } else { 5 };
        match g.usize_in(0, top) {
            0 => Json::Null,
            1 => Json::Bool(g.bool_with(0.5)),
            2 => Json::Num(g.usize_in(0, 10_000) as f64 - 5_000.0),
            3 => Json::Str(g.ascii_string(12)),
            4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|_| (g.ascii_string(6), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn prop_valid_values_roundtrip() {
        check("json_roundtrip", 300, 0x10AD, |g| {
            let v = gen_json(g, 4);
            let compact = Json::parse(&v.to_string())
                .map_err(|e| format!("compact reparse failed: {e}"))?;
            ensure(compact == v, "compact roundtrip changed value")?;
            let pretty = Json::parse(&v.pretty())
                .map_err(|e| format!("pretty reparse failed: {e}"))?;
            ensure(pretty == v, "pretty roundtrip changed value")
        });
    }

    #[test]
    fn prop_garbage_input_returns_err_never_panics() {
        // structural characters, escapes, digits, unicode — the grammar's
        // trouble spots; any panic fails the test by unwinding
        check("json_garbage", 800, 0xBAD, |g| {
            let s = g.string_from("{}[]\",:.eE+-0123456789truefalsenull \\\t\u{8}é😀", 48);
            let _ = Json::parse(&s);
            Ok(())
        });
    }

    #[test]
    fn prop_truncated_documents_never_panic() {
        check("json_truncate", 300, 0x72C, |g| {
            let v = gen_json(g, 4);
            let full = v.to_string();
            let prefix = g.prefix_of(&full);
            let _ = Json::parse(&prefix);
            // a *proper* prefix of a container document is always invalid
            if prefix.len() < full.len() && matches!(v, Json::Arr(_) | Json::Obj(_)) {
                ensure(Json::parse(&prefix).is_err(), "proper prefix parsed as valid")?;
            }
            Ok(())
        });
    }
}
