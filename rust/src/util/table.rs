//! ASCII table and CSV rendering for experiment reports. Every bench
//! binary prints the paper's table/figure series through this module so
//! outputs are uniform and machine-diffable.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table builder.
#[derive(Debug, Clone)]
pub struct Table {
    title: Option<String>,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            title: None,
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("== {t} ==\n"));
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push('|');
                }
                let cell = &cells[i];
                let pad = widths[i] - cell.len();
                match aligns[i] {
                    Align::Left => line.push_str(&format!(" {}{} ", cell, " ".repeat(pad))),
                    Align::Right => line.push_str(&format!(" {}{} ", " ".repeat(pad), cell)),
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths, &vec![Align::Left; ncol]));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV alongside printing; used by `ciminus report`.
    pub fn write_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Format helpers shared by reports.
pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

pub fn fmt_pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

/// Human-readable engineering format for energies (input in pJ).
pub fn fmt_energy_pj(pj: f64) -> String {
    if pj.abs() >= 1e9 {
        format!("{:.3} mJ", pj / 1e9)
    } else if pj.abs() >= 1e6 {
        format!("{:.3} uJ", pj / 1e6)
    } else if pj.abs() >= 1e3 {
        format!("{:.3} nJ", pj / 1e3)
    } else {
        format!("{pj:.3} pJ")
    }
}

/// Human-readable format for cycle counts.
pub fn fmt_cycles(c: u64) -> String {
    if c >= 1_000_000_000 {
        format!("{:.3} Gcyc", c as f64 / 1e9)
    } else if c >= 1_000_000 {
        format!("{:.3} Mcyc", c as f64 / 1e6)
    } else if c >= 1_000 {
        format!("{:.3} Kcyc", c as f64 / 1e3)
    } else {
        format!("{c} cyc")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]).with_title("demo");
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("alpha"));
        // all data lines same width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{s}");
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().nth(1).unwrap(), "\"x,y\",\"he said \"\"hi\"\"\"");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn energy_formatting() {
        assert_eq!(fmt_energy_pj(12.3456), "12.346 pJ");
        assert_eq!(fmt_energy_pj(12_300.0), "12.300 nJ");
        assert_eq!(fmt_energy_pj(5.0e6), "5.000 uJ");
        assert_eq!(fmt_energy_pj(2.5e9), "2.500 mJ");
    }

    #[test]
    fn cycle_formatting() {
        assert_eq!(fmt_cycles(999), "999 cyc");
        assert_eq!(fmt_cycles(1_500), "1.500 Kcyc");
        assert_eq!(fmt_cycles(2_000_000), "2.000 Mcyc");
    }
}
