//! The FlexBlock sparsity layer (Sec. III): pattern primitives, the
//! FlexBlock composition with its structural constraints, mask
//! generation, compression semantics and index-overhead accounting.

pub mod compress;
pub mod flexblock;
pub mod index;
pub mod mask;
pub mod pattern;

pub use compress::{compress, CompressedLayout};
pub use flexblock::FlexBlock;
pub use index::{index_storage, IndexStorage};
pub use mask::{mask_stats, random_mask, LayerCtx, MaskStats};
pub use pattern::{BlockPattern, BoundPattern, Dim, PatternKind};
