//! Block-based sparsity patterns: the two primitive pattern types of the
//! FlexBlock abstraction (Def. III.2 FullBlock, Def. III.3 IntraBlock).

use crate::util::bits::BitMatrix;

/// A block dimension, possibly symbolic. Symbolic dims are resolved
//  against a concrete weight matrix (and layer context) at bind time,
/// letting one description like "Row-wise = FullBlock(1, N)" apply to
/// every layer (Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dim {
    /// Fixed element count.
    Fixed(usize),
    /// The full extent of the matrix along this axis (M or N).
    Full,
    /// Rows of one input channel in the reshaped matrix (kh·kw under
    /// channel-major flattening) — used by channel-wise pruning.
    PerChannel,
}

impl Dim {
    /// Resolve to a concrete size; `extent` is the matrix dim this block
    /// dim lies along, `per_channel` the channel row-group size (kh·kw).
    pub fn resolve(&self, extent: usize, per_channel: usize) -> usize {
        match *self {
            Dim::Fixed(k) => k,
            Dim::Full => extent,
            Dim::PerChannel => per_channel.max(1),
        }
    }
}

/// Pattern type discriminator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PatternKind {
    FullBlock,
    IntraBlock,
}

/// One block-based sparsity pattern (an element of the FlexBlock set 𝓑).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockPattern {
    pub kind: PatternKind,
    /// Block height (rows), possibly symbolic.
    pub m: Dim,
    /// Block width (cols), possibly symbolic.
    pub n: Dim,
    /// Sparsity ratio r ∈ (0, 1): fraction of blocks (FullBlock) or of
    /// elements within each block (IntraBlock) that are zero.
    pub ratio: f64,
    /// IntraBlock only: explicit pattern set 𝒫 of binary masks. `None`
    /// defaults to *all* arrangements of φ non-zeros in an m×n block
    /// (Sec. IV-C: "when the pattern set is not specified, it defaults to
    /// all available patterns").
    pub pattern_set: Option<Vec<BitMatrix>>,
}

impl BlockPattern {
    pub fn full(m: Dim, n: Dim, ratio: f64) -> Self {
        Self {
            kind: PatternKind::FullBlock,
            m,
            n,
            ratio,
            pattern_set: None,
        }
    }

    pub fn intra(m: usize, ratio: f64) -> Self {
        Self {
            kind: PatternKind::IntraBlock,
            m: Dim::Fixed(m),
            // Practical constraint (Sec. III-D): IntraBlock blocks are
            // column-wise one-dimensional.
            n: Dim::Fixed(1),
            ratio,
            pattern_set: None,
        }
    }

    /// Bind symbolic dims against a concrete matrix.
    pub fn bind(&self, rows: usize, cols: usize, per_channel: usize) -> BoundPattern {
        let m = self.m.resolve(rows, per_channel).min(rows.max(1));
        let n = self.n.resolve(cols, per_channel).min(cols.max(1));
        BoundPattern {
            kind: self.kind,
            m,
            n,
            ratio: self.ratio,
            phi: match self.kind {
                PatternKind::IntraBlock => {
                    (((1.0 - self.ratio) * (m * n) as f64).floor() as usize).max(1)
                }
                PatternKind::FullBlock => 0,
            },
        }
    }

    /// Short label like `Full(1,16)@0.80` for reports.
    pub fn label(&self) -> String {
        let d = |d: &Dim| match d {
            Dim::Fixed(k) => k.to_string(),
            Dim::Full => "*".to_string(),
            Dim::PerChannel => "Cin".to_string(),
        };
        let k = match self.kind {
            PatternKind::FullBlock => "Full",
            PatternKind::IntraBlock => "Intra",
        };
        format!("{k}({},{})@{:.2}", d(&self.m), d(&self.n), self.ratio)
    }
}

/// A pattern bound to concrete dims.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoundPattern {
    pub kind: PatternKind,
    pub m: usize,
    pub n: usize,
    pub ratio: f64,
    /// IntraBlock non-zeros per block: φ = ⌊(1−r)·m·n⌋ (≥ 1).
    pub phi: usize,
}

impl BoundPattern {
    /// Number of blocks along rows/cols (ceil: edge blocks are partial).
    pub fn grid(&self, rows: usize, cols: usize) -> (usize, usize) {
        (rows.div_ceil(self.m), cols.div_ceil(self.n))
    }

    /// FullBlock: number of non-zero blocks Φ = ⌊(1−r)·(M/m)·(N/n)⌋
    /// (Def. III.2), computed over the ceil grid.
    pub fn nonzero_blocks(&self, rows: usize, cols: usize) -> usize {
        let (gr, gc) = self.grid(rows, cols);
        (((1.0 - self.ratio) * (gr * gc) as f64).floor() as usize).clamp(1, gr * gc)
    }
}

/// Enumerate the default IntraBlock pattern set: all C(m·n, φ) placements
/// of φ non-zeros in an m×n block. Sizes used in practice are tiny
/// (1:2 → C(2,1)=2, 1:4 → C(4,1)=4, 2:4 → C(4,2)=6).
pub fn default_pattern_set(m: usize, n: usize, phi: usize) -> Vec<BitMatrix> {
    let total = m * n;
    assert!(phi <= total, "phi {phi} > block size {total}");
    assert!(
        total <= 16,
        "default pattern set for block of {total} elements would be huge; supply an explicit set"
    );
    let mut out = Vec::new();
    // iterate bitmasks of `total` bits with exactly `phi` ones
    for bits in 0u32..(1u32 << total) {
        if bits.count_ones() as usize != phi {
            continue;
        }
        let mut mask = BitMatrix::zeros(m, n);
        for i in 0..total {
            if (bits >> i) & 1 == 1 {
                mask.set(i / n, i % n, true);
            }
        }
        out.push(mask);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dim_resolution() {
        assert_eq!(Dim::Fixed(16).resolve(100, 9), 16);
        assert_eq!(Dim::Full.resolve(100, 9), 100);
        assert_eq!(Dim::PerChannel.resolve(100, 9), 9);
    }

    #[test]
    fn bind_clamps_to_matrix() {
        let p = BlockPattern::full(Dim::Fixed(64), Dim::Fixed(16), 0.5);
        let b = p.bind(32, 8, 1);
        assert_eq!((b.m, b.n), (32, 8));
    }

    #[test]
    fn intra_phi() {
        let p = BlockPattern::intra(2, 0.5); // 1:2
        let b = p.bind(100, 50, 1);
        assert_eq!(b.phi, 1);
        let p4 = BlockPattern::intra(4, 0.75); // 1:4
        assert_eq!(p4.bind(100, 50, 1).phi, 1);
        let p24 = BlockPattern::intra(4, 0.5); // 2:4
        assert_eq!(p24.bind(100, 50, 1).phi, 2);
    }

    #[test]
    fn fullblock_phi_formula() {
        // 8x8 matrix, 2x2 blocks, r=0.75 → 16 blocks, 4 survive
        let p = BlockPattern::full(Dim::Fixed(2), Dim::Fixed(2), 0.75);
        let b = p.bind(8, 8, 1);
        assert_eq!(b.nonzero_blocks(8, 8), 4);
        // ceil grid with non-dividing dims
        let b2 = p.bind(9, 9, 1);
        assert_eq!(b2.grid(9, 9), (5, 5));
    }

    #[test]
    fn default_set_sizes() {
        assert_eq!(default_pattern_set(2, 1, 1).len(), 2);
        assert_eq!(default_pattern_set(4, 1, 1).len(), 4);
        assert_eq!(default_pattern_set(4, 1, 2).len(), 6);
        for p in default_pattern_set(4, 1, 2) {
            assert_eq!(p.count_ones(), 2);
            assert_eq!((p.rows(), p.cols()), (4, 1));
        }
    }

    #[test]
    fn labels_readable() {
        assert_eq!(
            BlockPattern::full(Dim::Fixed(1), Dim::Full, 0.8).label(),
            "Full(1,*)@0.80"
        );
        assert_eq!(BlockPattern::intra(2, 0.5).label(), "Intra(2,1)@0.50");
    }
}
