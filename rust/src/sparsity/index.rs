//! Index storage overhead for sparsity support (Eq. 8, Sec. V-B):
//!
//! S_idx(W) = N_nz_blocks · S_B + Σᵢ N_nz(Bᵢ) · S_elem
//!
//! Block indices locate each surviving block of the finest FullBlock (or
//! IntraBlock) pattern; element indices locate each kept element within
//! an IntraBlock block. These sizes drive the capacity of the index
//! memories the hardware layer instantiates automatically.

use super::compress::CompressedLayout;
use super::flexblock::FlexBlock;
use super::mask::{bind, LayerCtx};

/// Bits needed to address `n` distinct values (≥1 bit).
pub fn addr_bits(n: usize) -> u32 {
    if n <= 1 {
        1
    } else {
        (usize::BITS - (n - 1).leading_zeros()).max(1)
    }
}

/// Index storage requirement for one layer, in bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IndexStorage {
    /// Bits per block index (S_B).
    pub block_index_bits: u32,
    /// Bits per element index (S_elem).
    pub elem_index_bits: u32,
    /// Number of stored block indices (N_nz blocks).
    pub n_block_indices: u64,
    /// Number of stored element indices (Σ N_nz(Bᵢ)).
    pub n_elem_indices: u64,
}

impl IndexStorage {
    /// Total bits of index memory needed (Eq. 8).
    pub fn total_bits(&self) -> u64 {
        self.n_block_indices * self.block_index_bits as u64
            + self.n_elem_indices * self.elem_index_bits as u64
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bits().div_ceil(8)
    }
}

/// Compute Eq. 8 for a layer from its FlexBlock description and the
/// compressed layout measured from the actual mask.
pub fn index_storage(
    fb: &FlexBlock,
    layout: &CompressedLayout,
    ctx: LayerCtx,
) -> IndexStorage {
    if fb.is_dense() {
        return IndexStorage {
            block_index_bits: 0,
            elem_index_bits: 0,
            n_block_indices: 0,
            n_elem_indices: 0,
        };
    }
    let (intra, full) = bind(fb, layout.orig_rows, layout.orig_cols, ctx);
    // Block index width: addresses a position in the coarse grid (or the
    // fine grid when only IntraBlock is present — each fine block then
    // needs its position implicitly, which is sequential, so 0).
    let block_index_bits = match &full {
        Some(bp) => {
            let (gr, gc) = bp.grid(layout.orig_rows, layout.orig_cols);
            addr_bits(gr * gc)
        }
        None => 0,
    };
    // Element index width: position of a kept element within an m×1 block.
    let elem_index_bits = intra.map(|bp| addr_bits(bp.m)).unwrap_or(0);
    IndexStorage {
        block_index_bits,
        elem_index_bits,
        n_block_indices: layout.block_index_count,
        n_elem_indices: layout.elem_index_count,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::compress::compress;
    use crate::sparsity::mask::random_mask;
    use crate::util::rng::Pcg32;

    fn storage_for(fb: &FlexBlock, rows: usize, cols: usize, seed: u64) -> IndexStorage {
        let ctx = LayerCtx::fc();
        let mut rng = Pcg32::new(seed);
        let mask = random_mask(fb, rows, cols, ctx, &mut rng);
        let layout = compress(fb, &mask, ctx);
        index_storage(fb, &layout, ctx)
    }

    #[test]
    fn addr_bits_values() {
        assert_eq!(addr_bits(1), 1);
        assert_eq!(addr_bits(2), 1);
        assert_eq!(addr_bits(3), 2);
        assert_eq!(addr_bits(4), 2);
        assert_eq!(addr_bits(1024), 10);
        assert_eq!(addr_bits(1025), 11);
    }

    #[test]
    fn dense_needs_nothing() {
        let s = storage_for(&FlexBlock::dense(), 64, 64, 1);
        assert_eq!(s.total_bits(), 0);
    }

    #[test]
    fn fullblock_only_needs_block_indices() {
        let fb = FlexBlock::row_block(16, 0.5);
        let s = storage_for(&fb, 64, 64, 2);
        assert!(s.n_block_indices > 0);
        assert_eq!(s.n_elem_indices, 0);
        // grid is 64 × 4 = 256 blocks → 8-bit indices
        assert_eq!(s.block_index_bits, 8);
    }

    #[test]
    fn intra_needs_elem_indices() {
        let fb = FlexBlock::intra(4, 0.75);
        let s = storage_for(&fb, 64, 64, 3);
        assert_eq!(s.n_block_indices, 0);
        assert_eq!(s.n_elem_indices, 64 * 64 / 4); // φ=1 kept per 4-block
        assert_eq!(s.elem_index_bits, 2); // position within 4
    }

    #[test]
    fn hybrid_needs_both() {
        let fb = FlexBlock::hybrid(2, 16, 0.8);
        let s = storage_for(&fb, 128, 64, 4);
        assert!(s.n_block_indices > 0);
        assert!(s.n_elem_indices > 0);
        assert_eq!(s.elem_index_bits, 1); // within a 2-block
        assert!(s.total_bits() > 0);
        assert_eq!(
            s.total_bits(),
            s.n_block_indices * 8 + s.n_elem_indices // grid 64*4=256 → 8 bits
        );
    }

    #[test]
    fn finer_patterns_cost_more_index_storage() {
        // Paper: finer granularity → more indexing overhead.
        let coarse = storage_for(&FlexBlock::row_wise(0.8), 256, 256, 5);
        let fine = storage_for(&FlexBlock::hybrid(2, 16, 0.8), 256, 256, 5);
        assert!(
            fine.total_bits() > coarse.total_bits(),
            "fine {} <= coarse {}",
            fine.total_bits(),
            coarse.total_bits()
        );
    }
}
