//! Compression semantics: how a FlexBlock-masked weight matrix maps to a
//! dense physical layout in CIM arrays (Sec. III-B/III-D, Sec. IV-C ①).
//!
//! Five structural paths, selected by the bound coarse pattern geometry:
//!
//! | path | coarse pattern          | compression                         | hw support                 |
//! |------|-------------------------|-------------------------------------|----------------------------|
//! | A    | none (intra only)       | uniform row compression φ/m         | mux routing + elem indices |
//! | B    | full-width (n = N)      | row-strip elimination               | block indices              |
//! | C    | full-height (m = M)     | column elimination                  | block indices              |
//! | D    | partial width (n < N)   | horizontal in-strip packing, ragged | block idx + extra accum    |
//! | E    | partial height, n = 1   | vertical in-column packing, ragged  | block idx + mux routing    |
//!
//! A hybrid (intra + full) composes the full path with path A's uniform
//! row compression inside surviving strips.

use super::flexblock::FlexBlock;
use super::mask::{bind, LayerCtx};
use super::pattern::BoundPattern;
use crate::util::bits::BitMatrix;

/// Physical layout of a compressed weight matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedLayout {
    pub orig_rows: usize,
    pub orig_cols: usize,
    /// Physical rows required (max over ragged columns).
    pub comp_rows: usize,
    /// Physical columns required (max over ragged rows).
    pub comp_cols: usize,
    /// Per-physical-row occupancy in columns (len == comp_rows). Ragged
    /// when compression produces uneven strips; uniform otherwise.
    pub row_lengths: Vec<usize>,
    /// Distinct logical inputs broadcast per physical row (1 = dense
    /// broadcast; m for IntraBlock(m,1); measured fan-in for path E).
    pub broadcast: usize,
    /// Non-zero weight elements.
    pub nnz: u64,
    /// Block-level indices the hardware must store (Eq. 8 first term).
    pub block_index_count: u64,
    /// Element-level indices (Eq. 8 second term; IntraBlock only).
    pub elem_index_count: u64,
    /// Horizontal packing misaligned partial sums → extra accumulators.
    pub misaligned_cols: bool,
    /// Vertical packing / intra → mux-based input routing required.
    pub routed_rows: bool,
}

impl CompressedLayout {
    /// Dense layout for an unpruned matrix.
    pub fn dense(rows: usize, cols: usize) -> Self {
        Self {
            orig_rows: rows,
            orig_cols: cols,
            comp_rows: rows,
            comp_cols: cols,
            row_lengths: vec![cols; rows],
            broadcast: 1,
            nnz: (rows * cols) as u64,
            block_index_count: 0,
            elem_index_count: 0,
            misaligned_cols: false,
            routed_rows: false,
        }
    }

    /// Occupied fraction of the comp_rows × comp_cols bounding rectangle.
    pub fn packing_utilization(&self) -> f64 {
        if self.comp_rows == 0 || self.comp_cols == 0 {
            return 0.0;
        }
        let occ: usize = self.row_lengths.iter().sum();
        occ as f64 / (self.comp_rows * self.comp_cols) as f64
    }

    /// Compression ratio of physical footprint vs original (< 1 good).
    pub fn footprint_ratio(&self) -> f64 {
        (self.comp_rows * self.comp_cols) as f64 / (self.orig_rows * self.orig_cols) as f64
    }
}

/// Compute the compressed layout of `mask` under FlexBlock `fb`.
pub fn compress(fb: &FlexBlock, mask: &BitMatrix, ctx: LayerCtx) -> CompressedLayout {
    let rows = mask.rows();
    let cols = mask.cols();
    let nnz = mask.count_ones() as u64;
    if fb.is_dense() {
        return CompressedLayout::dense(rows, cols);
    }
    let (intra, full) = bind(fb, rows, cols, ctx);
    // intra compression factor: fine block of height im keeps φ rows
    let (im, phi) = intra.map(|b| (b.m, b.phi)).unwrap_or((1, 1));
    let elem_index_count = if intra.is_some() { nnz } else { 0 };

    match full {
        None => {
            // Path A: uniform intra row compression.
            let comp_rows = rows.div_ceil(im) * phi;
            CompressedLayout {
                orig_rows: rows,
                orig_cols: cols,
                comp_rows,
                comp_cols: cols,
                row_lengths: vec![cols; comp_rows],
                broadcast: im,
                nnz,
                block_index_count: 0,
                elem_index_count,
                misaligned_cols: false,
                routed_rows: true,
            }
        }
        Some(bp) => compress_with_full(mask, &bp, im, phi, nnz, elem_index_count),
    }
}

fn strip_phys_rows(cm: usize, im: usize, phi: usize) -> usize {
    // a coarse strip of cm logical rows holds cm/im fine blocks of φ rows
    cm.div_ceil(im) * phi
}

fn compress_with_full(
    mask: &BitMatrix,
    bp: &BoundPattern,
    im: usize,
    phi: usize,
    nnz: u64,
    elem_index_count: u64,
) -> CompressedLayout {
    let rows = mask.rows();
    let cols = mask.cols();
    let (gr, gc) = bp.grid(rows, cols);
    // coarse-cell occupancy grid
    let mut occupied = vec![false; gr * gc];
    let mut n_occupied: u64 = 0;
    for bi in 0..gr {
        for bj in 0..gc {
            let r0 = bi * bp.m;
            let c0 = bj * bp.n;
            let h = bp.m.min(rows - r0);
            let w = bp.n.min(cols - c0);
            if !mask.block_is_zero(r0, c0, h, w) {
                occupied[bi * gc + bj] = true;
                n_occupied += 1;
            }
        }
    }
    let wide = bp.n >= cols; // spans full width
    let tall = bp.m >= rows; // spans full height
    let sr = strip_phys_rows(bp.m, im, phi);
    let routed_by_intra = im > 1;

    if wide {
        // Path B: row-strip elimination (gc == 1). Partial edge strips
        // cannot exceed the original row count.
        let surviving = occupied.iter().filter(|&&o| o).count();
        let comp_rows = (surviving * sr).min(rows);
        CompressedLayout {
            orig_rows: rows,
            orig_cols: cols,
            comp_rows,
            comp_cols: cols,
            row_lengths: vec![cols; comp_rows],
            broadcast: im.max(1),
            nnz,
            block_index_count: n_occupied,
            elem_index_count,
            misaligned_cols: false,
            routed_rows: routed_by_intra,
        }
    } else if tall {
        // Path C: column elimination (gr == 1).
        let surviving_cols: usize = (0..gc)
            .map(|bj| if occupied[bj] { bp.n.min(cols - bj * bp.n) } else { 0 })
            .sum();
        let comp_rows = rows.div_ceil(im) * phi;
        CompressedLayout {
            orig_rows: rows,
            orig_cols: cols,
            comp_rows,
            comp_cols: surviving_cols,
            row_lengths: vec![surviving_cols; comp_rows],
            broadcast: im.max(1),
            nnz,
            block_index_count: n_occupied,
            elem_index_count,
            misaligned_cols: false,
            routed_rows: routed_by_intra,
        }
    } else if bp.n > 1 {
        // Path D: horizontal packing of surviving blocks within each strip.
        let mut strip_widths: Vec<usize> = Vec::with_capacity(gr);
        for bi in 0..gr {
            let s: usize = (0..gc)
                .map(|bj| {
                    if occupied[bi * gc + bj] {
                        bp.n.min(cols - bj * bp.n)
                    } else {
                        0
                    }
                })
                .sum();
            strip_widths.push(s);
        }
        // strips with zero survivors are eliminated entirely
        let surviving: Vec<usize> = strip_widths.iter().copied().filter(|&w| w > 0).collect();
        let comp_rows = (surviving.len() * sr).min(rows);
        let comp_cols = surviving.iter().copied().max().unwrap_or(0);
        let mut row_lengths = Vec::with_capacity(comp_rows);
        'fill: for &w in &surviving {
            for _ in 0..sr {
                if row_lengths.len() == comp_rows {
                    break 'fill;
                }
                row_lengths.push(w);
            }
        }
        CompressedLayout {
            orig_rows: rows,
            orig_cols: cols,
            comp_rows,
            comp_cols,
            row_lengths,
            broadcast: im.max(1),
            nnz,
            block_index_count: n_occupied,
            elem_index_count,
            misaligned_cols: true,
            routed_rows: routed_by_intra,
        }
    } else {
        // Path E: vertical packing within each column (bp.n == 1, bp.m < M).
        // Column heights after packing + measured routing fan-in per slot.
        let mut col_heights: Vec<usize> = Vec::with_capacity(gc);
        for bj in 0..gc {
            let o = (0..gr).filter(|&bi| occupied[bi * gc + bj]).count();
            col_heights.push(o);
        }
        let max_slots = col_heights.iter().copied().max().unwrap_or(0);
        // partial edge blocks cap at the original row extent
        let comp_rows = (max_slots * sr).min(rows);
        // fan-in: for each packed slot index, distinct logical block rows
        // across columns — this is what the input-routing mux must cover.
        let mut fan_in_sum = 0usize;
        let mut fan_in_slots = 0usize;
        for slot in 0..max_slots {
            let mut distinct = std::collections::BTreeSet::new();
            for bj in 0..gc {
                let mut seen = 0usize;
                for bi in 0..gr {
                    if occupied[bi * gc + bj] {
                        if seen == slot {
                            distinct.insert(bi);
                            break;
                        }
                        seen += 1;
                    }
                }
            }
            if !distinct.is_empty() {
                fan_in_sum += distinct.len();
                fan_in_slots += 1;
            }
        }
        let fan_in = if fan_in_slots > 0 {
            (fan_in_sum as f64 / fan_in_slots as f64).ceil() as usize
        } else {
            1
        };
        let surviving_cols = col_heights.iter().filter(|&&h| h > 0).count();
        // per-physical-row occupancy (transposed view of column heights)
        let mut row_lengths = vec![0usize; comp_rows];
        for &h in &col_heights {
            for r in 0..(h * sr).min(comp_rows) {
                row_lengths[r] += 1;
            }
        }
        let _ = surviving_cols;
        CompressedLayout {
            orig_rows: rows,
            orig_cols: cols,
            comp_rows,
            comp_cols: cols,
            row_lengths,
            broadcast: (fan_in * im).max(1),
            nnz,
            block_index_count: n_occupied,
            elem_index_count,
            misaligned_cols: false,
            routed_rows: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::mask::random_mask;
    use crate::util::rng::Pcg32;

    fn ctx() -> LayerCtx {
        LayerCtx { per_channel: 9 }
    }

    #[test]
    fn dense_layout() {
        let fb = FlexBlock::dense();
        let mask = BitMatrix::ones(64, 32);
        let l = compress(&fb, &mask, ctx());
        assert_eq!((l.comp_rows, l.comp_cols), (64, 32));
        assert_eq!(l.packing_utilization(), 1.0);
        assert_eq!(l.broadcast, 1);
    }

    #[test]
    fn path_b_row_wise() {
        let fb = FlexBlock::row_wise(0.75);
        let mut rng = Pcg32::new(1);
        let mask = random_mask(&fb, 64, 32, ctx(), &mut rng);
        let l = compress(&fb, &mask, ctx());
        assert_eq!(l.comp_rows, 16); // 25% of 64 rows survive
        assert_eq!(l.comp_cols, 32);
        assert!(!l.misaligned_cols && !l.routed_rows);
        assert_eq!(l.block_index_count, 16);
        assert_eq!(l.elem_index_count, 0);
        assert_eq!(l.packing_utilization(), 1.0);
    }

    #[test]
    fn path_c_column_wise() {
        let fb = FlexBlock::column_wise(0.5);
        let mut rng = Pcg32::new(2);
        let mask = random_mask(&fb, 64, 40, ctx(), &mut rng);
        let l = compress(&fb, &mask, ctx());
        assert_eq!(l.comp_rows, 64);
        assert_eq!(l.comp_cols, 20);
        assert!(!l.misaligned_cols);
    }

    #[test]
    fn path_a_intra() {
        let fb = FlexBlock::intra(2, 0.5);
        let mut rng = Pcg32::new(3);
        let mask = random_mask(&fb, 64, 32, ctx(), &mut rng);
        let l = compress(&fb, &mask, ctx());
        assert_eq!(l.comp_rows, 32); // halved uniformly
        assert_eq!(l.broadcast, 2);
        assert!(l.routed_rows);
        assert_eq!(l.elem_index_count, l.nnz);
        assert_eq!(l.packing_utilization(), 1.0);
    }

    #[test]
    fn path_d_row_block_ragged() {
        let fb = FlexBlock::row_block(16, 0.5);
        let mut rng = Pcg32::new(4);
        let mask = random_mask(&fb, 32, 64, ctx(), &mut rng);
        let l = compress(&fb, &mask, ctx());
        assert!(l.misaligned_cols);
        assert!(l.comp_cols <= 64);
        assert!(l.comp_rows <= 32);
        // every row length is a multiple of the block width
        assert!(l.row_lengths.iter().all(|&w| w % 16 == 0));
        // ragged unless extremely lucky
        let min = l.row_lengths.iter().min().unwrap();
        let max = l.row_lengths.iter().max().unwrap();
        assert!(max >= min);
        assert_eq!(*max, l.comp_cols);
    }

    #[test]
    fn path_e_column_block_vertical() {
        let fb = FlexBlock::column_block(8, 0.5);
        let mut rng = Pcg32::new(5);
        let mask = random_mask(&fb, 64, 16, ctx(), &mut rng);
        let l = compress(&fb, &mask, ctx());
        assert!(l.routed_rows);
        assert!(l.broadcast >= 1);
        assert!(l.comp_rows <= 64);
        // vertical packing reduces rows below original on average
        assert!(l.comp_rows >= 8, "at least one slot of 8 rows");
    }

    #[test]
    fn hybrid_combines_intra_and_full() {
        let fb = FlexBlock::hybrid(2, 16, 0.8);
        let mut rng = Pcg32::new(6);
        let mask = random_mask(&fb, 128, 64, ctx(), &mut rng);
        let l = compress(&fb, &mask, ctx());
        assert!(l.misaligned_cols, "row-block packing");
        assert!(l.routed_rows, "intra routing");
        assert_eq!(l.broadcast, 2);
        assert_eq!(l.elem_index_count, l.nnz);
        // rows compress: 128 logical rows → strips of 2 → ≤ 64 physical
        assert!(l.comp_rows <= 64, "comp_rows={}", l.comp_rows);
    }

    #[test]
    fn hybrid_row_wise_uniform() {
        let fb = FlexBlock::hybrid_row_wise(2, 0.8);
        let mut rng = Pcg32::new(7);
        let mask = random_mask(&fb, 128, 64, ctx(), &mut rng);
        let l = compress(&fb, &mask, ctx());
        assert!(!l.misaligned_cols);
        assert_eq!(l.packing_utilization(), 1.0);
        // density 0.2 → 0.4 of strips survive → 128/2*0.4 ≈ 25 physical rows
        assert!(l.comp_rows <= 32 && l.comp_rows >= 18, "{}", l.comp_rows);
    }

    #[test]
    fn footprint_improves_with_sparsity() {
        let mut rng = Pcg32::new(8);
        let lo = FlexBlock::row_wise(0.5);
        let hi = FlexBlock::row_wise(0.9);
        let ml = random_mask(&lo, 256, 64, ctx(), &mut rng);
        let mh = random_mask(&hi, 256, 64, ctx(), &mut rng);
        let fl = compress(&lo, &ml, ctx()).footprint_ratio();
        let fh = compress(&hi, &mh, ctx()).footprint_ratio();
        assert!(fh < fl, "higher sparsity → smaller footprint: {fh} vs {fl}");
    }
}
