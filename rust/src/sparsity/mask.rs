//! Sparsity mask generation from FlexBlock descriptions.
//!
//! Masks come from two sources, matching Sec. IV-C: the pruning workflow
//! (importance-driven selection, `crate::pruning`) or randomized
//! generation "in accordance with the provided pattern description" for
//! user-defined workloads without weights. Both go through the selection
//! functions here so the structural guarantees are enforced in one place.

use super::pattern::{default_pattern_set, BoundPattern, PatternKind};
use crate::sparsity::flexblock::FlexBlock;
use crate::util::bits::BitMatrix;
use crate::util::rng::Pcg32;

/// Layer context needed to bind symbolic dims: `per_channel` = rows per
/// input channel in the reshaped matrix (kh·kw; 1 for FC).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerCtx {
    pub per_channel: usize,
}

impl LayerCtx {
    pub fn fc() -> Self {
        Self { per_channel: 1 }
    }
}

/// Bind a FlexBlock's patterns against a concrete matrix, returning
/// `(intra, full)` bound components.
pub fn bind(
    fb: &FlexBlock,
    rows: usize,
    cols: usize,
    ctx: LayerCtx,
) -> (Option<BoundPattern>, Option<BoundPattern>) {
    let mut intra = None;
    let mut full = None;
    for p in &fb.patterns {
        let b = p.bind(rows, cols, ctx.per_channel);
        match b.kind {
            PatternKind::IntraBlock => intra = Some(b),
            PatternKind::FullBlock => full = Some(b),
        }
    }
    (intra, full)
}

/// Build a mask keeping exactly the coarse blocks whose grid-row-major
/// index is in `keep` (true = keep). Grid uses ceil division; edge blocks
/// are partial.
pub fn fullblock_mask_from_selection(
    rows: usize,
    cols: usize,
    bp: &BoundPattern,
    keep: &[bool],
) -> BitMatrix {
    let (gr, gc) = bp.grid(rows, cols);
    assert_eq!(keep.len(), gr * gc, "selection length != grid size");
    let mut mask = BitMatrix::zeros(rows, cols);
    for bi in 0..gr {
        for bj in 0..gc {
            if keep[bi * gc + bj] {
                let r0 = bi * bp.m;
                let c0 = bj * bp.n;
                let c1 = (c0 + bp.n).min(cols);
                for r in r0..(r0 + bp.m).min(rows) {
                    mask.set_row_range(r, c0, c1, true);
                }
            }
        }
    }
    mask
}

/// Fast path for randomized IntraBlock(m, 1) with the default (full)
/// pattern set: keeping φ of m elements uniformly is equivalent to
/// sampling φ distinct offsets per surviving block — no pattern-set
/// materialization, no per-pattern masking. Hot path of the pruning
/// workflow (§Perf).
pub fn intrablock_random_m1(mask: &mut BitMatrix, bp: &BoundPattern, rng: &mut Pcg32) {
    debug_assert_eq!(bp.n, 1);
    let rows = mask.rows();
    let cols = mask.cols();
    let gr = rows.div_ceil(bp.m);
    for bi in 0..gr {
        let r0 = bi * bp.m;
        let h = bp.m.min(rows - r0);
        let phi = bp.phi.min(h);
        let pow2 = h.is_power_of_two();
        for c in 0..cols {
            // coarse FullBlock pruning is block-aligned (integral-multiple
            // constraint), so a surviving fine block is fully set — test
            // one cell
            if !mask.get(r0, c) {
                continue;
            }
            if phi == 1 {
                // power-of-two block heights (1:2, 1:4 — the practical
                // cases) take an unbiased masked draw, skipping Lemire
                // rejection (§Perf opt 4)
                let keep = if pow2 {
                    (rng.next_u32() as usize) & (h - 1)
                } else {
                    rng.index(h)
                };
                for r in 0..h {
                    if r != keep {
                        mask.set(r0 + r, c, false);
                    }
                }
            } else {
                let keeps = rng.sample_indices(h, phi);
                for r in 0..h {
                    if !keeps.contains(&r) {
                        mask.set(r0 + r, c, false);
                    }
                }
            }
        }
    }
}

/// Random FullBlock selection: keep Φ = ⌊(1−r)·G⌋ blocks chosen uniformly.
pub fn fullblock_random_selection(
    rows: usize,
    cols: usize,
    bp: &BoundPattern,
    rng: &mut Pcg32,
) -> Vec<bool> {
    let (gr, gc) = bp.grid(rows, cols);
    let total = gr * gc;
    let keep_n = bp.nonzero_blocks(rows, cols);
    let mut keep = vec![false; total];
    for i in rng.sample_indices(total, keep_n) {
        keep[i] = true;
    }
    keep
}

/// Apply IntraBlock sparsity in place: for every fine block that is not
/// already fully zero, AND it with a pattern chosen by `choose` (given
/// the block grid coordinates and the candidate set, return the index of
/// the pattern to use).
pub fn intrablock_apply<F>(
    mask: &mut BitMatrix,
    bp: &BoundPattern,
    patterns: &[BitMatrix],
    mut choose: F,
) where
    F: FnMut(usize, usize, &[BitMatrix]) -> usize,
{
    assert!(!patterns.is_empty(), "empty IntraBlock pattern set");
    for p in patterns {
        assert_eq!(
            (p.rows(), p.cols()),
            (bp.m, bp.n),
            "pattern shape mismatch with block size"
        );
    }
    let rows = mask.rows();
    let cols = mask.cols();
    let (gr, gc) = bp.grid(rows, cols);
    for bi in 0..gr {
        for bj in 0..gc {
            let r0 = bi * bp.m;
            let c0 = bj * bp.n;
            let h = bp.m.min(rows - r0);
            let w = bp.n.min(cols - c0);
            if mask.block_is_zero(r0, c0, h, w) {
                continue; // pruned by a coarser pattern
            }
            let pi = choose(bi, bj, patterns);
            let pat = &patterns[pi];
            for r in 0..h {
                for c in 0..w {
                    if !pat.get(r, c) {
                        mask.set(r0 + r, c0 + c, false);
                    }
                }
            }
        }
    }
}

/// The effective IntraBlock pattern set: explicit if provided, else the
/// default full enumeration for (m, n, φ).
pub fn pattern_set_for(fb: &FlexBlock, bp: &BoundPattern) -> Vec<BitMatrix> {
    if let Some(p) = fb.intra_pattern() {
        if let Some(set) = &p.pattern_set {
            return set.clone();
        }
    }
    default_pattern_set(bp.m, bp.n, bp.phi)
}

/// Generate a randomized mask realizing `fb` on a `rows`×`cols` matrix
/// (Sec. IV-C: auto-generated randomized sparsity for user-defined
/// workloads). Coarse FullBlock applies first, IntraBlock within the
/// survivors.
pub fn random_mask(
    fb: &FlexBlock,
    rows: usize,
    cols: usize,
    ctx: LayerCtx,
    rng: &mut Pcg32,
) -> BitMatrix {
    if fb.is_dense() {
        return BitMatrix::ones(rows, cols);
    }
    let (intra, full) = bind(fb, rows, cols, ctx);
    let mut mask = match &full {
        Some(bp) => {
            let keep = fullblock_random_selection(rows, cols, bp, rng);
            fullblock_mask_from_selection(rows, cols, bp, &keep)
        }
        None => BitMatrix::ones(rows, cols),
    };
    if let Some(bp) = &intra {
        let has_custom_set = fb
            .intra_pattern()
            .map(|p| p.pattern_set.is_some())
            .unwrap_or(false);
        if bp.n == 1 && !has_custom_set {
            intrablock_random_m1(&mut mask, bp, rng);
        } else {
            let patterns = pattern_set_for(fb, bp);
            intrablock_apply(&mut mask, bp, &patterns, |_, _, set| rng.index(set.len()));
        }
    }
    mask
}

/// Measured sparsity statistics of a mask against its description.
#[derive(Debug, Clone, PartialEq)]
pub struct MaskStats {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub sparsity: f64,
}

pub fn mask_stats(mask: &BitMatrix) -> MaskStats {
    let nnz = mask.count_ones();
    MaskStats {
        rows: mask.rows(),
        cols: mask.cols(),
        nnz,
        sparsity: 1.0 - mask.density(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};

    fn ctx() -> LayerCtx {
        LayerCtx { per_channel: 9 }
    }

    #[test]
    fn dense_mask_is_all_ones() {
        let mut rng = Pcg32::new(1);
        let m = random_mask(&FlexBlock::dense(), 8, 8, ctx(), &mut rng);
        assert_eq!(m.count_ones(), 64);
    }

    #[test]
    fn row_wise_mask_prunes_whole_rows() {
        let mut rng = Pcg32::new(2);
        let fb = FlexBlock::row_wise(0.75);
        let m = random_mask(&fb, 64, 32, ctx(), &mut rng);
        let mut surviving = 0;
        for r in 0..64 {
            let cnt = m.row_count(r);
            assert!(cnt == 0 || cnt == 32, "row {r} partially pruned: {cnt}");
            if cnt > 0 {
                surviving += 1;
            }
        }
        assert_eq!(surviving, 16); // ⌊0.25 · 64⌋
    }

    #[test]
    fn column_wise_mask_prunes_whole_cols() {
        let mut rng = Pcg32::new(3);
        let fb = FlexBlock::column_wise(0.5);
        let m = random_mask(&fb, 32, 40, ctx(), &mut rng);
        let surviving = (0..40).filter(|&c| m.col_count(c) > 0).count();
        assert_eq!(surviving, 20);
        for c in 0..40 {
            let cnt = m.col_count(c);
            assert!(cnt == 0 || cnt == 32);
        }
    }

    #[test]
    fn intra_mask_keeps_phi_per_block() {
        let mut rng = Pcg32::new(4);
        let fb = FlexBlock::intra(2, 0.5); // 1:2
        let m = random_mask(&fb, 64, 16, ctx(), &mut rng);
        for b in 0..32 {
            for c in 0..16 {
                let cnt = m.block_count(b * 2, c, 2, 1);
                assert_eq!(cnt, 1, "block ({b},{c}) keeps exactly 1 of 2");
            }
        }
        let s = mask_stats(&m);
        assert!((s.sparsity - 0.5).abs() < 1e-9);
    }

    #[test]
    fn hybrid_mask_overall_ratio() {
        let mut rng = Pcg32::new(5);
        let fb = FlexBlock::hybrid(2, 16, 0.8);
        let m = random_mask(&fb, 128, 64, ctx(), &mut rng);
        let s = mask_stats(&m);
        assert!(
            (s.sparsity - 0.8).abs() < 0.05,
            "sparsity {} vs target 0.8",
            s.sparsity
        );
        // surviving (2,16) blocks must have exactly 1 nonzero per (2,1) column
        for bi in 0..64 {
            for bj in 0..4 {
                let (r0, c0) = (bi * 2, bj * 16);
                let cnt = m.block_count(r0, c0, 2, 16);
                assert!(
                    cnt == 0 || cnt == 16,
                    "surviving block keeps 1 of 2 per column: got {cnt}"
                );
            }
        }
    }

    #[test]
    fn channel_wise_uses_per_channel_rows() {
        let mut rng = Pcg32::new(6);
        let fb = FlexBlock::channel_wise(0.5);
        // 4 channels × 9 rows each
        let m = random_mask(&fb, 36, 8, ctx(), &mut rng);
        for ch in 0..4 {
            let cnt = m.block_count(ch * 9, 0, 9, 8);
            assert!(cnt == 0 || cnt == 72, "channel {ch} all-or-nothing: {cnt}");
        }
        assert_eq!(m.count_ones(), 2 * 72);
    }

    #[test]
    fn prop_random_mask_sparsity_tracks_description() {
        check("mask_sparsity", 60, 42, |g| {
            let rows = g.usize_in(2, 40) * 4;
            let cols = g.usize_in(1, 10) * 16;
            let ratio = g.f64_in(0.3, 0.9);
            let fb = match g.usize_in(0, 3) {
                0 => FlexBlock::row_wise(ratio),
                1 => FlexBlock::row_block(16, ratio),
                2 => FlexBlock::column_block(4, ratio),
                _ => FlexBlock::intra(4, 0.75),
            };
            let mut rng = g.rng.fork(99);
            let m = random_mask(&fb, rows, cols, LayerCtx::fc(), &mut rng);
            let want = fb.overall_sparsity();
            let got = mask_stats(&m).sparsity;
            // floor effects on small grids allow some slack
            ensure(
                (got - want).abs() < 0.15,
                format!("{}: sparsity {got} vs {want} ({rows}x{cols})", fb.name),
            )
        });
    }

    #[test]
    fn mask_deterministic_per_seed() {
        let fb = FlexBlock::hybrid(2, 16, 0.8);
        let a = random_mask(&fb, 64, 32, ctx(), &mut Pcg32::new(7));
        let b = random_mask(&fb, 64, 32, ctx(), &mut Pcg32::new(7));
        assert_eq!(a, b);
        let c = random_mask(&fb, 64, 32, ctx(), &mut Pcg32::new(8));
        assert_ne!(a, c);
    }
}
