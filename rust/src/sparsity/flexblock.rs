//! The FlexBlock sparsity abstraction (Def. III.1): a composition of at
//! most two block-based patterns describing a weight matrix's sparsity,
//! with the practical constraints of Sec. III-D enforced by
//! [`FlexBlock::validate`].

use super::pattern::{BlockPattern, Dim, PatternKind};
use crate::util::json::Json;

/// A FlexBlock sparsity description 𝓑 = {B₁, …, B_k}, k ≤ 2, stored
/// finest-first. For hybrid patterns the finer IntraBlock precedes the
/// coarser FullBlock (e.g. "1:2 + Row-block" = Intra(2,1) + Full(2,16)).
#[derive(Debug, Clone, PartialEq)]
pub struct FlexBlock {
    pub patterns: Vec<BlockPattern>,
    /// Human-readable name used in reports (e.g. "Row-block").
    pub name: String,
}

impl FlexBlock {
    /// Dense (no sparsity) marker — empty pattern set.
    pub fn dense() -> Self {
        Self {
            patterns: vec![],
            name: "Dense".into(),
        }
    }

    pub fn is_dense(&self) -> bool {
        self.patterns.is_empty()
    }

    /// Single FullBlock pattern.
    pub fn full_block(m: usize, n: usize, ratio: f64) -> Self {
        Self {
            patterns: vec![BlockPattern::full(Dim::Fixed(m), Dim::Fixed(n), ratio)],
            name: format!("FullBlock({m},{n})"),
        }
    }

    // ---- Table II named patterns ----

    /// Row-wise: FullBlock(1, N).
    pub fn row_wise(ratio: f64) -> Self {
        Self {
            patterns: vec![BlockPattern::full(Dim::Fixed(1), Dim::Full, ratio)],
            name: "Row-wise".into(),
        }
    }

    /// Row-block: FullBlock(1, w) (default w = 16).
    pub fn row_block(w: usize, ratio: f64) -> Self {
        Self {
            patterns: vec![BlockPattern::full(Dim::Fixed(1), Dim::Fixed(w), ratio)],
            name: format!("Row-block({w})"),
        }
    }

    /// Column (filter)-wise: FullBlock(M, 1).
    pub fn column_wise(ratio: f64) -> Self {
        Self {
            patterns: vec![BlockPattern::full(Dim::Full, Dim::Fixed(1), ratio)],
            name: "Column-wise".into(),
        }
    }

    /// Channel-wise: prunes whole input channels — row groups of kh·kw
    /// under channel-major flattening, spanning all columns.
    pub fn channel_wise(ratio: f64) -> Self {
        Self {
            patterns: vec![BlockPattern::full(Dim::PerChannel, Dim::Full, ratio)],
            name: "Channel-wise".into(),
        }
    }

    /// Column-block: FullBlock(h, 1) (default h = 16).
    pub fn column_block(h: usize, ratio: f64) -> Self {
        Self {
            patterns: vec![BlockPattern::full(Dim::Fixed(h), Dim::Fixed(1), ratio)],
            name: format!("Column-block({h})"),
        }
    }

    /// IntraBlock m:1 column pattern (e.g. m=2 → "1:2").
    pub fn intra(m: usize, ratio: f64) -> Self {
        Self {
            patterns: vec![BlockPattern::intra(m, ratio)],
            name: format!("Intra({m},1)"),
        }
    }

    /// IntraBlock with an explicit pattern set 𝒫 (SegPrune-style
    /// pattern-based sparsity, Sec. III-D): only the given m×1 masks are
    /// admissible arrangements. All masks must share the same popcount φ
    /// (uniform compressed shape) — enforced by `validate`.
    pub fn intra_with_patterns(
        m: usize,
        patterns: Vec<crate::util::bits::BitMatrix>,
        name: &str,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!patterns.is_empty(), "pattern set must be non-empty");
        let phi = patterns[0].count_ones();
        anyhow::ensure!(phi >= 1 && phi < m, "patterns must keep 1..m-1 of {m}");
        for p in &patterns {
            anyhow::ensure!(
                (p.rows(), p.cols()) == (m, 1),
                "pattern shape {}x{} != {m}x1",
                p.rows(),
                p.cols()
            );
            anyhow::ensure!(
                p.count_ones() == phi,
                "all patterns must keep the same φ (uniform compressed shape)"
            );
        }
        let ratio = 1.0 - phi as f64 / m as f64;
        let mut bp = BlockPattern::intra(m, ratio);
        bp.pattern_set = Some(patterns);
        Ok(Self {
            patterns: vec![bp],
            name: name.to_string(),
        })
    }

    /// Hybrid: IntraBlock(m,1) keeping 1 of m + FullBlock(m, w) at a
    /// FullBlock ratio chosen to hit `overall_ratio` total sparsity
    /// (Sec. VII-A: "the IntraBlock ratio is fixed such that only one
    /// element per block remains; the FullBlock ratio is adjusted to
    /// maintain the overall sparsity ratio").
    pub fn hybrid(m: usize, w: usize, overall_ratio: f64) -> Self {
        let intra_keep = 1.0 / m as f64; // density after intra
        // overall density = intra_keep * (1 - r_full)  ⇒
        let r_full = (1.0 - (1.0 - overall_ratio) / intra_keep).clamp(0.01, 0.99);
        let intra_ratio = 1.0 - intra_keep;
        Self {
            patterns: vec![
                BlockPattern::intra(m, intra_ratio),
                BlockPattern::full(Dim::Fixed(m), Dim::Fixed(w), r_full),
            ],
            name: format!("1:{m}+Row-block({w})"),
        }
    }

    /// Hybrid with a full-width coarse pattern ("1:2 + Row-wise").
    pub fn hybrid_row_wise(m: usize, overall_ratio: f64) -> Self {
        let intra_keep = 1.0 / m as f64;
        let r_full = (1.0 - (1.0 - overall_ratio) / intra_keep).clamp(0.01, 0.99);
        Self {
            patterns: vec![
                BlockPattern::intra(m, 1.0 - intra_keep),
                BlockPattern::full(Dim::Fixed(m), Dim::Full, r_full),
            ],
            name: format!("1:{m}+Row-wise"),
        }
    }

    /// Overall expected weight sparsity (fraction of zero elements).
    pub fn overall_sparsity(&self) -> f64 {
        let mut density = 1.0;
        for p in &self.patterns {
            match p.kind {
                PatternKind::FullBlock => density *= 1.0 - p.ratio,
                PatternKind::IntraBlock => density *= 1.0 - p.ratio,
            }
        }
        1.0 - density
    }

    /// The IntraBlock component, if any.
    pub fn intra_pattern(&self) -> Option<&BlockPattern> {
        self.patterns
            .iter()
            .find(|p| p.kind == PatternKind::IntraBlock)
    }

    /// The FullBlock component, if any.
    pub fn full_pattern(&self) -> Option<&BlockPattern> {
        self.patterns
            .iter()
            .find(|p| p.kind == PatternKind::FullBlock)
    }

    /// Enforce the structural constraints of Sec. III-C/III-D:
    /// - at most two patterns; if two, exactly one IntraBlock (finer) and
    ///   one FullBlock (coarser);
    /// - ratios in (0, 1); block sizes m·n > 1;
    /// - IntraBlock blocks are column-wise 1-D (n = 1);
    /// - the coarser FullBlock size is an integral multiple of the finer
    ///   IntraBlock size along both axes.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.patterns.len() > 2 {
            anyhow::bail!(
                "FlexBlock `{}`: composition limited to 2 patterns, got {}",
                self.name,
                self.patterns.len()
            );
        }
        for p in &self.patterns {
            if !(0.0..1.0).contains(&p.ratio) || p.ratio == 0.0 {
                anyhow::bail!(
                    "FlexBlock `{}`: sparsity ratio must be in (0,1), got {}",
                    self.name,
                    p.ratio
                );
            }
            if let (Dim::Fixed(m), Dim::Fixed(n)) = (p.m, p.n) {
                if m * n <= 1 {
                    anyhow::bail!("FlexBlock `{}`: block size m·n must exceed 1", self.name);
                }
            }
            if p.kind == PatternKind::IntraBlock && p.n != Dim::Fixed(1) {
                anyhow::bail!(
                    "FlexBlock `{}`: IntraBlock patterns must be column-wise 1-D (n = 1)",
                    self.name
                );
            }
        }
        if self.patterns.len() == 2 {
            let kinds: Vec<PatternKind> = self.patterns.iter().map(|p| p.kind).collect();
            let n_intra = kinds.iter().filter(|k| **k == PatternKind::IntraBlock).count();
            if n_intra != 1 {
                // Two FullBlocks are a mathematical subset of the finer one
                // (Sec. III-D); two IntraBlocks explode routing complexity.
                anyhow::bail!(
                    "FlexBlock `{}`: a 2-pattern composition must pair one IntraBlock with one FullBlock",
                    self.name
                );
            }
            let intra = self.intra_pattern().unwrap();
            let full = self.full_pattern().unwrap();
            // integral-multiple constraint along rows (both are column-wise
            // 1-D or wider in n; n multiple only checked for Fixed dims)
            if let (Dim::Fixed(fm), Dim::Fixed(im)) = (full.m, intra.m) {
                if fm % im != 0 {
                    anyhow::bail!(
                        "FlexBlock `{}`: coarse block height {fm} must be an integral multiple of fine height {im}",
                        self.name
                    );
                }
            }
        }
        Ok(())
    }

    // ---- JSON interchange (config files / python pruning workflow) ----

    pub fn to_json(&self) -> Json {
        let dim_to_json = |d: &Dim| match d {
            Dim::Fixed(k) => Json::Num(*k as f64),
            Dim::Full => Json::Str("full".into()),
            Dim::PerChannel => Json::Str("per_channel".into()),
        };
        let patterns: Vec<Json> = self
            .patterns
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set(
                    "kind",
                    Json::Str(
                        match p.kind {
                            PatternKind::FullBlock => "full_block",
                            PatternKind::IntraBlock => "intra_block",
                        }
                        .into(),
                    ),
                );
                o.set("m", dim_to_json(&p.m));
                o.set("n", dim_to_json(&p.n));
                o.set("ratio", Json::Num(p.ratio));
                o
            })
            .collect();
        let mut root = Json::obj();
        root.set("name", Json::Str(self.name.clone()));
        root.set("patterns", Json::Arr(patterns));
        root
    }

    pub fn from_json(j: &Json) -> anyhow::Result<FlexBlock> {
        let name = j.req_str("name")?.to_string();
        let parse_dim = |v: &Json| -> anyhow::Result<Dim> {
            if let Some(k) = v.as_usize() {
                Ok(Dim::Fixed(k))
            } else {
                match v.as_str() {
                    Some("full") => Ok(Dim::Full),
                    Some("per_channel") => Ok(Dim::PerChannel),
                    _ => anyhow::bail!("bad block dim {v}"),
                }
            }
        };
        let mut patterns = Vec::new();
        for p in j.req_arr("patterns")? {
            let kind = match p.req_str("kind")? {
                "full_block" => PatternKind::FullBlock,
                "intra_block" => PatternKind::IntraBlock,
                other => anyhow::bail!("unknown pattern kind `{other}`"),
            };
            patterns.push(BlockPattern {
                kind,
                m: parse_dim(p.req("m")?)?,
                n: parse_dim(p.req("n")?)?,
                ratio: p.req_f64("ratio")?,
                pattern_set: None,
            });
        }
        let fb = FlexBlock { patterns, name };
        fb.validate()?;
        Ok(fb)
    }

    /// FlexBlock representation string as printed in Table II.
    pub fn representation(&self) -> String {
        if self.is_dense() {
            return "Dense".into();
        }
        self.patterns
            .iter()
            .map(|p| p.label())
            .collect::<Vec<_>>()
            .join(" + ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_patterns_validate() {
        for fb in [
            FlexBlock::row_wise(0.8),
            FlexBlock::row_block(16, 0.8),
            FlexBlock::column_wise(0.8),
            FlexBlock::channel_wise(0.8),
            FlexBlock::column_block(16, 0.8),
            FlexBlock::intra(2, 0.5),
            FlexBlock::hybrid(2, 16, 0.8),
            FlexBlock::hybrid_row_wise(2, 0.8),
            FlexBlock::hybrid(4, 16, 0.8),
        ] {
            fb.validate().unwrap_or_else(|e| panic!("{}: {e}", fb.name));
        }
    }

    #[test]
    fn hybrid_hits_overall_ratio() {
        for target in [0.5, 0.6, 0.7, 0.8, 0.9] {
            let fb = FlexBlock::hybrid(2, 16, target);
            let got = fb.overall_sparsity();
            assert!(
                (got - target).abs() < 0.02,
                "target {target} got {got} ({})",
                fb.name
            );
        }
    }

    #[test]
    fn rejects_three_patterns() {
        let mut fb = FlexBlock::hybrid(2, 16, 0.8);
        fb.patterns.push(BlockPattern::full(Dim::Fixed(4), Dim::Fixed(4), 0.5));
        assert!(fb.validate().is_err());
    }

    #[test]
    fn rejects_two_fullblocks() {
        let fb = FlexBlock {
            patterns: vec![
                BlockPattern::full(Dim::Fixed(1), Dim::Fixed(16), 0.5),
                BlockPattern::full(Dim::Fixed(2), Dim::Fixed(32), 0.5),
            ],
            name: "bad".into(),
        };
        assert!(fb.validate().is_err());
    }

    #[test]
    fn rejects_non_multiple_hybrid() {
        let fb = FlexBlock {
            patterns: vec![
                BlockPattern::intra(2, 0.5),
                BlockPattern::full(Dim::Fixed(3), Dim::Fixed(16), 0.5),
            ],
            name: "bad".into(),
        };
        assert!(fb.validate().is_err());
    }

    #[test]
    fn rejects_2d_intra() {
        let fb = FlexBlock {
            patterns: vec![BlockPattern {
                kind: PatternKind::IntraBlock,
                m: Dim::Fixed(2),
                n: Dim::Fixed(2),
                ratio: 0.5,
                pattern_set: None,
            }],
            name: "bad".into(),
        };
        assert!(fb.validate().is_err());
    }

    #[test]
    fn rejects_bad_ratio() {
        let mut fb = FlexBlock::row_wise(0.8);
        fb.patterns[0].ratio = 1.0;
        assert!(fb.validate().is_err());
        fb.patterns[0].ratio = 0.0;
        assert!(fb.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        for fb in [
            FlexBlock::row_wise(0.8),
            FlexBlock::hybrid(2, 16, 0.7),
            FlexBlock::channel_wise(0.5),
        ] {
            let j = fb.to_json();
            let fb2 = FlexBlock::from_json(&j).unwrap();
            assert_eq!(fb, fb2);
        }
    }

    #[test]
    fn representations_match_table2_style() {
        assert_eq!(FlexBlock::row_wise(0.8).representation(), "Full(1,*)@0.80");
        assert_eq!(
            FlexBlock::hybrid(2, 16, 0.8).representation(),
            "Intra(2,1)@0.50 + Full(2,16)@0.60"
        );
    }

    #[test]
    fn custom_pattern_sets() {
        use crate::util::bits::BitMatrix;
        let mk = |keeps: &[usize]| {
            let mut m = BitMatrix::zeros(4, 1);
            for &k in keeps {
                m.set(k, 0, true);
            }
            m
        };
        // SegPrune-style: only "adjacent pair" arrangements allowed
        let fb = FlexBlock::intra_with_patterns(
            4,
            vec![mk(&[0, 1]), mk(&[1, 2]), mk(&[2, 3])],
            "AdjacentPairs",
        )
        .unwrap();
        fb.validate().unwrap();
        assert!((fb.overall_sparsity() - 0.5).abs() < 1e-9);
        // masks drawn from it only use admissible arrangements
        let mut rng = crate::util::rng::Pcg32::new(3);
        let mask = crate::sparsity::mask::random_mask(
            &fb,
            64,
            8,
            crate::sparsity::mask::LayerCtx::fc(),
            &mut rng,
        );
        for b in 0..16 {
            for c in 0..8 {
                let kept: Vec<usize> = (0..4).filter(|&r| mask.get(b * 4 + r, c)).collect();
                assert_eq!(kept.len(), 2, "uniform φ");
                assert_eq!(kept[1], kept[0] + 1, "adjacent pair only: {kept:?}");
            }
        }
        // rejected: mixed popcounts / wrong shapes
        assert!(FlexBlock::intra_with_patterns(4, vec![mk(&[0]), mk(&[1, 2])], "bad").is_err());
        assert!(FlexBlock::intra_with_patterns(4, vec![], "bad").is_err());
        assert!(
            FlexBlock::intra_with_patterns(3, vec![mk(&[0, 1])], "bad").is_err(),
            "shape mismatch"
        );
    }

    #[test]
    fn dense_is_dense() {
        let d = FlexBlock::dense();
        assert!(d.is_dense());
        assert_eq!(d.overall_sparsity(), 0.0);
        d.validate().unwrap();
    }
}
