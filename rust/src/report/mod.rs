//! Report emitters: turn study results into the paper's tables/series
//! (ASCII + CSV). Shared by the bench binaries and `ciminus report`.

use crate::explore::fault_study::ResiliencePoint;
use crate::explore::input_study::InputSparsityPoint;
use crate::explore::mapping_study::{MappingPoint, RearrangePoint};
use crate::explore::sparsity_study::SparsityPoint;
use crate::sparsity::flexblock::FlexBlock;
use crate::util::table::{fmt_f, Table};
use crate::validate::ValidationPoint;

/// Table I: validation-architecture summary.
pub fn tab1() -> Table {
    let mut t = Table::new(&["parameter", "MARS", "SDP"]).with_title("Table I: CIM designs for validation");
    let m = crate::hw::presets::mars();
    let s = crate::hw::presets::sdp();
    t.row(vec![
        "macro size".into(),
        format!("{}x{}", m.cim.rows, m.cim.cols),
        format!("{}x{}", s.cim.rows, s.cim.cols),
    ]);
    t.row(vec![
        "sub-array size".into(),
        format!("{}x{}", m.cim.sub_rows, m.cim.sub_cols),
        format!("{}x{}", s.cim.sub_rows, s.cim.sub_cols),
    ]);
    t.row(vec![
        "macro org".into(),
        format!("{} macros ({})", m.org.n_macros(), m.org.label()),
        format!("{} macros ({})", s.org.n_macros(), s.org.label()),
    ]);
    t.row(vec![
        "global buf".into(),
        format!(
            "{} KB (ping-pong)",
            (m.global_in_buf.size_bytes + m.global_out_buf.size_bytes) / 1024
        ),
        format!(
            "{} KB (in), {} KB (out)",
            s.global_in_buf.size_bytes / 1024,
            s.global_out_buf.size_bytes / 1024
        ),
    ]);
    t.row(vec![
        "sparsity".into(),
        "Full (1, 16)".into(),
        "Intra (2, 1) + Full (2, 8)".into(),
    ]);
    t.row(vec![
        "eval scope".into(),
        "Only Conv layers".into(),
        "Entire NN".into(),
    ]);
    t
}

/// Table II: sparsity patterns and their FlexBlock representations.
pub fn tab2() -> Table {
    let mut t = Table::new(&["sparsity pattern", "FlexBlock representation"])
        .with_title("Table II: FlexBlock representations");
    let rows: Vec<(&str, FlexBlock)> = vec![
        ("Row-wise", FlexBlock::row_wise(0.8)),
        ("Row-block", FlexBlock::row_block(16, 0.8)),
        ("Column (Filter)-wise", FlexBlock::column_wise(0.8)),
        ("Channel-wise", FlexBlock::channel_wise(0.8)),
        ("Column-block", FlexBlock::column_block(16, 0.8)),
        ("1:2 + Row-block", FlexBlock::hybrid(2, 16, 0.8)),
        ("1:2 + Row-wise", FlexBlock::hybrid_row_wise(2, 0.8)),
        ("1:4 + Row-block", FlexBlock::hybrid(4, 16, 0.8)),
    ];
    for (name, fb) in rows {
        fb.validate().expect("table II patterns are valid");
        t.row(vec![name.to_string(), fb.representation()]);
    }
    t
}

/// Fig. 6(a)/(b): reported-vs-estimated table.
pub fn fig6_table(points: &[ValidationPoint]) -> Table {
    let mut t = Table::new(&["design", "workload", "metric", "reported", "estimated", "err%"])
        .with_title("Fig. 6: validation against MARS and SDP");
    for p in points {
        t.row(vec![
            p.design.to_string(),
            p.workload.clone(),
            p.metric.to_string(),
            fmt_f(p.reported, 2),
            fmt_f(p.estimated, 2),
            fmt_f(p.err_pct(), 2),
        ]);
    }
    t
}

/// Fig. 6(c): SDP power-breakdown comparison.
pub fn fig6c_table(rows: &[(&'static str, f64, f64)]) -> Table {
    let mut t = Table::new(&["component", "reported%", "estimated%"])
        .with_title("Fig. 6(c): SDP power breakdown");
    for (name, rep, est) in rows {
        t.row(vec![
            name.to_string(),
            fmt_f(rep * 100.0, 1),
            fmt_f(est * 100.0, 1),
        ]);
    }
    t
}

/// Fig. 8-style sparsity-sweep table.
pub fn sparsity_table(title: &str, points: &[SparsityPoint]) -> Table {
    let mut t = Table::new(&["pattern", "ratio", "speedup", "energy_saving", "util%", "accuracy"])
        .with_title(title);
    for p in points {
        t.row(vec![
            p.pattern.clone(),
            fmt_f(p.ratio, 2),
            fmt_f(p.speedup, 3),
            fmt_f(p.energy_saving, 3),
            fmt_f(p.utilization * 100.0, 1),
            p.accuracy
                .map(|a| fmt_f(a * 100.0, 1))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

/// Fig. 10: input-sparsity table.
pub fn input_sparsity_table(title: &str, points: &[InputSparsityPoint]) -> Table {
    let mut t = Table::new(&["config", "skip%", "speedup(I/W)", "energy_saving(I/W)"])
        .with_title(title);
    for p in points {
        t.row(vec![
            p.label.clone(),
            fmt_f(p.skip_ratio * 100.0, 1),
            fmt_f(p.speedup_from_input, 3),
            fmt_f(p.energy_saving_from_input, 3),
        ]);
    }
    t
}

/// Fig. 11: mapping-strategy grid.
pub fn mapping_table(points: &[MappingPoint]) -> Table {
    let mut t = Table::new(&["model", "org", "strategy", "energy(uJ)", "latency(cyc)", "util%"])
        .with_title("Fig. 11: mapping strategies across macro organizations");
    for p in points {
        t.row(vec![
            p.model.clone(),
            p.org.clone(),
            p.strategy.clone(),
            fmt_f(p.energy_pj / 1e6, 3),
            p.latency_cycles.to_string(),
            fmt_f(p.utilization * 100.0, 1),
        ]);
    }
    t
}

/// Fault-resilience curve: degradation vs. injected fault density.
pub fn fault_table(title: &str, points: &[ResiliencePoint]) -> Table {
    let mut t = Table::new(&[
        "rate", "spatial", "macros", "cap_loss%", "+rounds", "latency_ovh", "energy_ovh",
    ])
    .with_title(title);
    for p in points {
        if p.usable {
            t.row(vec![
                fmt_f(p.fault_rate, 4),
                p.spatial.clone(),
                format!("{}/{}", p.usable_macros, p.total_macros),
                fmt_f(p.capacity_loss * 100.0, 1),
                p.extra_rounds.to_string(),
                fmt_f(p.latency_overhead, 3),
                fmt_f(p.energy_overhead, 3),
            ]);
        } else {
            t.row(vec![
                fmt_f(p.fault_rate, 4),
                p.spatial.clone(),
                format!("0/{}", p.total_macros),
                "100.0".into(),
                "-".into(),
                "unusable".into(),
                "unusable".into(),
            ]);
        }
    }
    t
}

/// Fig. 12: rearrangement comparison.
pub fn rearrange_table(points: &[RearrangePoint]) -> Table {
    let mut t = Table::new(&["strategy", "rearranged", "energy(uJ)", "latency(cyc)", "util%"])
        .with_title("Fig. 12: weight-data rearrangement");
    for p in points {
        t.row(vec![
            p.strategy.clone(),
            if p.rearranged { "R" } else { "-" }.to_string(),
            fmt_f(p.energy_pj / 1e6, 3),
            p.latency_cycles.to_string(),
            fmt_f(p.utilization * 100.0, 1),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_tables_render() {
        let t1 = tab1().render();
        assert!(t1.contains("1024x64"));
        assert!(t1.contains("Intra (2, 1)"));
        let t2 = tab2().render();
        assert!(t2.contains("Row-wise"));
        assert!(t2.contains("Full(1,*)@0.80"));
        assert_eq!(tab2().n_rows(), 8);
    }

    #[test]
    fn fig6_table_includes_errors() {
        let pts = vec![ValidationPoint {
            design: "MARS",
            workload: "vgg16".into(),
            metric: "speedup",
            reported: 2.0,
            estimated: 2.2,
        }];
        let t = fig6_table(&pts).render();
        assert!(t.contains("10.00"));
    }
}
