//! Workload description layer (Sec. IV-C ①): DNN operators, DAG
//! construction and verification, JSON interchange, and the built-in
//! model zoo with the paper's evaluation networks.

pub mod graph;
pub mod import;
pub mod op;
pub mod zoo;

pub use graph::{LayerSparsity, Network, NetworkStats};
pub use op::{MvmDims, Op, OpId, OpKind, Shape};
