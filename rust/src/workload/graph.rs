//! Workload DAG: construction builder, topological ordering, shape
//! inference / functional verification (the "pre-simulation analysis"
//! validity check of Sec. IV-B), and whole-network statistics.

use super::op::{kind_label, MvmDims, Op, OpId, OpKind, Shape};
use std::collections::BTreeMap;

/// A DNN workload as a DAG of [`Op`]s in insertion order. Insertion order
/// must be topological (builders guarantee it; `verify` checks it).
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub ops: Vec<Op>,
}

impl Network {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            ops: Vec::new(),
        }
    }

    // ---------- builder ----------

    /// Add the graph input node.
    pub fn input(&mut self, shape: Shape) -> OpId {
        self.push("input", OpKind::Input, vec![], Some(shape))
    }

    pub fn conv(
        &mut self,
        name: &str,
        input: OpId,
        in_ch: usize,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> OpId {
        self.push(
            name,
            OpKind::Conv2d {
                in_ch,
                out_ch,
                kh: k,
                kw: k,
                stride,
                pad,
                groups: 1,
            },
            vec![input],
            None,
        )
    }

    pub fn dwconv(
        &mut self,
        name: &str,
        input: OpId,
        ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> OpId {
        self.push(
            name,
            OpKind::Conv2d {
                in_ch: ch,
                out_ch: ch,
                kh: k,
                kw: k,
                stride,
                pad,
                groups: ch,
            },
            vec![input],
            None,
        )
    }

    pub fn fc(&mut self, name: &str, input: OpId, in_f: usize, out_f: usize) -> OpId {
        self.push(
            name,
            OpKind::Fc {
                in_features: in_f,
                out_features: out_f,
            },
            vec![input],
            None,
        )
    }

    pub fn relu(&mut self, name: &str, input: OpId) -> OpId {
        self.push(name, OpKind::Relu, vec![input], None)
    }

    pub fn bn(&mut self, name: &str, input: OpId) -> OpId {
        self.push(name, OpKind::BatchNorm, vec![input], None)
    }

    pub fn add(&mut self, name: &str, a: OpId, b: OpId) -> OpId {
        self.push(name, OpKind::Add, vec![a, b], None)
    }

    pub fn maxpool(&mut self, name: &str, input: OpId, k: usize, stride: usize) -> OpId {
        self.push(
            name,
            OpKind::Pool {
                kind: super::op::PoolKind::Max,
                k,
                stride,
            },
            vec![input],
            None,
        )
    }

    pub fn avgpool(&mut self, name: &str, input: OpId, k: usize, stride: usize) -> OpId {
        self.push(
            name,
            OpKind::Pool {
                kind: super::op::PoolKind::Avg,
                k,
                stride,
            },
            vec![input],
            None,
        )
    }

    pub fn gap(&mut self, name: &str, input: OpId) -> OpId {
        self.push(name, OpKind::GlobalAvgPool, vec![input], None)
    }

    pub fn flatten(&mut self, name: &str, input: OpId) -> OpId {
        self.push(name, OpKind::Flatten, vec![input], None)
    }

    fn push(
        &mut self,
        name: &str,
        kind: OpKind,
        inputs: Vec<OpId>,
        shape: Option<Shape>,
    ) -> OpId {
        let id = self.ops.len();
        self.ops.push(Op {
            id,
            name: name.to_string(),
            kind,
            inputs,
            out_shape: shape.unwrap_or(Shape::Flat(0)),
        });
        id
    }

    // ---------- analysis ----------

    /// Infer all output shapes in topological (insertion) order and verify
    /// graph validity: edge targets exist and precede their consumers,
    /// exactly one Input, shape compatibility throughout.
    pub fn infer_shapes(&mut self) -> anyhow::Result<()> {
        let n_inputs = self
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Input))
            .count();
        if n_inputs != 1 {
            anyhow::bail!(
                "network `{}` must have exactly 1 input op, found {n_inputs}",
                self.name
            );
        }
        for i in 0..self.ops.len() {
            let op = self.ops[i].clone();
            if op.id != i {
                anyhow::bail!("op `{}` id {} != position {i}", op.name, op.id);
            }
            for &src in &op.inputs {
                if src >= i {
                    anyhow::bail!(
                        "op `{}` consumes op {src} which does not precede it (not topological)",
                        op.name
                    );
                }
            }
            let in_shapes: Vec<Shape> =
                op.inputs.iter().map(|&s| self.ops[s].out_shape).collect();
            let out = op.infer_shape(&in_shapes)?;
            self.ops[i].out_shape = out;
        }
        Ok(())
    }

    /// Input shape of op `id` (its first producer's output shape).
    pub fn input_shape(&self, id: OpId) -> Option<Shape> {
        let op = &self.ops[id];
        op.inputs.first().map(|&s| self.ops[s].out_shape)
    }

    /// MVM dims of op `id` if it is an MVM op.
    pub fn mvm_dims(&self, id: OpId) -> Option<MvmDims> {
        let op = &self.ops[id];
        self.input_shape(id).and_then(|s| op.mvm_dims(s))
    }

    /// Ids of all MVM ops (the layers that land on CIM macros).
    pub fn mvm_ops(&self) -> Vec<OpId> {
        self.ops
            .iter()
            .filter(|o| o.is_mvm())
            .map(|o| o.id)
            .collect()
    }

    /// Consumers-of map (adjacency), for pipeline/liveness analysis.
    pub fn consumers(&self) -> Vec<Vec<OpId>> {
        let mut out = vec![Vec::new(); self.ops.len()];
        for op in &self.ops {
            for &src in &op.inputs {
                out[src].push(op.id);
            }
        }
        out
    }

    /// Aggregate statistics.
    pub fn stats(&self) -> NetworkStats {
        let mut s = NetworkStats::default();
        for op in &self.ops {
            if let Some(d) = self.mvm_dims(op.id) {
                s.macs += d.macs();
                s.params += d.params();
                match op.kind {
                    OpKind::Conv2d { groups, .. } if groups > 1 => s.n_dwconv += 1,
                    OpKind::Conv2d { .. } => s.n_conv += 1,
                    OpKind::Fc { .. } => s.n_fc += 1,
                    _ => {}
                }
            }
            let in_shapes: Vec<Shape> =
                op.inputs.iter().map(|&i| self.ops[i].out_shape).collect();
            s.postproc_ops += op.postproc_ops(&in_shapes);
        }
        s.n_ops = self.ops.len();
        s
    }

    /// One-line-per-op textual summary (debugging, `ciminus zoo`).
    pub fn describe(&self) -> String {
        let mut out = format!("network `{}` ({} ops)\n", self.name, self.ops.len());
        for op in &self.ops {
            let dims = self
                .mvm_dims(op.id)
                .map(|d| {
                    format!(
                        " W[{}x{}]{} vecs={}",
                        d.rows,
                        d.cols,
                        if d.groups > 1 {
                            format!(" x{}grp", d.groups)
                        } else {
                            String::new()
                        },
                        d.n_vectors
                    )
                })
                .unwrap_or_default();
            out.push_str(&format!(
                "  [{:>3}] {:<10} {:<24} out={:?}{}\n",
                op.id,
                kind_label(&op.kind),
                op.name,
                op.out_shape,
                dims
            ));
        }
        out
    }
}

/// Whole-network aggregate counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NetworkStats {
    pub n_ops: usize,
    pub n_conv: usize,
    pub n_dwconv: usize,
    pub n_fc: usize,
    /// Dense MACs per inference.
    pub macs: u64,
    /// Dense weight parameters.
    pub params: u64,
    /// Post-processing element ops per inference.
    pub postproc_ops: u64,
}

/// Per-layer sparsity assignment: which MVM ops get which FlexBlock
/// description. Ops absent from the map run dense.
pub type LayerSparsity = BTreeMap<OpId, crate::sparsity::flexblock::FlexBlock>;

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        let mut n = Network::new("tiny");
        let x = n.input(Shape::Chw(3, 8, 8));
        let c1 = n.conv("c1", x, 3, 16, 3, 1, 1);
        let r1 = n.relu("r1", c1);
        let c2 = n.conv("c2", r1, 16, 16, 3, 1, 1);
        let a = n.add("res", c2, r1);
        let g = n.gap("gap", a);
        let _f = n.fc("fc", g, 16, 10);
        n.infer_shapes().unwrap();
        n
    }

    #[test]
    fn shapes_flow() {
        let n = tiny();
        assert_eq!(n.ops.last().unwrap().out_shape, Shape::Flat(10));
        assert_eq!(n.ops[1].out_shape, Shape::Chw(16, 8, 8));
    }

    #[test]
    fn mvm_ops_listed() {
        let n = tiny();
        let mvm = n.mvm_ops();
        assert_eq!(mvm.len(), 3); // c1, c2, fc
        let d = n.mvm_dims(mvm[0]).unwrap();
        assert_eq!(d.rows, 27);
        assert_eq!(d.cols, 16);
    }

    #[test]
    fn stats_counts() {
        let n = tiny();
        let s = n.stats();
        assert_eq!(s.n_conv, 2);
        assert_eq!(s.n_fc, 1);
        assert_eq!(
            s.params,
            (27 * 16 + 16 * 9 * 16 + 16 * 10) as u64
        );
        assert!(s.macs > 0);
    }

    #[test]
    fn rejects_non_topological() {
        let mut n = Network::new("bad");
        let x = n.input(Shape::Chw(3, 8, 8));
        // manually create a forward reference
        let id = n.conv("c", x, 3, 8, 3, 1, 1);
        n.ops[id].inputs = vec![id + 1];
        n.ops.push(Op {
            id: id + 1,
            name: "ghost".into(),
            kind: OpKind::Relu,
            inputs: vec![x],
            out_shape: Shape::Flat(0),
        });
        assert!(n.infer_shapes().is_err());
    }

    #[test]
    fn rejects_multiple_inputs() {
        let mut n = Network::new("bad2");
        n.input(Shape::Chw(3, 8, 8));
        n.input(Shape::Chw(3, 8, 8));
        assert!(n.infer_shapes().is_err());
    }

    #[test]
    fn consumers_map() {
        let n = tiny();
        let cons = n.consumers();
        // relu r1 feeds c2 and the residual add
        assert_eq!(cons[2].len(), 2);
    }

    #[test]
    fn describe_contains_all_ops() {
        let n = tiny();
        let d = n.describe();
        for op in &n.ops {
            assert!(d.contains(&op.name), "{d}");
        }
    }
}
