//! DNN operator definitions.
//!
//! CIMinus models workloads as DAGs of [`Op`]s (Sec. IV-C "Workload
//! Description"). MVM-based operators (convolutions, fully-connected
//! layers) are the ones mapped onto CIM macros; everything else is routed
//! to the post-processing units by the mapping (Sec. IV-C ②).

/// Feature tensor shape flowing along DAG edges (batch dim is implicit:
/// CIM inference is modeled per-sample, as in the paper's evaluations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// Channels × height × width feature map.
    Chw(usize, usize, usize),
    /// Flat vector (after Flatten / for FC layers).
    Flat(usize),
}

impl Shape {
    pub fn numel(&self) -> usize {
        match *self {
            Shape::Chw(c, h, w) => c * h * w,
            Shape::Flat(n) => n,
        }
    }
}

/// Pooling flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Operator kind with its static parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Graph input placeholder.
    Input,
    /// 2-D convolution. `groups == in_ch == out_ch` models depthwise.
    Conv2d {
        in_ch: usize,
        out_ch: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    },
    /// Fully-connected layer.
    Fc { in_features: usize, out_features: usize },
    /// Spatial pooling.
    Pool {
        kind: PoolKind,
        k: usize,
        stride: usize,
    },
    /// Global average pooling → Flat(c).
    GlobalAvgPool,
    /// Element-wise ReLU.
    Relu,
    /// Element-wise addition (residual); takes two inputs.
    Add,
    /// Batch normalization (folded at inference; modeled as post-processing).
    BatchNorm,
    /// Reshape CHW → Flat.
    Flatten,
}

/// Dimensions of the reshaped 2-D weight matrix of an MVM op plus the
/// number of input vectors streamed through it (Sec. III-A).
///
/// Orientation follows the paper's weight-stationary convention: matrix
/// *rows* (M) are the flattened input dimensions (`C_in/groups · Kh · Kw`)
/// unrolled along the CIM array row direction (inputs broadcast across a
/// row); matrix *columns* (N) are output channels accumulated along the
/// bitline direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MvmDims {
    /// Weight-matrix rows M (input-patch length).
    pub rows: usize,
    /// Weight-matrix columns N (output channels / features).
    pub cols: usize,
    /// Number of input vectors per inference (im2col columns; 1 for FC).
    pub n_vectors: usize,
    /// Independent weight groups sharing nothing (depthwise: `groups`).
    pub groups: usize,
}

impl MvmDims {
    /// Dense multiply-accumulate count per inference.
    pub fn macs(&self) -> u64 {
        self.rows as u64 * self.cols as u64 * self.n_vectors as u64 * self.groups as u64
    }

    /// Dense weight parameter count.
    pub fn params(&self) -> u64 {
        self.rows as u64 * self.cols as u64 * self.groups as u64
    }
}

/// Identifier of an op inside its [`super::graph::Network`].
pub type OpId = usize;

/// A node in the workload DAG.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: OpId,
    pub name: String,
    pub kind: OpKind,
    /// Producer ops. `Input` has none.
    pub inputs: Vec<OpId>,
    /// Inferred output shape (filled by `Network::infer_shapes`).
    pub out_shape: Shape,
}

impl Op {
    /// Whether this op is executed on CIM macros (true) or post-processing
    /// units (false).
    pub fn is_mvm(&self) -> bool {
        matches!(self.kind, OpKind::Conv2d { .. } | OpKind::Fc { .. })
    }

    /// Compute the output shape given input shapes; errors on mismatch.
    pub fn infer_shape(&self, ins: &[Shape]) -> anyhow::Result<Shape> {
        use OpKind::*;
        let one = |ins: &[Shape]| -> anyhow::Result<Shape> {
            if ins.len() != 1 {
                anyhow::bail!("op `{}` expects 1 input, got {}", self.name, ins.len());
            }
            Ok(ins[0])
        };
        match &self.kind {
            Input => Ok(self.out_shape),
            Conv2d {
                in_ch,
                out_ch,
                kh,
                kw,
                stride,
                pad,
                groups,
            } => {
                let s = one(ins)?;
                let (c, h, w) = match s {
                    Shape::Chw(c, h, w) => (c, h, w),
                    _ => anyhow::bail!("conv `{}` requires CHW input", self.name),
                };
                if c != *in_ch {
                    anyhow::bail!(
                        "conv `{}` expects {in_ch} input channels, got {c}",
                        self.name
                    );
                }
                if in_ch % groups != 0 || out_ch % groups != 0 {
                    anyhow::bail!("conv `{}`: groups {groups} must divide channels", self.name);
                }
                if h + 2 * pad < *kh || w + 2 * pad < *kw {
                    anyhow::bail!("conv `{}`: kernel larger than padded input", self.name);
                }
                let oh = (h + 2 * pad - kh) / stride + 1;
                let ow = (w + 2 * pad - kw) / stride + 1;
                Ok(Shape::Chw(*out_ch, oh, ow))
            }
            Fc {
                in_features,
                out_features,
            } => {
                let s = one(ins)?;
                let n = s.numel();
                if n != *in_features {
                    anyhow::bail!(
                        "fc `{}` expects {in_features} features, got {n}",
                        self.name
                    );
                }
                Ok(Shape::Flat(*out_features))
            }
            Pool { k, stride, .. } => {
                let s = one(ins)?;
                let (c, h, w) = match s {
                    Shape::Chw(c, h, w) => (c, h, w),
                    _ => anyhow::bail!("pool `{}` requires CHW input", self.name),
                };
                if h < *k || w < *k {
                    anyhow::bail!("pool `{}`: window {k} larger than input {h}x{w}", self.name);
                }
                Ok(Shape::Chw(c, (h - k) / stride + 1, (w - k) / stride + 1))
            }
            GlobalAvgPool => {
                let s = one(ins)?;
                match s {
                    Shape::Chw(c, _, _) => Ok(Shape::Flat(c)),
                    _ => anyhow::bail!("gap `{}` requires CHW input", self.name),
                }
            }
            Relu | BatchNorm => one(ins),
            Flatten => Ok(Shape::Flat(one(ins)?.numel())),
            Add => {
                if ins.len() != 2 {
                    anyhow::bail!("add `{}` expects 2 inputs, got {}", self.name, ins.len());
                }
                if ins[0] != ins[1] {
                    anyhow::bail!(
                        "add `{}` shape mismatch: {:?} vs {:?}",
                        self.name,
                        ins[0],
                        ins[1]
                    );
                }
                Ok(ins[0])
            }
        }
    }

    /// The reshaped weight-matrix dims if this is an MVM op.
    ///
    /// Requires shapes to be inferred (uses input shape for conv spatial
    /// dims), so it takes the producer's shape.
    pub fn mvm_dims(&self, input_shape: Shape) -> Option<MvmDims> {
        match &self.kind {
            OpKind::Conv2d {
                in_ch,
                out_ch,
                kh,
                kw,
                stride,
                pad,
                groups,
            } => {
                let (_, h, w) = match input_shape {
                    Shape::Chw(c, h, w) => (c, h, w),
                    _ => return None,
                };
                let oh = (h + 2 * pad - kh) / stride + 1;
                let ow = (w + 2 * pad - kw) / stride + 1;
                Some(MvmDims {
                    rows: (in_ch / groups) * kh * kw,
                    cols: out_ch / groups,
                    n_vectors: oh * ow,
                    groups: *groups,
                })
            }
            OpKind::Fc {
                in_features,
                out_features,
            } => Some(MvmDims {
                rows: *in_features,
                cols: *out_features,
                n_vectors: 1,
                groups: 1,
            }),
            _ => None,
        }
    }

    /// Element-wise work for post-processing ops (ops per inference).
    pub fn postproc_ops(&self, input_shapes: &[Shape]) -> u64 {
        match &self.kind {
            OpKind::Relu | OpKind::BatchNorm | OpKind::Flatten => {
                input_shapes.first().map(|s| s.numel() as u64).unwrap_or(0)
            }
            OpKind::Add => input_shapes.first().map(|s| s.numel() as u64).unwrap_or(0),
            OpKind::Pool { k, .. } => {
                // window reads per output element
                self.out_shape.numel() as u64 * (k * k) as u64
            }
            OpKind::GlobalAvgPool => input_shapes.first().map(|s| s.numel() as u64).unwrap_or(0),
            _ => 0,
        }
    }
}

/// Short human label for op kinds (reports, traces).
pub fn kind_label(kind: &OpKind) -> &'static str {
    match kind {
        OpKind::Input => "input",
        OpKind::Conv2d { groups, .. } if *groups > 1 => "dwconv",
        OpKind::Conv2d { .. } => "conv",
        OpKind::Fc { .. } => "fc",
        OpKind::Pool { .. } => "pool",
        OpKind::GlobalAvgPool => "gap",
        OpKind::Relu => "relu",
        OpKind::Add => "add",
        OpKind::BatchNorm => "bn",
        OpKind::Flatten => "flatten",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(in_ch: usize, out_ch: usize, k: usize, stride: usize, pad: usize) -> Op {
        Op {
            id: 0,
            name: "c".into(),
            kind: OpKind::Conv2d {
                in_ch,
                out_ch,
                kh: k,
                kw: k,
                stride,
                pad,
                groups: 1,
            },
            inputs: vec![],
            out_shape: Shape::Flat(0),
        }
    }

    #[test]
    fn conv_shape_inference() {
        let c = conv(3, 64, 3, 1, 1);
        let out = c.infer_shape(&[Shape::Chw(3, 32, 32)]).unwrap();
        assert_eq!(out, Shape::Chw(64, 32, 32));
        let c2 = conv(64, 128, 3, 2, 1);
        let out2 = c2.infer_shape(&[Shape::Chw(64, 32, 32)]).unwrap();
        assert_eq!(out2, Shape::Chw(128, 16, 16));
    }

    #[test]
    fn conv_shape_errors() {
        let c = conv(3, 64, 3, 1, 1);
        assert!(c.infer_shape(&[Shape::Chw(4, 32, 32)]).is_err());
        assert!(c.infer_shape(&[Shape::Flat(10)]).is_err());
        assert!(c.infer_shape(&[]).is_err());
    }

    #[test]
    fn mvm_dims_conv() {
        let c = conv(64, 128, 3, 1, 1);
        let d = c.mvm_dims(Shape::Chw(64, 16, 16)).unwrap();
        assert_eq!(d.rows, 64 * 9);
        assert_eq!(d.cols, 128);
        assert_eq!(d.n_vectors, 256);
        assert_eq!(d.macs(), (64 * 9) as u64 * 128 * 256);
    }

    #[test]
    fn mvm_dims_depthwise() {
        let c = Op {
            id: 0,
            name: "dw".into(),
            kind: OpKind::Conv2d {
                in_ch: 32,
                out_ch: 32,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
                groups: 32,
            },
            inputs: vec![],
            out_shape: Shape::Flat(0),
        };
        let d = c.mvm_dims(Shape::Chw(32, 8, 8)).unwrap();
        assert_eq!(d.rows, 9); // 1 channel per group
        assert_eq!(d.cols, 1);
        assert_eq!(d.groups, 32);
        assert_eq!(d.params(), 9 * 32);
    }

    #[test]
    fn fc_dims_and_shape() {
        let f = Op {
            id: 0,
            name: "fc".into(),
            kind: OpKind::Fc {
                in_features: 512,
                out_features: 100,
            },
            inputs: vec![],
            out_shape: Shape::Flat(0),
        };
        assert_eq!(f.infer_shape(&[Shape::Flat(512)]).unwrap(), Shape::Flat(100));
        // FC also accepts CHW that flattens to the right size
        assert_eq!(
            f.infer_shape(&[Shape::Chw(512, 1, 1)]).unwrap(),
            Shape::Flat(100)
        );
        let d = f.mvm_dims(Shape::Flat(512)).unwrap();
        assert_eq!((d.rows, d.cols, d.n_vectors), (512, 100, 1));
    }

    #[test]
    fn add_requires_matching_shapes() {
        let a = Op {
            id: 0,
            name: "add".into(),
            kind: OpKind::Add,
            inputs: vec![],
            out_shape: Shape::Flat(0),
        };
        assert!(a
            .infer_shape(&[Shape::Chw(8, 4, 4), Shape::Chw(8, 4, 4)])
            .is_ok());
        assert!(a
            .infer_shape(&[Shape::Chw(8, 4, 4), Shape::Chw(4, 4, 4)])
            .is_err());
    }

    #[test]
    fn pool_and_gap() {
        let p = Op {
            id: 0,
            name: "p".into(),
            kind: OpKind::Pool {
                kind: PoolKind::Max,
                k: 2,
                stride: 2,
            },
            inputs: vec![],
            out_shape: Shape::Flat(0),
        };
        assert_eq!(
            p.infer_shape(&[Shape::Chw(16, 8, 8)]).unwrap(),
            Shape::Chw(16, 4, 4)
        );
        let g = Op {
            id: 0,
            name: "g".into(),
            kind: OpKind::GlobalAvgPool,
            inputs: vec![],
            out_shape: Shape::Flat(0),
        };
        assert_eq!(g.infer_shape(&[Shape::Chw(16, 4, 4)]).unwrap(), Shape::Flat(16));
    }
}
