//! Built-in workload definitions ("model zoo"): the full-size networks
//! the paper evaluates (exact layer geometry) plus mini variants with
//! trained-weight artifacts for accuracy experiments.

mod mini;
mod mobilenet;
mod resnet;
mod vgg;

pub use mini::{mobilenet_mini, resnet_mini, vgg_mini, MINI_CLASSES, MINI_PX};
pub use mobilenet::mobilenetv2;
pub use resnet::{resnet18, resnet34, resnet50};
pub use vgg::{vgg11, vgg16, vgg19};

use crate::workload::graph::Network;

/// All zoo entries: (name, default constructor).
pub const ZOO_NAMES: [&str; 10] = [
    "resnet18",
    "resnet34",
    "resnet50",
    "vgg11",
    "vgg16",
    "vgg19",
    "mobilenetv2",
    "resnet_mini",
    "vgg_mini",
    "mobilenet_mini",
];

/// Look up a zoo network by name. Full-size models take
/// `input_px`/`classes`; minis ignore them (fixed 16 px / 10 classes).
pub fn by_name(name: &str, input_px: usize, classes: usize) -> anyhow::Result<Network> {
    Ok(match name {
        "resnet18" => resnet18(input_px, classes),
        "resnet34" => resnet34(input_px, classes),
        "resnet50" => resnet50(input_px, classes),
        "vgg11" => vgg11(input_px, classes),
        "vgg16" => vgg16(input_px, classes),
        "vgg19" => vgg19(input_px, classes),
        "mobilenetv2" => mobilenetv2(input_px, classes),
        "resnet_mini" => resnet_mini(),
        "vgg_mini" => vgg_mini(),
        "mobilenet_mini" => mobilenet_mini(),
        other => anyhow::bail!(
            "unknown zoo model `{other}` (available: {})",
            ZOO_NAMES.join(", ")
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_covers_all() {
        for name in ZOO_NAMES {
            let n = by_name(name, 32, 100).unwrap();
            assert!(!n.ops.is_empty(), "{name}");
        }
        assert!(by_name("nope", 32, 100).is_err());
    }
}
