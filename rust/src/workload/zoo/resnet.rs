//! ResNet-18 / ResNet-50 workload definitions with exact layer
//! dimensions. The cost model needs layer geometry only (weights live in
//! masks / artifacts), so these builders produce the full-size networks
//! used by the paper's evaluations (ResNet18 on CIFAR-100 for MARS
//! validation, ResNet50 on CIFAR-100/ImageNet for the use-cases).

use crate::workload::graph::Network;
use crate::workload::op::{OpId, Shape};

/// Stem: ImageNet inputs (>= 64 px) get 7×7/2 + maxpool; small inputs
/// (CIFAR) get the standard 3×3/1 CIFAR-ResNet stem.
fn stem(n: &mut Network, x: OpId, input_px: usize, out_ch: usize) -> OpId {
    if input_px >= 64 {
        let c = n.conv("conv1", x, 3, out_ch, 7, 2, 3);
        let b = n.bn("bn1", c);
        let r = n.relu("relu1", b);
        n.maxpool("maxpool", r, 3, 2)
    } else {
        let c = n.conv("conv1", x, 3, out_ch, 3, 1, 1);
        let b = n.bn("bn1", c);
        n.relu("relu1", b)
    }
}

/// Basic residual block (two 3×3 convs), ResNet-18/34 style.
fn basic_block(
    n: &mut Network,
    x: OpId,
    in_ch: usize,
    out_ch: usize,
    stride: usize,
    tag: &str,
) -> OpId {
    let c1 = n.conv(&format!("{tag}.conv1"), x, in_ch, out_ch, 3, stride, 1);
    let b1 = n.bn(&format!("{tag}.bn1"), c1);
    let r1 = n.relu(&format!("{tag}.relu1"), b1);
    let c2 = n.conv(&format!("{tag}.conv2"), r1, out_ch, out_ch, 3, 1, 1);
    let b2 = n.bn(&format!("{tag}.bn2"), c2);
    let short = if stride != 1 || in_ch != out_ch {
        let sc = n.conv(&format!("{tag}.downsample"), x, in_ch, out_ch, 1, stride, 0);
        n.bn(&format!("{tag}.downsample_bn"), sc)
    } else {
        x
    };
    let a = n.add(&format!("{tag}.add"), b2, short);
    n.relu(&format!("{tag}.relu2"), a)
}

/// Bottleneck residual block (1×1 → 3×3 → 1×1, expansion 4), ResNet-50 style.
fn bottleneck(
    n: &mut Network,
    x: OpId,
    in_ch: usize,
    mid_ch: usize,
    stride: usize,
    tag: &str,
) -> OpId {
    let out_ch = mid_ch * 4;
    let c1 = n.conv(&format!("{tag}.conv1"), x, in_ch, mid_ch, 1, 1, 0);
    let b1 = n.bn(&format!("{tag}.bn1"), c1);
    let r1 = n.relu(&format!("{tag}.relu1"), b1);
    let c2 = n.conv(&format!("{tag}.conv2"), r1, mid_ch, mid_ch, 3, stride, 1);
    let b2 = n.bn(&format!("{tag}.bn2"), c2);
    let r2 = n.relu(&format!("{tag}.relu2"), b2);
    let c3 = n.conv(&format!("{tag}.conv3"), r2, mid_ch, out_ch, 1, 1, 0);
    let b3 = n.bn(&format!("{tag}.bn3"), c3);
    let short = if stride != 1 || in_ch != out_ch {
        let sc = n.conv(&format!("{tag}.downsample"), x, in_ch, out_ch, 1, stride, 0);
        n.bn(&format!("{tag}.downsample_bn"), sc)
    } else {
        x
    };
    let a = n.add(&format!("{tag}.add"), b3, short);
    n.relu(&format!("{tag}.relu3"), a)
}

/// ResNet-18 for `input_px`×`input_px` RGB inputs and `classes` outputs.
pub fn resnet18(input_px: usize, classes: usize) -> Network {
    let mut n = Network::new(&format!("resnet18_{input_px}px"));
    let x = n.input(Shape::Chw(3, input_px, input_px));
    let mut h = stem(&mut n, x, input_px, 64);
    let cfg = [(64usize, 2usize), (128, 2), (256, 2), (512, 2)];
    let mut in_ch = 64;
    for (si, &(ch, blocks)) in cfg.iter().enumerate() {
        for b in 0..blocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            h = basic_block(&mut n, h, in_ch, ch, stride, &format!("layer{}.{}", si + 1, b));
            in_ch = ch;
        }
    }
    let g = n.gap("gap", h);
    n.fc("fc", g, 512, classes);
    n.infer_shapes().expect("resnet18 is well-formed");
    n
}

/// ResNet-34 for `input_px`×`input_px` RGB inputs and `classes` outputs.
pub fn resnet34(input_px: usize, classes: usize) -> Network {
    let mut n = Network::new(&format!("resnet34_{input_px}px"));
    let x = n.input(Shape::Chw(3, input_px, input_px));
    let mut h = stem(&mut n, x, input_px, 64);
    let cfg = [(64usize, 3usize), (128, 4), (256, 6), (512, 3)];
    let mut in_ch = 64;
    for (si, &(ch, blocks)) in cfg.iter().enumerate() {
        for b in 0..blocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            h = basic_block(&mut n, h, in_ch, ch, stride, &format!("layer{}.{}", si + 1, b));
            in_ch = ch;
        }
    }
    let g = n.gap("gap", h);
    n.fc("fc", g, 512, classes);
    n.infer_shapes().expect("resnet34 is well-formed");
    n
}

/// ResNet-50 for `input_px`×`input_px` RGB inputs and `classes` outputs.
pub fn resnet50(input_px: usize, classes: usize) -> Network {
    let mut n = Network::new(&format!("resnet50_{input_px}px"));
    let x = n.input(Shape::Chw(3, input_px, input_px));
    let mut h = stem(&mut n, x, input_px, 64);
    let cfg = [(64usize, 3usize), (128, 4), (256, 6), (512, 3)];
    let mut in_ch = 64;
    for (si, &(mid, blocks)) in cfg.iter().enumerate() {
        for b in 0..blocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            h = bottleneck(&mut n, h, in_ch, mid, stride, &format!("layer{}.{}", si + 1, b));
            in_ch = mid * 4;
        }
    }
    let g = n.gap("gap", h);
    n.fc("fc", g, 2048, classes);
    n.infer_shapes().expect("resnet50 is well-formed");
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::op::Shape;

    #[test]
    fn resnet18_imagenet_params() {
        let n = resnet18(224, 1000);
        let s = n.stats();
        // torchvision resnet18: 11.69 M params total; conv+fc (no bn) ≈ 11.68 M
        let m = s.params as f64 / 1e6;
        assert!((11.0..12.0).contains(&m), "params = {m} M");
        // ≈ 1.82 GMACs
        let g = s.macs as f64 / 1e9;
        assert!((1.6..2.0).contains(&g), "macs = {g} G");
    }

    #[test]
    fn resnet50_imagenet_params() {
        let n = resnet50(224, 1000);
        let s = n.stats();
        let m = s.params as f64 / 1e6;
        // torchvision resnet50: 25.56 M total; conv+fc ≈ 25.5 M
        assert!((24.5..26.0).contains(&m), "params = {m} M");
        let g = s.macs as f64 / 1e9;
        // ≈ 4.1 GMACs
        assert!((3.7..4.5).contains(&g), "macs = {g} G");
    }

    #[test]
    fn resnet34_imagenet_params() {
        let n = resnet34(224, 1000);
        let m = n.stats().params as f64 / 1e6;
        // torchvision resnet34: 21.80 M params
        assert!((20.5..22.5).contains(&m), "params = {m} M");
    }

    #[test]
    fn resnet50_cifar_shapes() {
        let n = resnet50(32, 100);
        assert_eq!(n.ops.last().unwrap().out_shape, Shape::Flat(100));
        // CIFAR stem: no downsample before layer1 → final maps 4x4 before GAP
        let gap_in = n.input_shape(n.ops.len() - 2).unwrap();
        assert_eq!(gap_in, Shape::Chw(2048, 4, 4));
    }

    #[test]
    fn resnet18_layer_count() {
        let n = resnet18(32, 100);
        let s = n.stats();
        // 1 stem + 16 block convs + 3 downsample convs = 20 convs, 1 fc
        assert_eq!(s.n_conv, 20);
        assert_eq!(s.n_fc, 1);
    }
}
