//! Mini model variants for the *accuracy* axis of the paper's experiments.
//!
//! The full-size models (resnet50/vgg16/mobilenetv2) give the cost model
//! its layer geometry, but measuring pruned-model accuracy requires
//! trained weights. The paper trains on CIFAR-100/ImageNet; this
//! reproduction substitutes SynthCIFAR-trained mini networks with the
//! same structural features (residual adds, FC-heavy classifier,
//! depthwise convs — see DESIGN.md §3).
//!
//! IMPORTANT: these definitions must stay byte-for-byte consistent with
//! `python/compile/models.py`, which trains the same graphs in JAX and
//! exports their weights. `integration_runtime.rs` asserts the parameter
//! layout matches the artifact manifest.

use crate::workload::graph::Network;
use crate::workload::op::{OpId, Shape};

/// SynthCIFAR input resolution and class count shared with python/compile.
pub const MINI_PX: usize = 16;
pub const MINI_CLASSES: usize = 10;

fn basic_block(n: &mut Network, x: OpId, in_ch: usize, out_ch: usize, stride: usize, tag: &str) -> OpId {
    let c1 = n.conv(&format!("{tag}.conv1"), x, in_ch, out_ch, 3, stride, 1);
    let r1 = n.relu(&format!("{tag}.relu1"), c1);
    let c2 = n.conv(&format!("{tag}.conv2"), r1, out_ch, out_ch, 3, 1, 1);
    let short = if stride != 1 || in_ch != out_ch {
        n.conv(&format!("{tag}.down"), x, in_ch, out_ch, 1, stride, 0)
    } else {
        x
    };
    let a = n.add(&format!("{tag}.add"), c2, short);
    n.relu(&format!("{tag}.relu2"), a)
}

/// ResNet-mini: 3×16×16 → stem(16) → 2×block(16) → 2×block(32, /2) → GAP → FC(10).
/// Residual structure mirrors ResNet50's role in the experiments.
pub fn resnet_mini() -> Network {
    let mut n = Network::new("resnet_mini");
    let x = n.input(Shape::Chw(3, MINI_PX, MINI_PX));
    let c0 = n.conv("stem", x, 3, 16, 3, 1, 1);
    let mut h = n.relu("stem_relu", c0);
    h = basic_block(&mut n, h, 16, 16, 1, "layer1.0");
    h = basic_block(&mut n, h, 16, 16, 1, "layer1.1");
    h = basic_block(&mut n, h, 16, 32, 2, "layer2.0");
    h = basic_block(&mut n, h, 32, 32, 1, "layer2.1");
    let g = n.gap("gap", h);
    n.fc("fc", g, 32, MINI_CLASSES);
    n.infer_shapes().expect("resnet_mini is well-formed");
    n
}

/// VGG-mini: two conv blocks then an FC-heavy classifier (512→128→10),
/// mirroring VGG16's FC-dominated parameter profile.
pub fn vgg_mini() -> Network {
    let mut n = Network::new("vgg_mini");
    let x = n.input(Shape::Chw(3, MINI_PX, MINI_PX));
    let c1 = n.conv("conv1_1", x, 3, 16, 3, 1, 1);
    let r1 = n.relu("relu1_1", c1);
    let c2 = n.conv("conv1_2", r1, 16, 16, 3, 1, 1);
    let r2 = n.relu("relu1_2", c2);
    let p1 = n.maxpool("pool1", r2, 2, 2);
    let c3 = n.conv("conv2_1", p1, 16, 32, 3, 1, 1);
    let r3 = n.relu("relu2_1", c3);
    let c4 = n.conv("conv2_2", r3, 32, 32, 3, 1, 1);
    let r4 = n.relu("relu2_2", c4);
    let p2 = n.maxpool("pool2", r4, 2, 2);
    let f = n.flatten("flatten", p2);
    let f1 = n.fc("fc1", f, 32 * 4 * 4, 128);
    let rf = n.relu("relu_fc1", f1);
    n.fc("fc2", rf, 128, MINI_CLASSES);
    n.infer_shapes().expect("vgg_mini is well-formed");
    n
}

/// MobileNet-mini: stem + two inverted-residual blocks (with depthwise
/// convs) + head, mirroring MobileNetV2's depthwise-dominated structure.
pub fn mobilenet_mini() -> Network {
    let mut n = Network::new("mobilenet_mini");
    let x = n.input(Shape::Chw(3, MINI_PX, MINI_PX));
    let c0 = n.conv("stem", x, 3, 16, 3, 1, 1);
    let mut h = n.relu("stem_relu", c0);
    // block1: expand 16→32, dw, project →16, residual
    let e1 = n.conv("block1.expand", h, 16, 32, 1, 1, 0);
    let re1 = n.relu("block1.expand_relu", e1);
    let d1 = n.dwconv("block1.dw", re1, 32, 3, 1, 1);
    let rd1 = n.relu("block1.dw_relu", d1);
    let p1 = n.conv("block1.project", rd1, 32, 16, 1, 1, 0);
    h = n.add("block1.add", p1, h);
    // block2: expand 16→32, dw stride 2, project →32 (no residual)
    let e2 = n.conv("block2.expand", h, 16, 32, 1, 1, 0);
    let re2 = n.relu("block2.expand_relu", e2);
    let d2 = n.dwconv("block2.dw", re2, 32, 3, 2, 1);
    let rd2 = n.relu("block2.dw_relu", d2);
    h = n.conv("block2.project", rd2, 32, 32, 1, 1, 0);
    // head
    let ch = n.conv("head", h, 32, 64, 1, 1, 0);
    let rh = n.relu("head_relu", ch);
    let g = n.gap("gap", rh);
    n.fc("classifier", g, 64, MINI_CLASSES);
    n.infer_shapes().expect("mobilenet_mini is well-formed");
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minis_are_well_formed() {
        for net in [resnet_mini(), vgg_mini(), mobilenet_mini()] {
            assert_eq!(net.ops.last().unwrap().out_shape, Shape::Flat(MINI_CLASSES));
            assert!(net.stats().macs > 0);
        }
    }

    #[test]
    fn vgg_mini_is_fc_heavy() {
        let n = vgg_mini();
        let (mut fc, mut conv) = (0u64, 0u64);
        for id in n.mvm_ops() {
            let p = n.mvm_dims(id).unwrap().params();
            if matches!(n.ops[id].kind, crate::workload::op::OpKind::Fc { .. }) {
                fc += p;
            } else {
                conv += p;
            }
        }
        assert!(fc * 2 > conv, "fc={fc} conv={conv}");
    }

    #[test]
    fn mobilenet_mini_has_depthwise() {
        let n = mobilenet_mini();
        assert_eq!(n.stats().n_dwconv, 2);
    }

    #[test]
    fn resnet_mini_param_count_is_small() {
        let n = resnet_mini();
        let p = n.stats().params;
        assert!(p < 100_000, "mini model stays mini: {p}");
        assert!(p > 10_000);
    }
}
