//! VGG-16 workload definition. VGG is the paper's FC-heavy model: the
//! three classifier layers dominate the parameter count, which drives the
//! weight-duplication finding in Fig. 11 (duplication hurts FC-heavy
//! models) and the "pruning FC layers hurts accuracy" note on Fig. 9(b).

use crate::workload::graph::Network;
use crate::workload::op::Shape;

fn vgg_from_cfg(name: &str, input_px: usize, classes: usize, cfg: &[&[usize]]) -> Network {
    let mut n = Network::new(&format!("{name}_{input_px}px"));
    let x = n.input(Shape::Chw(3, input_px, input_px));
    let mut h = x;
    let mut in_ch = 3;
    let mut px = input_px;
    for (bi, block) in cfg.iter().enumerate() {
        for (ci, &ch) in block.iter().enumerate() {
            let c = n.conv(&format!("conv{}_{}", bi + 1, ci + 1), h, in_ch, ch, 3, 1, 1);
            let b = n.bn(&format!("bn{}_{}", bi + 1, ci + 1), c);
            h = n.relu(&format!("relu{}_{}", bi + 1, ci + 1), b);
            in_ch = ch;
        }
        h = n.maxpool(&format!("pool{}", bi + 1), h, 2, 2);
        px /= 2;
    }
    let flat = n.flatten("flatten", h);
    let feat = 512 * px * px;
    let f1 = n.fc("fc1", flat, feat, 4096);
    let r1 = n.relu("relu_fc1", f1);
    let f2 = n.fc("fc2", r1, 4096, 4096);
    let r2 = n.relu("relu_fc2", f2);
    n.fc("fc3", r2, 4096, classes);
    n.infer_shapes().expect("vgg is well-formed");
    n
}

/// VGG-16 (configuration D) for `input_px`×`input_px` RGB inputs.
///
/// For 224 px inputs the classifier is the ImageNet 25088→4096→4096→C;
/// for small (CIFAR) inputs the feature map flattens to 512 but the two
/// 4096-wide hidden FC layers are kept, matching common CIFAR-VGG16
/// variants and preserving the FC-heavy parameter profile.
pub fn vgg16(input_px: usize, classes: usize) -> Network {
    vgg_from_cfg(
        "vgg16",
        input_px,
        classes,
        &[
            &[64, 64],
            &[128, 128],
            &[256, 256, 256],
            &[512, 512, 512],
            &[512, 512, 512],
        ],
    )
}

/// VGG-11 (configuration A): the shallow end of the family.
pub fn vgg11(input_px: usize, classes: usize) -> Network {
    vgg_from_cfg(
        "vgg11",
        input_px,
        classes,
        &[&[64], &[128], &[256, 256], &[512, 512], &[512, 512]],
    )
}

/// VGG-19 (configuration E): the deep end of the family.
pub fn vgg19(input_px: usize, classes: usize) -> Network {
    vgg_from_cfg(
        "vgg19",
        input_px,
        classes,
        &[
            &[64, 64],
            &[128, 128],
            &[256, 256, 256, 256],
            &[512, 512, 512, 512],
            &[512, 512, 512, 512],
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_imagenet_params() {
        let n = vgg16(224, 1000);
        let s = n.stats();
        let m = s.params as f64 / 1e6;
        // torchvision vgg16: 138.36 M params
        assert!((135.0..140.0).contains(&m), "params = {m} M");
        let g = s.macs as f64 / 1e9;
        // ≈ 15.5 GMACs
        assert!((14.5..16.5).contains(&g), "macs = {g} G");
    }

    #[test]
    fn vgg16_is_fc_heavy() {
        let n = vgg16(32, 100);
        let mut conv_params = 0u64;
        let mut fc_params = 0u64;
        for id in n.mvm_ops() {
            let d = n.mvm_dims(id).unwrap();
            if matches!(n.ops[id].kind, crate::workload::op::OpKind::Fc { .. }) {
                fc_params += d.params();
            } else {
                conv_params += d.params();
            }
        }
        assert!(
            fc_params > conv_params,
            "fc={fc_params} conv={conv_params}: VGG classifier must dominate"
        );
    }

    #[test]
    fn vgg16_layer_counts() {
        let n = vgg16(32, 100);
        let s = n.stats();
        assert_eq!(s.n_conv, 13);
        assert_eq!(s.n_fc, 3);
    }

    #[test]
    fn vgg_family_depths() {
        assert_eq!(vgg11(32, 10).stats().n_conv, 8);
        assert_eq!(vgg19(32, 10).stats().n_conv, 16);
        // vgg19 ≈ 143.7 M params on ImageNet
        let m = vgg19(224, 1000).stats().params as f64 / 1e6;
        assert!((140.0..147.0).contains(&m), "params = {m} M");
        // family ordering by compute
        let a = vgg11(32, 10).stats().macs;
        let b = vgg16(32, 10).stats().macs;
        let c = vgg19(32, 10).stats().macs;
        assert!(a < b && b < c);
    }
}
