//! MobileNetV2 workload definition. The depthwise convolutions are the
//! paper's problem child: their tiny reshaped weight matrices (9×1 per
//! group) map poorly onto CIM arrays and pruning them destroys accuracy
//! (Fig. 9(b)), so the use-case restricts pruning to standard convs.

use crate::workload::graph::Network;
use crate::workload::op::{OpId, Shape};

/// Inverted residual block: 1×1 expand → 3×3 depthwise → 1×1 project,
/// with a residual add when stride == 1 and channels match.
fn inverted_residual(
    n: &mut Network,
    x: OpId,
    in_ch: usize,
    out_ch: usize,
    stride: usize,
    expand: usize,
    tag: &str,
) -> OpId {
    let mid = in_ch * expand;
    let mut h = x;
    if expand != 1 {
        let c = n.conv(&format!("{tag}.expand"), h, in_ch, mid, 1, 1, 0);
        let b = n.bn(&format!("{tag}.expand_bn"), c);
        h = n.relu(&format!("{tag}.expand_relu"), b);
    }
    let dw = n.dwconv(&format!("{tag}.dw"), h, mid, 3, stride, 1);
    let bdw = n.bn(&format!("{tag}.dw_bn"), dw);
    let rdw = n.relu(&format!("{tag}.dw_relu"), bdw);
    let proj = n.conv(&format!("{tag}.project"), rdw, mid, out_ch, 1, 1, 0);
    let bproj = n.bn(&format!("{tag}.project_bn"), proj);
    if stride == 1 && in_ch == out_ch {
        n.add(&format!("{tag}.add"), bproj, x)
    } else {
        bproj
    }
}

/// MobileNetV2 (width 1.0). For small inputs (CIFAR) the stem stride and
/// the first two stage strides are reduced, the standard CIFAR adaptation.
pub fn mobilenetv2(input_px: usize, classes: usize) -> Network {
    let mut n = Network::new(&format!("mobilenetv2_{input_px}px"));
    let x = n.input(Shape::Chw(3, input_px, input_px));
    let small = input_px < 64;
    // (expand, out_ch, repeats, stride)
    let cfg: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, if small { 1 } else { 2 }),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let stem_stride = if small { 1 } else { 2 };
    let c0 = n.conv("stem", x, 3, 32, 3, stem_stride, 1);
    let b0 = n.bn("stem_bn", c0);
    let mut h = n.relu("stem_relu", b0);
    let mut in_ch = 32;
    for (bi, &(t, c, reps, s)) in cfg.iter().enumerate() {
        for r in 0..reps {
            let stride = if r == 0 { s } else { 1 };
            h = inverted_residual(
                &mut n,
                h,
                in_ch,
                c,
                stride,
                t,
                &format!("block{}.{}", bi + 1, r),
            );
            in_ch = c;
        }
    }
    let ch = n.conv("head", h, 320, 1280, 1, 1, 0);
    let bh = n.bn("head_bn", ch);
    let rh = n.relu("head_relu", bh);
    let g = n.gap("gap", rh);
    n.fc("classifier", g, 1280, classes);
    n.infer_shapes().expect("mobilenetv2 is well-formed");
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mobilenetv2_imagenet_params() {
        let n = mobilenetv2(224, 1000);
        let s = n.stats();
        let m = s.params as f64 / 1e6;
        // torchvision mobilenet_v2: 3.50 M params (paper quotes 3.4 M)
        assert!((3.2..3.7).contains(&m), "params = {m} M");
        let g = s.macs as f64 / 1e9;
        // ≈ 0.3 GMACs
        assert!((0.25..0.40).contains(&g), "macs = {g} G");
    }

    #[test]
    fn has_depthwise_layers() {
        let n = mobilenetv2(32, 100);
        let s = n.stats();
        assert_eq!(s.n_dwconv, 17); // one per inverted residual block
        assert!(s.n_conv > 30);
    }

    #[test]
    fn depthwise_mvm_dims_are_tiny() {
        let n = mobilenetv2(32, 100);
        for id in n.mvm_ops() {
            if let crate::workload::op::OpKind::Conv2d { groups, .. } = n.ops[id].kind {
                if groups > 1 {
                    let d = n.mvm_dims(id).unwrap();
                    assert_eq!(d.rows, 9, "depthwise rows per group");
                    assert_eq!(d.cols, 1);
                }
            }
        }
    }
}
