//! JSON model-graph interchange.
//!
//! The paper imports workloads from ONNX; `onnx` is unavailable offline,
//! so CIMinus defines an equivalent JSON schema (op type + dimensions +
//! edges) emitted by `python/compile/models.py::export_graph` and parsed
//! here. Export is also implemented for round-tripping and tooling.
//!
//! Schema: `{"name": str, "ops": [op...]}` where each op is
//! `{"name": str, "kind": str, "inputs": [int], ...kind-specific fields}`.
//! Op order must be topological; ids are implicit positions.

use super::graph::Network;
use super::op::{OpKind, PoolKind, Shape};
use crate::util::json::Json;

/// Parse a network from its JSON description.
pub fn network_from_json(j: &Json) -> anyhow::Result<Network> {
    let name = j.req_str("name")?;
    let mut net = Network::new(name);
    for (i, op_j) in j.req_arr("ops")?.iter().enumerate() {
        let op_name = op_j.req_str("name")?;
        let kind_s = op_j.req_str("kind")?;
        let inputs: Vec<usize> = match op_j.get("inputs") {
            Some(Json::Arr(a)) => a
                .iter()
                .map(|v| {
                    v.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("op `{op_name}`: inputs must be non-negative ints"))
                })
                .collect::<anyhow::Result<_>>()?,
            None if kind_s == "input" => vec![],
            _ => anyhow::bail!("op `{op_name}`: missing `inputs` array"),
        };
        let kind = match kind_s {
            "input" => {
                let shape = op_j.req_arr("shape")?;
                if shape.len() != 3 {
                    anyhow::bail!("input `{op_name}`: shape must be [c,h,w]");
                }
                let dims: Vec<usize> = shape
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape dim")))
                    .collect::<anyhow::Result<_>>()?;
                let id = net.input(Shape::Chw(dims[0], dims[1], dims[2]));
                net.ops[id].name = op_name.to_string();
                continue;
            }
            "conv2d" => OpKind::Conv2d {
                in_ch: op_j.req_usize("in_ch")?,
                out_ch: op_j.req_usize("out_ch")?,
                kh: op_j.req_usize("kh")?,
                kw: op_j.req_usize("kw")?,
                stride: op_j.opt_usize("stride", 1),
                pad: op_j.opt_usize("pad", 0),
                groups: op_j.opt_usize("groups", 1),
            },
            "fc" => OpKind::Fc {
                in_features: op_j.req_usize("in_features")?,
                out_features: op_j.req_usize("out_features")?,
            },
            "pool" => OpKind::Pool {
                kind: match op_j.opt_str("pool", "max") {
                    "max" => PoolKind::Max,
                    "avg" => PoolKind::Avg,
                    other => anyhow::bail!("op `{op_name}`: unknown pool kind `{other}`"),
                },
                k: op_j.req_usize("k")?,
                stride: op_j.req_usize("stride")?,
            },
            "gap" => OpKind::GlobalAvgPool,
            "relu" => OpKind::Relu,
            "add" => OpKind::Add,
            "bn" => OpKind::BatchNorm,
            "flatten" => OpKind::Flatten,
            other => anyhow::bail!("op `{op_name}` (#{i}): unknown kind `{other}`"),
        };
        let id = net.ops.len();
        net.ops.push(super::op::Op {
            id,
            name: op_name.to_string(),
            kind,
            inputs,
            out_shape: Shape::Flat(0),
        });
    }
    net.infer_shapes()?;
    Ok(net)
}

/// Load a network from a JSON file.
pub fn network_from_file(path: &std::path::Path) -> anyhow::Result<Network> {
    network_from_json(&Json::parse_file(path)?)
}

/// Serialize a network to the interchange schema.
pub fn network_to_json(net: &Network) -> Json {
    let ops: Vec<Json> = net
        .ops
        .iter()
        .map(|op| {
            let mut o = Json::obj();
            o.set("name", Json::Str(op.name.clone()));
            let inputs = Json::Arr(op.inputs.iter().map(|&i| Json::Num(i as f64)).collect());
            match &op.kind {
                OpKind::Input => {
                    o.set("kind", Json::Str("input".into()));
                    if let Shape::Chw(c, h, w) = op.out_shape {
                        o.set(
                            "shape",
                            Json::Arr(vec![
                                Json::Num(c as f64),
                                Json::Num(h as f64),
                                Json::Num(w as f64),
                            ]),
                        );
                    }
                }
                OpKind::Conv2d {
                    in_ch,
                    out_ch,
                    kh,
                    kw,
                    stride,
                    pad,
                    groups,
                } => {
                    o.set("kind", Json::Str("conv2d".into()));
                    o.set("inputs", inputs);
                    o.set("in_ch", Json::Num(*in_ch as f64));
                    o.set("out_ch", Json::Num(*out_ch as f64));
                    o.set("kh", Json::Num(*kh as f64));
                    o.set("kw", Json::Num(*kw as f64));
                    o.set("stride", Json::Num(*stride as f64));
                    o.set("pad", Json::Num(*pad as f64));
                    o.set("groups", Json::Num(*groups as f64));
                }
                OpKind::Fc {
                    in_features,
                    out_features,
                } => {
                    o.set("kind", Json::Str("fc".into()));
                    o.set("inputs", inputs);
                    o.set("in_features", Json::Num(*in_features as f64));
                    o.set("out_features", Json::Num(*out_features as f64));
                }
                OpKind::Pool { kind, k, stride } => {
                    o.set("kind", Json::Str("pool".into()));
                    o.set("inputs", inputs);
                    o.set(
                        "pool",
                        Json::Str(match kind {
                            PoolKind::Max => "max".into(),
                            PoolKind::Avg => "avg".into(),
                        }),
                    );
                    o.set("k", Json::Num(*k as f64));
                    o.set("stride", Json::Num(*stride as f64));
                }
                simple => {
                    let label = match simple {
                        OpKind::GlobalAvgPool => "gap",
                        OpKind::Relu => "relu",
                        OpKind::Add => "add",
                        OpKind::BatchNorm => "bn",
                        OpKind::Flatten => "flatten",
                        _ => unreachable!(),
                    };
                    o.set("kind", Json::Str(label.into()));
                    o.set("inputs", inputs);
                }
            }
            o
        })
        .collect();
    let mut root = Json::obj();
    root.set("name", Json::Str(net.name.clone()));
    root.set("ops", Json::Arr(ops));
    root
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn roundtrip_all_zoo_models() {
        for name in zoo::ZOO_NAMES {
            let net = zoo::by_name(name, 32, 100).unwrap();
            let j = network_to_json(&net);
            let net2 = network_from_json(&j).unwrap();
            assert_eq!(net.ops.len(), net2.ops.len(), "{name}");
            for (a, b) in net.ops.iter().zip(&net2.ops) {
                assert_eq!(a.kind, b.kind, "{name}/{}", a.name);
                assert_eq!(a.inputs, b.inputs);
                assert_eq!(a.out_shape, b.out_shape);
            }
            assert_eq!(net.stats(), net2.stats(), "{name}");
        }
    }

    #[test]
    fn parse_minimal() {
        let src = r#"{
            "name": "m",
            "ops": [
                {"name": "x", "kind": "input", "shape": [3, 8, 8]},
                {"name": "c", "kind": "conv2d", "inputs": [0],
                 "in_ch": 3, "out_ch": 4, "kh": 3, "kw": 3, "stride": 1, "pad": 1},
                {"name": "g", "kind": "gap", "inputs": [1]},
                {"name": "f", "kind": "fc", "inputs": [2], "in_features": 4, "out_features": 2}
            ]
        }"#;
        let net = network_from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(net.ops.len(), 4);
        assert_eq!(net.ops[3].out_shape, Shape::Flat(2));
    }

    #[test]
    fn rejects_bad_kind_and_missing_fields() {
        let bad_kind = r#"{"name":"m","ops":[{"name":"x","kind":"wat","inputs":[]}]}"#;
        assert!(network_from_json(&Json::parse(bad_kind).unwrap()).is_err());
        let missing = r#"{"name":"m","ops":[{"name":"x","kind":"input","shape":[3,8,8]},
            {"name":"c","kind":"conv2d","inputs":[0],"in_ch":3}]}"#;
        assert!(network_from_json(&Json::parse(missing).unwrap()).is_err());
    }
}
