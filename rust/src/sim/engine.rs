//! The cycle-level simulation engine (Sec. V): walks the mapped network
//! op by op, round by round, producing pipeline step latencies (Eq. 3),
//! per-unit access counts (Eq. 5/6 inputs) and utilization/skip
//! statistics, then aggregates energy (Eq. 4–7).

use super::access::Counters;
use super::energy::aggregate;
use super::input_sparsity::InputProfiles;
use super::pipeline::{pipeline_latency, StepLat};
use super::report::{OpReport, SimReport};
use crate::eval::{Evaluator, Scenario};
use crate::hw::arch::Architecture;
use crate::hw::units::UnitKind;
use crate::mapping::planner::MappingPlan;
use crate::sparsity::flexblock::FlexBlock;
use crate::workload::graph::Network;
use crate::workload::op::kind_label;

/// Simulation knobs.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Elements per cycle sustained by each post-processing lane.
    pub postproc_throughput: usize,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self {
            postproc_throughput: 4,
        }
    }
}

/// Simulate a mapped network on an architecture.
///
/// `profiles` supplies activation statistics for input-sparsity skipping
/// (ignored unless `arch.sparsity.input_skipping`); `None` disables
/// skipping (dense bit-serial execution).
pub fn simulate(
    arch: &Architecture,
    net: &Network,
    mapping: &MappingPlan,
    profiles: Option<&InputProfiles>,
    opts: SimOptions,
) -> anyhow::Result<SimReport> {
    // Validation is hoisted into `eval::Evaluator` construction (paid
    // once per distinct architecture) — callers reach simulate()
    // through the evaluator or via plan(), both of which validate.
    debug_assert!(
        arch.validate().is_ok(),
        "simulate() expects a pre-validated architecture"
    );
    let input_bits = arch.input_bits;
    let sub_rows = arch.cim.sub_rows;
    let sub_cols = arch.cim.sub_cols;
    let mut counters = Counters::new();
    let mut steps: Vec<StepLat> = Vec::new();
    let mut op_reports: Vec<OpReport> = Vec::new();
    let mut util_num = 0.0;
    let mut util_den = 0.0;
    let mut skip_num = 0.0;
    let mut skip_den = 0.0;
    let mut index_bytes_total = 0u64;

    for op in &net.ops {
        if let Some(m) = mapping.ops.get(&op.id) {
            // ---------- MVM op on CIM macros ----------
            let layout = &m.layout;
            let dims = &m.dims;
            // Broadcast group for OR-skip: each sub-array row window sees
            // sub_rows physical rows × `broadcast` candidates per row.
            let skip_group = sub_rows * layout.broadcast;
            let eff_bits = if arch.sparsity.input_skipping {
                match profiles.and_then(|p| p.profile_for(op.id)) {
                    Some(p) => p.group_active_bits(skip_group),
                    None => input_bits as f64,
                }
            } else {
                input_bits as f64
            };
            let skip_ratio = 1.0 - eff_bits / input_bits as f64;
            skip_num += skip_ratio * dims.macs() as f64;
            skip_den += dims.macs() as f64;
            index_bytes_total += m.index.total_bytes();

            let op_occupied: u64 = m
                .tiling
                .rounds
                .iter()
                .map(|r| r.occupied_cells())
                .sum::<u64>()
                .max(1);
            let mut op_cycles = 0u64;

            // Rearrangement overhead (Fig. 12): shuffling ragged rows
            // costs a read + write through the weight buffer per moved
            // byte, paid once before the op's first round.
            if m.rearrange_moved_bytes > 0 {
                let acc = arch.weight_buf.accesses_for(m.rearrange_moved_bytes);
                counters.add_read(UnitKind::WeightBuf, acc);
                counters.add_write(UnitKind::WeightBuf, acc);
                let shuffle_cycles =
                    2 * arch.weight_buf.transfer_cycles(m.rearrange_moved_bytes);
                steps.push(StepLat {
                    load: shuffle_cycles,
                    comp: 0,
                    wb: 0,
                });
                op_cycles += shuffle_cycles;
            }

            // Fault-repair overhead: weights displaced off faulty
            // rows/columns/macros are re-staged through the weight
            // buffer (read + write per byte), paid once per op like
            // rearrangement.
            if m.fault_moved_bytes > 0 {
                let acc = arch.weight_buf.accesses_for(m.fault_moved_bytes);
                counters.add_read(UnitKind::WeightBuf, acc);
                counters.add_write(UnitKind::WeightBuf, acc);
                let repair_cycles = 2 * arch.weight_buf.transfer_cycles(m.fault_moved_bytes);
                steps.push(StepLat {
                    load: repair_cycles,
                    comp: 0,
                    wb: 0,
                });
                op_cycles += repair_cycles;
            }

            for round in &m.tiling.rounds {
                let vecs = round.vectors_per_macro as u64;
                // ---- latency components ----
                // weight delivery: bounded by the shared weight-buffer
                // bandwidth (design-specific banking) AND the slowest
                // macro's local fill port
                let max_tile_bytes = round
                    .tiles
                    .iter()
                    .map(|t| t.occupied * arch.weight_bits as u64 / 8)
                    .max()
                    .unwrap_or(0);
                let per_macro = arch.local_buf.transfer_cycles(max_tile_bytes);
                let shared = arch.weight_buf.transfer_cycles(round.weight_bytes);
                let w_load = per_macro.max(shared);
                let idx_bytes_round = (m.index.total_bytes() as f64
                    * round.occupied_cells() as f64
                    / op_occupied as f64) as u64;
                let idx_load = arch.index_mem.transfer_cycles(idx_bytes_round);
                // weights stream into macros from the weight buffer while
                // indices configure the routing fabric — parallel paths
                let load = w_load.max(idx_load);
                let comp = (vecs as f64 * eff_bits).ceil() as u64;
                let out_bytes = round.outputs * input_bits as u64 / 8;
                let wb = arch.global_out_buf.transfer_cycles(out_bytes);
                steps.push(StepLat { load, comp, wb });
                op_cycles += load.max(comp) + wb; // op-attributed approximation

                // ---- access counting ----
                let ebits = eff_bits;
                let mut cim = 0f64;
                let mut tree = 0f64;
                let mut shift = 0f64;
                let mut acc = 0u64;
                let mut mux = 0f64;
                let mut zdet = 0u64;
                for t in &round.tiles {
                    let v = vecs as f64;
                    // full-array activation (Sec. II-A): every cell in the
                    // activated bounding box participates in the cycle;
                    // cells holding no weight still pay wordline/precharge
                    // energy (~30% of an active cell). This is the
                    // fragmentation penalty of misaligned patterns.
                    let boxed = (t.rows_used * t.cols_used) as f64;
                    let occ = t.occupied as f64;
                    cim += (occ + 0.3 * (boxed - occ)) * v * ebits;
                    let n_sub = (t.rows_used.div_ceil(sub_rows)
                        * t.cols_used.div_ceil(sub_cols)) as f64;
                    tree += n_sub * v * ebits;
                    shift += t.cols_used as f64 * v * ebits;
                    // accumulate partial sums across row groups
                    acc += t.cols_used as u64 * vecs * t.rows_used.div_ceil(sub_rows) as u64;
                    if layout.misaligned_cols {
                        // irregular partial-sum aggregation (Sec. V-B)
                        acc += t.cols_used as u64 * vecs;
                    }
                    if layout.routed_rows && arch.sparsity.weight_routing {
                        mux += t.rows_used as f64 * v * ebits;
                    }
                    if arch.sparsity.input_skipping {
                        zdet += t.rows_used.div_ceil(sub_rows) as u64
                            * vecs
                            * input_bits as u64;
                    }
                }
                counters.add_compute(UnitKind::CimArray, cim as u64);
                counters.add_compute(UnitKind::AdderTree, tree as u64);
                counters.add_compute(UnitKind::ShiftAdd, shift as u64);
                counters.add_compute(UnitKind::Accumulator, acc);
                counters.add_compute(UnitKind::Mux, mux as u64);
                counters.add_compute(UnitKind::ZeroDetect, zdet);

                // pre-processing: every distinct input value is converted
                // to bit-serial once (all bits, conversion is not skipped)
                let distinct_inputs =
                    round.input_rows * layout.broadcast as u64 * vecs;
                counters.add_compute(
                    UnitKind::PreProc,
                    distinct_inputs * input_bits as u64,
                );
                // global-buffer traffic: overlapping im2col windows are
                // regenerated from line buffers, so each feature-map
                // value is read once (kh·kw reuse for convs)
                let im2col_reuse = match &op.kind {
                    crate::workload::op::OpKind::Conv2d { kh, kw, .. } => (kh * kw) as u64,
                    _ => 1,
                };

                // memory traffic
                counters.add_read(
                    UnitKind::WeightBuf,
                    arch.weight_buf.accesses_for(round.weight_bytes),
                );
                counters.add_read(
                    UnitKind::IndexMem,
                    arch.index_mem.accesses_for(idx_bytes_round),
                );
                let in_bytes = distinct_inputs * input_bits as u64 / 8 / im2col_reuse;
                counters.add_read(
                    UnitKind::GlobalInBuf,
                    arch.global_in_buf.accesses_for(in_bytes),
                );
                counters.add_write(
                    UnitKind::GlobalOutBuf,
                    arch.global_out_buf.accesses_for(out_bytes),
                );
                // local psum staging: write + read per output value
                counters.add_write(UnitKind::LocalBuf, round.outputs);
                counters.add_read(UnitKind::LocalBuf, round.outputs);
            }

            util_num += m.tiling.utilization * m.tiling.rounds.len() as f64;
            util_den += m.tiling.rounds.len() as f64;
            op_reports.push(OpReport {
                op: op.id,
                name: op.name.clone(),
                kind: kind_label(&op.kind).to_string(),
                rounds: m.tiling.rounds.len(),
                cycles: op_cycles,
                utilization: m.tiling.utilization,
                eff_bits,
                macs: dims.macs(),
            });
        } else if !matches!(op.kind, crate::workload::op::OpKind::Input) {
            // ---------- post-processing op ----------
            let in_shapes: Vec<_> = op
                .inputs
                .iter()
                .map(|&i| net.ops[i].out_shape)
                .collect();
            let elems = op.postproc_ops(&in_shapes);
            if elems == 0 {
                continue;
            }
            counters.add_compute(UnitKind::PostProc, elems);
            let lanes = (arch.org.n_macros() * opts.postproc_throughput) as u64;
            let cycles = elems.div_ceil(lanes);
            // post ops stream from/to the feature buffers
            counters.add_read(
                UnitKind::GlobalInBuf,
                arch.global_in_buf
                    .accesses_for(elems * input_bits as u64 / 8),
            );
            counters.add_write(
                UnitKind::GlobalOutBuf,
                arch.global_out_buf
                    .accesses_for(op.out_shape.numel() as u64 * input_bits as u64 / 8),
            );
            steps.push(StepLat {
                load: 0,
                comp: cycles,
                wb: 0,
            });
            op_reports.push(OpReport {
                op: op.id,
                name: op.name.clone(),
                kind: kind_label(&op.kind).to_string(),
                rounds: 1,
                cycles,
                utilization: 0.0,
                eff_bits: 0.0,
                macs: 0,
            });
        }
    }

    let overlap_load = arch.global_in_buf.ping_pong || arch.weight_buf.ping_pong;
    let overlap_wb = arch.global_out_buf.ping_pong;
    let stage_totals = steps.iter().fold((0u64, 0u64, 0u64), |acc, s| {
        (acc.0 + s.load, acc.1 + s.comp, acc.2 + s.wb)
    });
    let total_cycles = pipeline_latency(&steps, overlap_load, overlap_wb).max(1);
    let energy = aggregate(arch, &counters, total_cycles);
    let latency_us = total_cycles as f64 * arch.cycle_ns() / 1000.0;

    Ok(SimReport {
        arch: arch.name.clone(),
        network: net.name.clone(),
        sparsity_label: mapping
            .ops
            .values()
            .find(|m| !m.fb.is_dense())
            .map(|m| m.fb.name.clone())
            .unwrap_or_else(|| "Dense".into()),
        total_cycles,
        latency_us,
        energy,
        counters,
        ops: op_reports,
        mean_utilization: if util_den == 0.0 {
            0.0
        } else {
            util_num / util_den
        },
        mean_skip_ratio: if skip_den == 0.0 {
            0.0
        } else {
            skip_num / skip_den
        },
        index_bytes: index_bytes_total,
        stage_totals,
        faults: mapping.faults.clone(),
        cache: None,
    })
}

/// Convenience one-call pipeline: uniform FlexBlock pruning (random
/// masks), default mapping, synthetic activation profiles.
pub fn simulate_network_default(
    arch: &Architecture,
    net: &Network,
    fb: Option<&FlexBlock>,
) -> anyhow::Result<SimReport> {
    let mut s = Scenario::new(arch.clone(), net.clone()).synthetic_profiles(
        arch.input_bits,
        0.5,
        0xC1A0,
    );
    if let Some(fb) = fb {
        s = s.prune_uniform(fb);
    }
    Evaluator::new().evaluate(&s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::workload::zoo;

    fn dense_report(net: &Network) -> SimReport {
        let arch = presets::usecase_dense_baseline(4, (2, 2));
        simulate_network_default(&arch, net, None).unwrap()
    }

    #[test]
    fn dense_sim_runs_and_counts() {
        let net = zoo::resnet_mini();
        let r = dense_report(&net);
        assert!(r.total_cycles > 0);
        assert!(r.energy.total_pj > 0.0);
        assert!(r.counters.compute_of(UnitKind::CimArray) > 0);
        assert_eq!(r.mean_skip_ratio, 0.0, "no skipping on dense baseline");
        assert_eq!(r.index_bytes, 0);
        // every MVM + post op reported
        assert!(r.ops.len() >= net.mvm_ops().len());
    }

    #[test]
    fn sparse_faster_and_cheaper_than_dense() {
        let net = zoo::vgg16(32, 100);
        let dense = dense_report(&net);
        let arch = presets::usecase_arch(4, (2, 2));
        let fb = FlexBlock::row_wise(0.8);
        let sparse = simulate_network_default(&arch, &net, Some(&fb)).unwrap();
        let speedup = sparse.speedup_vs(&dense);
        let saving = sparse.energy_saving_vs(&dense);
        assert!(speedup > 1.5, "speedup {speedup}");
        assert!(saving > 1.5, "saving {saving}");
        assert!(sparse.index_bytes > 0);
    }

    #[test]
    fn input_skipping_reduces_cycles() {
        let net = zoo::resnet_mini();
        let mut arch = presets::usecase_arch(4, (2, 2));
        arch.sparsity.input_skipping = false;
        let no_skip = simulate_network_default(&arch, &net, None).unwrap();
        arch.sparsity.input_skipping = true;
        let skip = simulate_network_default(&arch, &net, None).unwrap();
        assert!(
            skip.total_cycles < no_skip.total_cycles,
            "{} !< {}",
            skip.total_cycles,
            no_skip.total_cycles
        );
        assert!(skip.mean_skip_ratio > 0.0);
    }

    #[test]
    fn higher_sparsity_more_speedup() {
        let net = zoo::resnet50(32, 100);
        let dense = dense_report(&net);
        let arch = presets::usecase_arch(4, (2, 2));
        let s5 = simulate_network_default(&arch, &net, Some(&FlexBlock::row_wise(0.5))).unwrap();
        let s9 = simulate_network_default(&arch, &net, Some(&FlexBlock::row_wise(0.9))).unwrap();
        assert!(
            s9.speedup_vs(&dense) > s5.speedup_vs(&dense),
            "0.9: {} vs 0.5: {}",
            s9.speedup_vs(&dense),
            s5.speedup_vs(&dense)
        );
    }

    #[test]
    fn intra_pattern_pays_mux_overhead() {
        let net = zoo::resnet_mini();
        let arch = presets::usecase_arch(4, (2, 2));
        let coarse =
            simulate_network_default(&arch, &net, Some(&FlexBlock::row_wise(0.5))).unwrap();
        let intra =
            simulate_network_default(&arch, &net, Some(&FlexBlock::intra(2, 0.5))).unwrap();
        assert_eq!(coarse.counters.compute_of(UnitKind::Mux), 0);
        assert!(intra.counters.compute_of(UnitKind::Mux) > 0);
        // intra skips less input-sparsity (bigger broadcast groups)
        assert!(intra.mean_skip_ratio <= coarse.mean_skip_ratio + 1e-12);
    }

    #[test]
    fn energy_breakdown_dominated_by_array_or_buffers() {
        let net = zoo::resnet_mini();
        let arch = presets::usecase_arch(4, (2, 2));
        let r = simulate_network_default(&arch, &net, None).unwrap();
        let arr = r.energy.of(UnitKind::CimArray);
        assert!(arr > 0.0);
        let total = r.energy.total_pj;
        assert!(arr / total > 0.01, "array share {:.4}", arr / total);
    }

    #[test]
    fn deterministic() {
        let net = zoo::resnet_mini();
        let arch = presets::usecase_arch(4, (2, 2));
        let fb = FlexBlock::hybrid(2, 16, 0.8);
        let a = simulate_network_default(&arch, &net, Some(&fb)).unwrap();
        let b = simulate_network_default(&arch, &net, Some(&fb)).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.energy.total_pj, b.energy.total_pj);
    }
}
