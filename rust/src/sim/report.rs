//! Simulation results: overall latency, the Eq. 4–7 energy breakdown,
//! per-op detail, utilization and input-sparsity statistics.

use super::access::Counters;
use super::energy::EnergyBreakdown;
use crate::eval::cache::StageHit;
use crate::mapping::planner::FaultPlanSummary;
use crate::util::table::{fmt_cycles, fmt_energy_pj, Table};
use crate::workload::op::OpId;

/// Where each pipeline stage's artifact came from when this report was
/// produced (memory cache, disk store, or recomputed). Stamped by
/// [`crate::eval::Evaluator::evaluate`]; `None` on reports from a
/// direct `simulate()` call. Provenance only — excluded from
/// [`SimReport::content_digest`], so cached and fresh evaluations of
/// the same scenario stay bit-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheNote {
    /// `None` when the scenario had no prune stage to run.
    pub prune_hit: Option<StageHit>,
    pub mapping_hit: StageHit,
    /// `None` when the scenario had no profile stage to run.
    pub profiles_hit: Option<StageHit>,
    pub sim_hit: StageHit,
}

/// Per-op simulation detail.
#[derive(Debug, Clone)]
pub struct OpReport {
    pub op: OpId,
    pub name: String,
    pub kind: String,
    pub rounds: usize,
    pub cycles: u64,
    pub utilization: f64,
    /// Mean executed bit cycles per bit-serial pass (≤ input_bits).
    pub eff_bits: f64,
    /// Dense-equivalent MACs this op represents.
    pub macs: u64,
}

/// Full simulation report for one (architecture, network, sparsity,
/// mapping) configuration.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub arch: String,
    pub network: String,
    pub sparsity_label: String,
    pub total_cycles: u64,
    pub latency_us: f64,
    pub energy: EnergyBreakdown,
    pub counters: Counters,
    pub ops: Vec<OpReport>,
    /// Mean array utilization over rounds (idle macros count).
    pub mean_utilization: f64,
    /// MAC-weighted mean input-bit skip ratio (0 when skipping disabled).
    pub mean_skip_ratio: f64,
    /// Index memory footprint required by the mapping (Eq. 8 total).
    pub index_bytes: u64,
    /// Pre-overlap stage totals (Σ over pipeline steps) — the Eq. 3
    /// inputs, useful for diagnosing load- vs compute-bound workloads.
    pub stage_totals: (u64, u64, u64),
    /// Degradation summary when the mapping was built against a faulty
    /// chip; `None` on the fault-free path.
    pub faults: Option<FaultPlanSummary>,
    /// Artifact-cache provenance (see [`CacheNote`]).
    pub cache: Option<CacheNote>,
}

impl SimReport {
    /// Stable structural fingerprint of the simulation *content* —
    /// every field except the cache-provenance note, which varies
    /// between cached and fresh evaluations of the same scenario.
    pub fn content_digest(&self) -> u128 {
        let mut scrubbed = self.clone();
        scrubbed.cache = None;
        crate::eval::hash::fingerprint("sim-report", &scrubbed)
    }

    /// Speedup of `self` relative to `baseline` (> 1 = faster).
    pub fn speedup_vs(&self, baseline: &SimReport) -> f64 {
        baseline.total_cycles as f64 / self.total_cycles.max(1) as f64
    }

    /// Energy saving factor relative to `baseline` (> 1 = less energy).
    pub fn energy_saving_vs(&self, baseline: &SimReport) -> f64 {
        baseline.energy.total_pj / self.energy.total_pj.max(1e-12)
    }

    /// Multi-line human summary.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "=== {} on {} [{}] ===\n",
            self.network, self.arch, self.sparsity_label
        ));
        s.push_str(&format!(
            "latency : {} ({:.3} us)\n",
            fmt_cycles(self.total_cycles),
            self.latency_us
        ));
        s.push_str(&format!(
            "energy  : {} (dynamic {}, static {})\n",
            fmt_energy_pj(self.energy.total_pj),
            fmt_energy_pj(self.energy.dynamic_total()),
            fmt_energy_pj(self.energy.static_pj)
        ));
        s.push_str(&format!(
            "util    : {:.1}%   skip: {:.1}%   index mem: {} B\n",
            self.mean_utilization * 100.0,
            self.mean_skip_ratio * 100.0,
            self.index_bytes
        ));
        let (l, c, w) = self.stage_totals;
        s.push_str(&format!(
            "stages  : load {}  comp {}  wb {}\n",
            fmt_cycles(l),
            fmt_cycles(c),
            fmt_cycles(w)
        ));
        if let Some(f) = &self.faults {
            s.push_str(&format!(
                "faults  : {}/{} macros usable, array {}x{} of {}x{}, \
                 capacity loss {:.1}%, +{} rounds, repair {} B\n",
                f.usable_macros,
                f.total_macros,
                f.effective_geometry.0,
                f.effective_geometry.1,
                f.full_geometry.0,
                f.full_geometry.1,
                f.capacity_loss * 100.0,
                f.extra_rounds(),
                f.repair_bytes
            ));
        }
        s
    }

    /// Per-op table (the detailed view).
    pub fn op_table(&self) -> Table {
        let mut t = Table::new(&["op", "kind", "rounds", "cycles", "util%", "eff_bits", "MACs"])
            .with_title(&format!("{} per-op detail", self.network));
        for o in &self.ops {
            t.row(vec![
                o.name.clone(),
                o.kind.clone(),
                o.rounds.to_string(),
                o.cycles.to_string(),
                format!("{:.1}", o.utilization * 100.0),
                format!("{:.2}", o.eff_bits),
                o.macs.to_string(),
            ]);
        }
        t
    }

    /// Energy-breakdown table (Fig. 6(c)-style).
    pub fn energy_table(&self) -> Table {
        let mut t = Table::new(&["component", "energy", "share%"])
            .with_title(&format!("{} energy breakdown", self.network));
        for (kind, pj) in &self.energy.dynamic_pj {
            t.row(vec![
                kind.label().to_string(),
                fmt_energy_pj(*pj),
                format!("{:.2}", pj / self.energy.total_pj * 100.0),
            ]);
        }
        t.row(vec![
            "static".into(),
            fmt_energy_pj(self.energy.static_pj),
            format!("{:.2}", self.energy.static_pj / self.energy.total_pj * 100.0),
        ]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(cycles: u64, energy: f64) -> SimReport {
        let mut e = EnergyBreakdown::default();
        e.total_pj = energy;
        SimReport {
            arch: "a".into(),
            network: "n".into(),
            sparsity_label: "Dense".into(),
            total_cycles: cycles,
            latency_us: cycles as f64 * 2e-3,
            energy: e,
            counters: Counters::new(),
            ops: vec![],
            mean_utilization: 0.5,
            mean_skip_ratio: 0.0,
            index_bytes: 0,
            stage_totals: (0, cycles, 0),
            faults: None,
            cache: None,
        }
    }

    #[test]
    fn speedup_and_saving() {
        let dense = dummy(1000, 100.0);
        let sparse = dummy(250, 40.0);
        assert!((sparse.speedup_vs(&dense) - 4.0).abs() < 1e-9);
        assert!((sparse.energy_saving_vs(&dense) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn content_digest_ignores_cache_note_only() {
        let a = dummy(100, 10.0);
        let mut b = a.clone();
        b.cache = Some(CacheNote {
            mapping_hit: StageHit::Memory,
            sim_hit: StageHit::Disk,
            ..Default::default()
        });
        assert_eq!(a.content_digest(), b.content_digest());
        let mut c = a.clone();
        c.total_cycles = 101;
        assert_ne!(a.content_digest(), c.content_digest());
    }

    #[test]
    fn summary_contains_key_metrics() {
        let r = dummy(1_500_000, 5e6);
        let s = r.summary();
        assert!(s.contains("latency"));
        assert!(s.contains("energy"));
        assert!(s.contains("util"));
    }
}
