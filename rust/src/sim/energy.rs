//! Energy aggregation (Eq. 4–7, Sec. V-A): dynamic energy = per-access ×
//! access counts; static energy = per-unit static power × total latency
//! × instantiated unit count.

use super::access::Counters;
use crate::hw::arch::Architecture;
use crate::hw::units::{UnitCounts, UnitKind};
use std::collections::BTreeMap;

/// Component-level energy breakdown (pJ) — the Fig. 6(c)-style split.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Dynamic energy per unit kind.
    pub dynamic_pj: BTreeMap<UnitKind, f64>,
    /// Total static energy.
    pub static_pj: f64,
    /// E_total (Eq. 4).
    pub total_pj: f64,
}

impl EnergyBreakdown {
    pub fn dynamic_total(&self) -> f64 {
        self.dynamic_pj.values().sum()
    }

    pub fn of(&self, kind: UnitKind) -> f64 {
        self.dynamic_pj.get(&kind).copied().unwrap_or(0.0)
    }

    /// Fraction of total energy per unit kind (dynamic only).
    pub fn share(&self, kind: UnitKind) -> f64 {
        if self.total_pj == 0.0 {
            0.0
        } else {
            self.of(kind) / self.total_pj
        }
    }
}

/// Compute Eq. 4–7 from counters, the architecture's energy table, and
/// the simulated latency in cycles.
pub fn aggregate(arch: &Architecture, counters: &Counters, total_cycles: u64) -> EnergyBreakdown {
    let e = &arch.energy;
    let mut dynamic: BTreeMap<UnitKind, f64> = BTreeMap::new();
    let mut add = |k: UnitKind, pj: f64| {
        if pj > 0.0 {
            *dynamic.entry(k).or_insert(0.0) += pj;
        }
    };

    // compute units (Eq. 5)
    add(
        UnitKind::CimArray,
        counters.compute_of(UnitKind::CimArray) as f64 * e.cim_cell.dynamic_pj,
    );
    add(
        UnitKind::AdderTree,
        counters.compute_of(UnitKind::AdderTree) as f64 * e.adder_tree.dynamic_pj,
    );
    add(
        UnitKind::ShiftAdd,
        counters.compute_of(UnitKind::ShiftAdd) as f64 * e.shift_add.dynamic_pj,
    );
    add(
        UnitKind::Accumulator,
        counters.compute_of(UnitKind::Accumulator) as f64 * e.accumulator.dynamic_pj,
    );
    add(
        UnitKind::PreProc,
        counters.compute_of(UnitKind::PreProc) as f64 * e.preproc_bit.dynamic_pj,
    );
    add(
        UnitKind::ZeroDetect,
        counters.compute_of(UnitKind::ZeroDetect) as f64 * e.zero_detect.dynamic_pj,
    );
    add(
        UnitKind::Mux,
        counters.compute_of(UnitKind::Mux) as f64 * e.mux.dynamic_pj,
    );
    add(
        UnitKind::PostProc,
        counters.compute_of(UnitKind::PostProc) as f64 * e.postproc.dynamic_pj,
    );

    // memory units (Eq. 6)
    let mem: [(UnitKind, f64, f64); 5] = [
        (
            UnitKind::GlobalInBuf,
            arch.global_in_buf.read_pj,
            arch.global_in_buf.write_pj,
        ),
        (
            UnitKind::GlobalOutBuf,
            arch.global_out_buf.read_pj,
            arch.global_out_buf.write_pj,
        ),
        (
            UnitKind::WeightBuf,
            arch.weight_buf.read_pj,
            arch.weight_buf.write_pj,
        ),
        (
            UnitKind::LocalBuf,
            arch.local_buf.read_pj,
            arch.local_buf.write_pj,
        ),
        (UnitKind::IndexMem, e.index_mem.dynamic_pj, e.index_mem.dynamic_pj),
    ];
    for (kind, rd, wr) in mem {
        add(
            kind,
            counters.reads_of(kind) as f64 * rd + counters.writes_of(kind) as f64 * wr,
        );
    }

    // static energy (Eq. 7): per instantiated unit per cycle
    let n = UnitCounts::infer(arch);
    let cyc = total_cycles as f64;
    let static_pj = cyc
        * ((n.subarrays * arch.cim.sub_rows * arch.cim.sub_cols) as f64
            * e.cim_cell.static_pj_cycle
            + n.adder_trees as f64 * e.adder_tree.static_pj_cycle
            + n.shift_adds as f64 * e.shift_add.static_pj_cycle
            + (n.macros * arch.cim.cols) as f64 * e.accumulator.static_pj_cycle
            + n.preproc_lanes as f64 * e.preproc_bit.static_pj_cycle
            + n.mux_lanes as f64 * e.mux.static_pj_cycle
            + n.postproc_lanes as f64 * e.postproc.static_pj_cycle
            + arch.global_in_buf.static_pj_cycle
            + arch.global_out_buf.static_pj_cycle
            + arch.weight_buf.static_pj_cycle
            + n.macros as f64 * arch.local_buf.static_pj_cycle
            + if arch.sparsity.weight_indexing {
                arch.index_mem.static_pj_cycle
            } else {
                0.0
            });

    let total = dynamic.values().sum::<f64>() + static_pj;
    EnergyBreakdown {
        dynamic_pj: dynamic,
        static_pj,
        total_pj: total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn dynamic_energy_proportional_to_accesses() {
        let arch = presets::usecase_arch(4, (2, 2));
        let mut c1 = Counters::new();
        c1.add_compute(UnitKind::CimArray, 1000);
        let mut c2 = Counters::new();
        c2.add_compute(UnitKind::CimArray, 2000);
        let e1 = aggregate(&arch, &c1, 0);
        let e2 = aggregate(&arch, &c2, 0);
        assert!((e2.of(UnitKind::CimArray) / e1.of(UnitKind::CimArray) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn static_energy_proportional_to_cycles() {
        let arch = presets::usecase_arch(4, (2, 2));
        let c = Counters::new();
        let e1 = aggregate(&arch, &c, 1_000);
        let e2 = aggregate(&arch, &c, 3_000);
        assert!((e2.static_pj / e1.static_pj - 3.0).abs() < 1e-9);
        assert_eq!(e1.dynamic_total(), 0.0);
    }

    #[test]
    fn buffer_reads_and_writes_priced_separately() {
        let arch = presets::usecase_arch(4, (2, 2));
        let mut cr = Counters::new();
        cr.add_read(UnitKind::GlobalInBuf, 100);
        let mut cw = Counters::new();
        cw.add_write(UnitKind::GlobalInBuf, 100);
        let er = aggregate(&arch, &cr, 0).of(UnitKind::GlobalInBuf);
        let ew = aggregate(&arch, &cw, 0).of(UnitKind::GlobalInBuf);
        assert!(ew > er, "writes cost more: {ew} vs {er}");
    }

    #[test]
    fn shares_sum_to_one_with_dynamic_only() {
        let arch = presets::usecase_arch(4, (2, 2));
        let mut c = Counters::new();
        c.add_compute(UnitKind::CimArray, 500);
        c.add_compute(UnitKind::AdderTree, 200);
        c.add_read(UnitKind::WeightBuf, 50);
        let e = aggregate(&arch, &c, 100);
        let share_sum: f64 = UnitKind::ALL.iter().map(|&k| e.share(k)).sum();
        let static_share = e.static_pj / e.total_pj;
        assert!((share_sum + static_share - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bigger_arch_leaks_more() {
        let small = presets::usecase_arch(4, (2, 2));
        let big = presets::usecase_arch(16, (4, 4));
        let c = Counters::new();
        let es = aggregate(&small, &c, 1000).static_pj;
        let eb = aggregate(&big, &c, 1000).static_pj;
        // macro-side leakage scales 4×, shared buffers stay constant
        assert!(eb > es * 1.3, "{eb} vs {es}");
    }
}
