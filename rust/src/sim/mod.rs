//! The modeling methodology (Sec. V): cycle-level simulation with
//! pipeline latency composition (Eq. 3), per-unit access counting and
//! energy aggregation (Eq. 4–7), and bit-serial input-sparsity skipping.

pub mod access;
pub mod energy;
pub mod engine;
pub mod input_sparsity;
pub mod pipeline;
pub mod report;
pub mod trace;

pub use access::Counters;
pub use energy::{aggregate, EnergyBreakdown};
pub use engine::{simulate, simulate_network_default, SimOptions};
pub use input_sparsity::{ActivationProfile, InputProfiles};
pub use pipeline::{pipeline_latency, uniform_pipeline_latency, StepLat};
pub use report::{OpReport, SimReport};
