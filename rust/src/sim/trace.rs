//! Execution tracing: an optional per-round record of what the
//! simulator scheduled, for debugging mappings and for the bound
//! (roofline-style) analysis the performance pass uses.

use crate::hw::arch::Architecture;
use crate::mapping::planner::MappingPlan;
use crate::workload::graph::Network;

/// What bounds a round's latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Load,
    Compute,
    WriteBack,
}

impl Bound {
    pub fn label(&self) -> &'static str {
        match self {
            Bound::Load => "load",
            Bound::Compute => "compute",
            Bound::WriteBack => "writeback",
        }
    }
}

/// One traced round.
#[derive(Debug, Clone)]
pub struct RoundTrace {
    pub op: String,
    pub round: usize,
    pub active_macros: usize,
    pub load_cycles: u64,
    pub comp_cycles: u64,
    pub wb_cycles: u64,
    pub occupied_cells: u64,
}

impl RoundTrace {
    pub fn bound(&self) -> Bound {
        if self.load_cycles >= self.comp_cycles && self.load_cycles >= self.wb_cycles {
            Bound::Load
        } else if self.comp_cycles >= self.wb_cycles {
            Bound::Compute
        } else {
            Bound::WriteBack
        }
    }
}

/// Whole-run trace with summary queries.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub rounds: Vec<RoundTrace>,
}

impl Trace {
    /// Fraction of rounds bound by each stage.
    pub fn bound_histogram(&self) -> [(Bound, f64); 3] {
        let n = self.rounds.len().max(1) as f64;
        let count = |b: Bound| self.rounds.iter().filter(|r| r.bound() == b).count() as f64 / n;
        [
            (Bound::Load, count(Bound::Load)),
            (Bound::Compute, count(Bound::Compute)),
            (Bound::WriteBack, count(Bound::WriteBack)),
        ]
    }

    /// Ops ranked by attributed cycles (descending) — the profiling view.
    pub fn hotspots(&self, top: usize) -> Vec<(String, u64)> {
        let mut per_op: std::collections::BTreeMap<String, u64> = Default::default();
        for r in &self.rounds {
            *per_op.entry(r.op.clone()).or_insert(0) +=
                r.load_cycles.max(r.comp_cycles) + r.wb_cycles;
        }
        let mut v: Vec<(String, u64)> = per_op.into_iter().collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v.truncate(top);
        v
    }

    pub fn render(&self, limit: usize) -> String {
        let mut out = String::from("op                        round macros    load    comp      wb bound\n");
        for r in self.rounds.iter().take(limit) {
            out.push_str(&format!(
                "{:<25} {:>5} {:>6} {:>7} {:>7} {:>7} {}\n",
                r.op,
                r.round,
                r.active_macros,
                r.load_cycles,
                r.comp_cycles,
                r.wb_cycles,
                r.bound().label()
            ));
        }
        out
    }
}

/// Build a trace by replaying the mapping the way the engine schedules
/// it (kept consistent with `engine::simulate` via the shared Round
/// structures; latencies recomputed with the same formulas).
pub fn trace_mapping(
    arch: &Architecture,
    net: &Network,
    mapping: &MappingPlan,
    eff_bits: f64,
) -> Trace {
    let mut t = Trace::default();
    for op in &net.ops {
        let Some(m) = mapping.ops.get(&op.id) else {
            continue;
        };
        for (i, round) in m.tiling.rounds.iter().enumerate() {
            let max_tile_bytes = round
                .tiles
                .iter()
                .map(|x| x.occupied * arch.weight_bits as u64 / 8)
                .max()
                .unwrap_or(0);
            let load = arch
                .local_buf
                .transfer_cycles(max_tile_bytes)
                .max(arch.weight_buf.transfer_cycles(round.weight_bytes));
            let comp = (round.vectors_per_macro as f64 * eff_bits).ceil() as u64;
            let wb = arch
                .global_out_buf
                .transfer_cycles(round.outputs * arch.input_bits as u64 / 8);
            t.rounds.push(RoundTrace {
                op: m.name.clone(),
                round: i,
                active_macros: round.tiles.len(),
                load_cycles: load,
                comp_cycles: comp,
                wb_cycles: wb,
                occupied_cells: round.occupied_cells(),
            });
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::mapping::planner::{plan, MappingOptions};
    use crate::workload::zoo;

    fn make_trace() -> Trace {
        let net = zoo::resnet_mini();
        let arch = presets::usecase_arch(4, (2, 2));
        let mapping = plan(&arch, &net, None, MappingOptions::default()).unwrap();
        trace_mapping(&arch, &net, &mapping, 8.0)
    }

    #[test]
    fn trace_covers_all_mvm_rounds() {
        let t = make_trace();
        assert!(!t.rounds.is_empty());
        let net = zoo::resnet_mini();
        let names: std::collections::BTreeSet<String> =
            t.rounds.iter().map(|r| r.op.clone()).collect();
        assert_eq!(names.len(), net.mvm_ops().len());
    }

    #[test]
    fn bound_histogram_sums_to_one() {
        let t = make_trace();
        let h = t.bound_histogram();
        let s: f64 = h.iter().map(|(_, f)| f).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn hotspots_sorted_desc() {
        let t = make_trace();
        let h = t.hotspots(5);
        assert!(!h.is_empty());
        for w in h.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }

    #[test]
    fn render_is_bounded() {
        let t = make_trace();
        let s = t.render(3);
        assert!(s.lines().count() <= 4);
        assert!(s.contains("bound"));
    }
}
