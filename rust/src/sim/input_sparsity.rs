//! Input-sparsity modeling (Sec. III-B, Sec. V-B, Fig. 10): bit-serial
//! zero-bit skipping.
//!
//! A bit-position cycle of a sub-array can be skipped iff *every* input
//! broadcast to the activated rows is zero at that bit. With per-bit
//! zero probability p_b for one activation and a broadcast group of G
//! distinct inputs, the skip probability is p_b^G (independence across
//! inputs, documented approximation), so the expected executed bits are
//! Σ_b (1 − p_b^G).
//!
//! Profiles come from two sources matching the paper's workflow:
//! measured activations (PJRT inference on dataset samples via
//! `runtime::infer`, quantized to the architecture's input width) or a
//! synthetic ReLU-censored Gaussian model for full-size networks whose
//! weights we do not have (DESIGN.md §3).

use crate::util::rng::Pcg32;
use crate::workload::op::OpId;
use std::collections::BTreeMap;

/// Per-bit zero probabilities of one activation value (bit 0 = LSB).
#[derive(Debug, Clone, PartialEq)]
pub struct ActivationProfile {
    pub bit_zero_prob: Vec<f64>,
}

impl ActivationProfile {
    pub fn bits(&self) -> usize {
        self.bit_zero_prob.len()
    }

    /// Profile of an all-dense (never-skippable) input stream.
    pub fn dense(bits: usize) -> Self {
        Self {
            bit_zero_prob: vec![0.0; bits],
        }
    }

    /// Measure from concrete activation values: quantize to `bits` by
    /// max-abs scaling (symmetric uint after ReLU) and count zero bits
    /// per plane.
    pub fn from_values(values: &[f32], bits: usize) -> Self {
        assert!(bits >= 1 && bits <= 16);
        let max = values.iter().cloned().fold(0f32, |a, b| a.max(b.abs()));
        if max == 0.0 || values.is_empty() {
            return Self {
                bit_zero_prob: vec![1.0; bits],
            };
        }
        let scale = ((1u32 << bits) - 1) as f32 / max;
        let mut zero_counts = vec![0u64; bits];
        for &v in values {
            let q = (v.max(0.0) * scale).round() as u32; // ReLU'd inputs
            for (b, cnt) in zero_counts.iter_mut().enumerate() {
                if (q >> b) & 1 == 0 {
                    *cnt += 1;
                }
            }
        }
        let n = values.len() as f64;
        Self {
            bit_zero_prob: zero_counts.iter().map(|&c| c as f64 / n).collect(),
        }
    }

    /// Synthetic ReLU-censored Gaussian profile: activations
    /// max(0, N(μ, σ))·quantized. `zero_frac` shifts μ to hit the target
    /// exact-zero fraction (ReLU kill rate), matching the ~50% typical of
    /// trained CNNs (higher for sparser models — Fig. 10's observation).
    pub fn synthetic_relu(bits: usize, zero_frac: f64, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed);
        // choose μ via the inverse CDF so P(N(μ,1) ≤ 0) = zero_frac
        let mu = -inv_normal_cdf(zero_frac.clamp(0.01, 0.99));
        let n = 8192;
        let values: Vec<f32> = (0..n)
            .map(|_| ((rng.next_normal() + mu).max(0.0)) as f32)
            .collect();
        Self::from_values(&values, bits)
    }

    /// Expected executed bit cycles for a broadcast group of `group`
    /// distinct inputs (≥ 1).
    pub fn group_active_bits(&self, group: usize) -> f64 {
        let g = group.max(1) as f64;
        self.bit_zero_prob
            .iter()
            .map(|&p| 1.0 - p.powf(g))
            .sum()
    }

    /// Skippable-cycle ratio for a group (the profiling metric Fig. 10
    /// reports).
    pub fn skip_ratio(&self, group: usize) -> f64 {
        1.0 - self.group_active_bits(group) / self.bits() as f64
    }
}

/// Rational approximation of the standard normal inverse CDF
/// (Acklam's method, |ε| < 1.15e-9 on (0,1)).
fn inv_normal_cdf(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Per-layer activation profiles for a network's MVM inputs.
#[derive(Debug, Clone, Default)]
pub struct InputProfiles {
    pub per_layer: BTreeMap<OpId, ActivationProfile>,
    pub fallback: Option<ActivationProfile>,
}

impl InputProfiles {
    /// Synthetic profiles for every MVM op; `zero_frac` optionally raised
    /// for deeper layers (activation distributions sparsify with depth in
    /// pruned models — Fig. 10).
    pub fn synthetic(
        net: &crate::workload::graph::Network,
        bits: usize,
        zero_frac: f64,
        seed: u64,
    ) -> Self {
        // One synthesis shared by all layers: the per-layer profiles are
        // iid draws from the same censored-Gaussian model, so separate
        // 8k-sample syntheses per layer only added noise and ~40% of the
        // per-configuration runtime (§Perf opt 3). Measured (PJRT)
        // profiles remain genuinely per-layer.
        let shared = ActivationProfile::synthetic_relu(bits, zero_frac, seed);
        let mut per_layer = BTreeMap::new();
        for id in net.mvm_ops() {
            per_layer.insert(id, shared.clone());
        }
        Self {
            fallback: Some(shared),
            per_layer,
        }
    }

    pub fn profile_for(&self, id: OpId) -> Option<&ActivationProfile> {
        self.per_layer.get(&id).or(self.fallback.as_ref())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_profile_never_skips() {
        let p = ActivationProfile::dense(8);
        assert_eq!(p.group_active_bits(64), 8.0);
        assert_eq!(p.skip_ratio(64), 0.0);
    }

    #[test]
    fn from_values_quantizes() {
        // all zeros → every bit plane fully zero
        let p = ActivationProfile::from_values(&[0.0; 64], 8);
        assert!(p.bit_zero_prob.iter().all(|&x| x == 1.0));
        assert_eq!(p.group_active_bits(1), 0.0);
        // max value sets all bits at max-abs scale
        let p2 = ActivationProfile::from_values(&[1.0], 8);
        assert_eq!(p2.group_active_bits(1), 8.0);
    }

    #[test]
    fn skip_decreases_with_group_size() {
        let p = ActivationProfile::synthetic_relu(8, 0.5, 42);
        let s1 = p.skip_ratio(1);
        let s32 = p.skip_ratio(32);
        let s1024 = p.skip_ratio(1024);
        assert!(s1 > s32 && s32 >= s1024, "{s1} {s32} {s1024}");
        assert!(s1 > 0.4, "single-input skip near zero fraction: {s1}");
    }

    #[test]
    fn sparser_activations_skip_more() {
        let mild = ActivationProfile::synthetic_relu(8, 0.4, 1);
        let sparse = ActivationProfile::synthetic_relu(8, 0.8, 1);
        for g in [1usize, 8, 32] {
            assert!(
                sparse.skip_ratio(g) > mild.skip_ratio(g),
                "g={g}: {} <= {}",
                sparse.skip_ratio(g),
                mild.skip_ratio(g)
            );
        }
    }

    #[test]
    fn sub_array_skip_is_meaningful_for_small_groups() {
        // paper reports 1.2–1.4× from input sparsity → skip 15–30% at
        // practical group sizes (1×64 rows like SDP, or 32 with leading
        // zeros); check our model lands in a plausible band for G=32.
        // at G=32 only the near-always-zero leading planes survive the
        // OR: a few percent. Designs with fine detection granularity
        // (SDP's 1-row sub-arrays → G≈2) reach the 20-40% band that
        // yields the paper's 1.2-1.4× (see fig10 bench).
        let p = ActivationProfile::synthetic_relu(8, 0.5, 7);
        let s32 = p.skip_ratio(32);
        assert!((0.02..0.6).contains(&s32), "skip(32) = {s32}");
        let s2 = p.skip_ratio(2);
        assert!((0.2..0.8).contains(&s2), "skip(2) = {s2}");
    }

    #[test]
    fn inv_normal_cdf_sane() {
        assert!((inv_normal_cdf(0.5)).abs() < 1e-9);
        assert!((inv_normal_cdf(0.975) - 1.959964).abs() < 1e-4);
        assert!((inv_normal_cdf(0.025) + 1.959964).abs() < 1e-4);
    }

    #[test]
    fn profiles_lookup_with_fallback() {
        let net = crate::workload::zoo::resnet_mini();
        let p = InputProfiles::synthetic(&net, 8, 0.5, 3);
        for id in net.mvm_ops() {
            assert!(p.profile_for(id).is_some());
        }
        assert!(p.profile_for(9999).is_some(), "fallback applies");
    }
}
