//! Pipeline latency composition (Eq. 3, Sec. V-A):
//!
//! L_total = L₁^load + Σᵢ₌₂ⁿ Pᵢ(Lᵢ^load, Lᵢ₋₁^comp, Lᵢ₋₁^wb) + Lₙ^comp + Lₙ^wb
//!
//! where Pᵢ returns the bottleneck of loading step i against finishing
//! step i−1, subject to what the buffers allow to overlap: ping-pong
//! weight/input buffers let load(i) run under comp(i−1); a ping-pong
//! output buffer hides wb(i−1) under comp(i−1).

/// Latencies of one pipeline step (cycles).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepLat {
    pub load: u64,
    pub comp: u64,
    pub wb: u64,
}

/// Compose total latency per Eq. 3.
///
/// `overlap_load`: load(i) overlaps comp(i−1) (ping-pong weight path).
/// `overlap_wb`: wb(i−1) overlaps comp(i−1) (double-buffered outputs).
pub fn pipeline_latency(steps: &[StepLat], overlap_load: bool, overlap_wb: bool) -> u64 {
    if steps.is_empty() {
        return 0;
    }
    if !overlap_load {
        // fully serial: Σ (load + comp + wb)
        return steps.iter().map(|s| s.load + s.comp + s.wb).sum();
    }
    let mut total = steps[0].load;
    for i in 1..steps.len() {
        let prev = &steps[i - 1];
        // what must finish before step i's compute can start
        let prev_busy = if overlap_wb {
            prev.comp.max(prev.wb) // wb runs under the *next* comp too;
                                   // conservatively under this window
        } else {
            prev.comp + prev.wb
        };
        total += steps[i].load.max(prev_busy);
    }
    // the last step's compute and write-back have nothing to hide under
    let last = steps.last().unwrap();
    total += last.comp + last.wb;
    total
}

/// Convenience: latency when every step is identical (uniform rounds).
pub fn uniform_pipeline_latency(
    n: usize,
    step: StepLat,
    overlap_load: bool,
    overlap_wb: bool,
) -> u64 {
    if n == 0 {
        return 0;
    }
    // avoid materializing huge vectors for big round counts
    if n <= 4 {
        let steps = vec![step; n];
        return pipeline_latency(&steps, overlap_load, overlap_wb);
    }
    let head = pipeline_latency(&vec![step; 2], overlap_load, overlap_wb);
    let three = pipeline_latency(&vec![step; 3], overlap_load, overlap_wb);
    let per_middle = three - head;
    head + per_middle * (n as u64 - 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(load: u64, comp: u64, wb: u64) -> StepLat {
        StepLat { load, comp, wb }
    }

    #[test]
    fn serial_sum_without_overlap() {
        let steps = [s(10, 20, 5), s(10, 20, 5)];
        assert_eq!(pipeline_latency(&steps, false, false), 2 * 35);
    }

    #[test]
    fn single_step() {
        assert_eq!(pipeline_latency(&[s(10, 20, 5)], true, true), 35);
        assert_eq!(pipeline_latency(&[s(10, 20, 5)], false, false), 35);
    }

    #[test]
    fn compute_bound_pipeline() {
        // load 5 hides under comp 20 → L = 5 + (n-1)*max(5, 20+2)... wb 2 not overlapped
        let steps = vec![s(5, 20, 2); 3];
        // Eq3: 5 + max(5, 22) + max(5, 22) + 20 + 2 = 5+22+22+22 = 71
        assert_eq!(pipeline_latency(&steps, true, false), 5 + 22 + 22 + 20 + 2);
    }

    #[test]
    fn load_bound_pipeline() {
        let steps = vec![s(50, 20, 2); 3];
        // 50 + max(50,22)*2 + 20 + 2 = 50+100+22 = 172
        assert_eq!(pipeline_latency(&steps, true, false), 50 + 50 + 50 + 20 + 2);
    }

    #[test]
    fn wb_overlap_hides_writeback_except_last() {
        let steps = vec![s(5, 20, 10); 3];
        // overlap_wb: prev busy = max(comp, wb) = 20
        // 5 + 20 + 20 + 20 + 10(last wb) = 75
        assert_eq!(pipeline_latency(&steps, true, true), 75);
        // without wb overlap: 5 + 30 + 30 + 20 + 10 = 95
        assert_eq!(pipeline_latency(&steps, true, false), 95);
    }

    #[test]
    fn overlap_never_slower_than_serial() {
        let cases = [
            vec![s(3, 7, 2); 5],
            vec![s(10, 1, 1); 4],
            vec![s(0, 9, 3), s(4, 2, 8), s(7, 7, 7)],
        ];
        for steps in cases {
            let serial = pipeline_latency(&steps, false, false);
            let pp = pipeline_latency(&steps, true, false);
            let full = pipeline_latency(&steps, true, true);
            assert!(pp <= serial, "{pp} > {serial}");
            assert!(full <= pp, "{full} > {pp}");
            // and every compute cycle is still paid at least once
            let comp_sum: u64 = steps.iter().map(|x| x.comp).sum();
            assert!(full >= comp_sum);
        }
    }

    #[test]
    fn uniform_matches_explicit() {
        let step = s(7, 13, 4);
        for n in [1usize, 2, 3, 4, 7, 50] {
            let explicit = pipeline_latency(&vec![step; n], true, true);
            let fast = uniform_pipeline_latency(n, step, true, true);
            assert_eq!(explicit, fast, "n={n}");
            let explicit2 = pipeline_latency(&vec![step; n], true, false);
            let fast2 = uniform_pipeline_latency(n, step, true, false);
            assert_eq!(explicit2, fast2, "n={n} no-wb");
        }
    }

    #[test]
    fn empty_steps() {
        assert_eq!(pipeline_latency(&[], true, true), 0);
        assert_eq!(uniform_pipeline_latency(0, s(1, 1, 1), true, true), 0);
    }
}
