//! Per-unit access counters accumulated during simulation — the N_acc /
//! N_read / N_write terms of Eq. 5 and Eq. 6.

use crate::hw::units::UnitKind;
use std::collections::BTreeMap;

/// Access counters: compute-unit accesses and memory reads/writes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Counters {
    pub compute: BTreeMap<UnitKind, u64>,
    pub mem_reads: BTreeMap<UnitKind, u64>,
    pub mem_writes: BTreeMap<UnitKind, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add_compute(&mut self, kind: UnitKind, n: u64) {
        if n > 0 {
            *self.compute.entry(kind).or_insert(0) += n;
        }
    }

    #[inline]
    pub fn add_read(&mut self, kind: UnitKind, n: u64) {
        if n > 0 {
            *self.mem_reads.entry(kind).or_insert(0) += n;
        }
    }

    #[inline]
    pub fn add_write(&mut self, kind: UnitKind, n: u64) {
        if n > 0 {
            *self.mem_writes.entry(kind).or_insert(0) += n;
        }
    }

    pub fn compute_of(&self, kind: UnitKind) -> u64 {
        self.compute.get(&kind).copied().unwrap_or(0)
    }

    pub fn reads_of(&self, kind: UnitKind) -> u64 {
        self.mem_reads.get(&kind).copied().unwrap_or(0)
    }

    pub fn writes_of(&self, kind: UnitKind) -> u64 {
        self.mem_writes.get(&kind).copied().unwrap_or(0)
    }

    /// Merge another counter set into this one.
    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.compute {
            *self.compute.entry(*k).or_insert(0) += v;
        }
        for (k, v) in &other.mem_reads {
            *self.mem_reads.entry(*k).or_insert(0) += v;
        }
        for (k, v) in &other.mem_writes {
            *self.mem_writes.entry(*k).or_insert(0) += v;
        }
    }

    pub fn total_accesses(&self) -> u64 {
        self.compute.values().sum::<u64>()
            + self.mem_reads.values().sum::<u64>()
            + self.mem_writes.values().sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_and_query() {
        let mut c = Counters::new();
        c.add_compute(UnitKind::CimArray, 10);
        c.add_compute(UnitKind::CimArray, 5);
        c.add_read(UnitKind::WeightBuf, 3);
        c.add_write(UnitKind::GlobalOutBuf, 2);
        c.add_compute(UnitKind::Mux, 0); // no-op
        assert_eq!(c.compute_of(UnitKind::CimArray), 15);
        assert_eq!(c.reads_of(UnitKind::WeightBuf), 3);
        assert_eq!(c.writes_of(UnitKind::GlobalOutBuf), 2);
        assert_eq!(c.compute_of(UnitKind::Mux), 0);
        assert!(!c.compute.contains_key(&UnitKind::Mux));
        assert_eq!(c.total_accesses(), 20);
    }

    #[test]
    fn merge_sums() {
        let mut a = Counters::new();
        a.add_compute(UnitKind::AdderTree, 7);
        a.add_read(UnitKind::IndexMem, 1);
        let mut b = Counters::new();
        b.add_compute(UnitKind::AdderTree, 3);
        b.add_write(UnitKind::IndexMem, 4);
        a.merge(&b);
        assert_eq!(a.compute_of(UnitKind::AdderTree), 10);
        assert_eq!(a.reads_of(UnitKind::IndexMem), 1);
        assert_eq!(a.writes_of(UnitKind::IndexMem), 4);
    }
}
