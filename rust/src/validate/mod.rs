//! Framework validation (Sec. VI, Fig. 6): published MARS/SDP results and
//! the comparison harness.

pub mod harness;
pub mod reported;

pub use harness::{correlation, error_stats, run_validation, sdp_power_breakdown, ValidationPoint};
pub use reported::{all_results, Design, ReportedResult};
