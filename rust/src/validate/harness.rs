//! Validation harness (Sec. VI-A, Fig. 6): runs CIMinus on the MARS and
//! SDP configurations of Table I and compares estimated speedups, energy
//! savings and power breakdowns against the published numbers.

use super::reported::{all_results, Design, ReportedResult, SDP_POWER_BREAKDOWN};
use crate::eval::{Evaluator, Scenario};
use crate::hw::arch::{Architecture, SparsitySupport};
use crate::hw::presets;
use crate::hw::units::UnitKind;
use crate::pruning::workflow::PruningWorkflow;
use crate::sim::report::SimReport;
use crate::sparsity::flexblock::FlexBlock;
use crate::workload::{graph::Network, zoo};
use std::sync::Arc;

/// One Fig. 6(a) point: a reported-vs-estimated pair.
#[derive(Debug, Clone)]
pub struct ValidationPoint {
    pub design: &'static str,
    pub workload: String,
    pub metric: &'static str,
    pub reported: f64,
    pub estimated: f64,
}

/// Error cap returned when the reported value is (near) zero but the
/// estimate is not — a finite sentinel that keeps `error_stats` /
/// `correlation` well-defined instead of poisoning them with inf/NaN.
pub const ERR_PCT_CAP: f64 = 999.0;

impl ValidationPoint {
    pub fn err_pct(&self) -> f64 {
        let diff = (self.estimated - self.reported).abs();
        if self.reported.abs() < 1e-12 {
            return if diff < 1e-12 { 0.0 } else { ERR_PCT_CAP };
        }
        (diff / self.reported.abs() * 100.0).min(ERR_PCT_CAP)
    }
}

fn scenario_net(r: &ReportedResult) -> anyhow::Result<Network> {
    Ok(match (r.design, r.workload) {
        // MARS evaluates CIFAR models, SDP ImageNet models (Sec. VI-A)
        (Design::Mars, w) => zoo::by_name(w, 32, 100)?,
        (Design::Sdp, w) => zoo::by_name(w, 224, 1000)?,
    })
}

fn scenario_fb(r: &ReportedResult) -> FlexBlock {
    match r.design {
        // MARS: group-wise FullBlock(1,16) on conv layers
        Design::Mars => FlexBlock::row_block(16, r.sparsity),
        // SDP: Intra(2,1) + Full(2,8) hierarchical pruning
        Design::Sdp => FlexBlock::hybrid(2, 8, r.sparsity),
    }
}

fn scenario_arch(r: &ReportedResult) -> Architecture {
    match r.design {
        Design::Mars => presets::mars(),
        Design::Sdp => presets::sdp(),
    }
}

fn scenario_wf(r: &ReportedResult) -> PruningWorkflow {
    PruningWorkflow {
        // MARS evaluates Conv layers only (Table I)
        skip_fc: r.design == Design::Mars,
        ..Default::default()
    }
}

/// Conv-only cycle/energy scoping (Table I: MARS evaluates "Only Conv
/// layers"): sum the per-op attributed cycles of conv ops.
fn conv_cycles(rep: &SimReport) -> u64 {
    rep.ops
        .iter()
        .filter(|o| o.kind == "conv" || o.kind == "dwconv")
        .map(|o| o.cycles)
        .sum::<u64>()
        .max(1)
}

/// Speedup / energy-saving under a design's evaluation scope.
pub fn scoped_metrics(r: &ReportedResult, dense: &SimReport, sparse: &SimReport) -> (f64, f64) {
    match r.design {
        Design::Mars => {
            // conv-only latency scope; energy scaled by the same scope
            // ratio (buffer/static energy follows the conv share)
            let speedup = conv_cycles(dense) as f64 / conv_cycles(sparse) as f64;
            let dense_conv_share = conv_cycles(dense) as f64 / dense.total_cycles as f64;
            let sparse_conv_share = conv_cycles(sparse) as f64 / sparse.total_cycles as f64;
            let saving = (dense.energy.total_pj * dense_conv_share)
                / (sparse.energy.total_pj * sparse_conv_share).max(1e-12);
            (speedup, saving)
        }
        Design::Sdp => (
            sparse.speedup_vs(dense),
            sparse.energy_saving_vs(dense),
        ),
    }
}

/// Simulate one validation scenario through a shared [`Evaluator`]:
/// returns (dense, sparse) reports on the same architecture geometry
/// (dense baseline runs without weight-sparsity hardware, as both
/// papers' baselines do). The two legs share the input-profile artifact,
/// and repeated workloads across the Fig. 6 result set reuse cached
/// prune/mapping plans.
fn scenario_reports(
    ev: &Evaluator,
    r: &ReportedResult,
) -> anyhow::Result<(SimReport, SimReport)> {
    let net = Arc::new(scenario_net(r)?);
    let arch = scenario_arch(r);
    let fb = scenario_fb(r);
    let wf = scenario_wf(r);
    let bits = arch.input_bits;

    // The dense baselines keep each design's input-sparsity (zero-bit
    // skip) logic — both papers' dense baselines are their own
    // architectures running uncompressed weights — but no weight-sparsity
    // hardware.
    let mut dense_arch = arch.clone();
    dense_arch.sparsity = SparsitySupport {
        weight_indexing: false,
        weight_routing: false,
        input_skipping: arch.sparsity.input_skipping,
    };
    let dense = ev.evaluate(
        &Scenario::new(dense_arch, net.clone()).synthetic_profiles(bits, 0.55, 0x6006),
    )?;
    let sparse = ev.evaluate(
        &Scenario::new(arch, net)
            .prune_with(wf, &fb)
            .synthetic_profiles(bits, 0.55, 0x6006),
    )?;
    Ok((dense, sparse))
}

/// One-off [`scenario_reports`] with a private evaluator (historical
/// public entry point).
pub fn run_scenario(r: &ReportedResult) -> anyhow::Result<(SimReport, SimReport)> {
    scenario_reports(&Evaluator::new(), r)
}

/// Run all Fig. 6(a)/(b) validation points through one shared evaluator.
pub fn run_validation() -> anyhow::Result<Vec<ValidationPoint>> {
    let ev = Evaluator::new();
    let mut out = Vec::new();
    for r in all_results() {
        let (dense, sparse) = scenario_reports(&ev, &r)?;
        let (speedup, saving) = scoped_metrics(&r, &dense, &sparse);
        let design = match r.design {
            Design::Mars => "MARS",
            Design::Sdp => "SDP",
        };
        out.push(ValidationPoint {
            design,
            workload: r.workload.to_string(),
            metric: "speedup",
            reported: r.speedup,
            estimated: speedup,
        });
        out.push(ValidationPoint {
            design,
            workload: r.workload.to_string(),
            metric: "energy_saving",
            reported: r.energy_saving,
            estimated: saving,
        });
    }
    Ok(out)
}

/// Fig. 6(c): estimated SDP power breakdown vs published, as matched
/// category fractions.
pub fn sdp_power_breakdown() -> anyhow::Result<Vec<(&'static str, f64, f64)>> {
    let r = &super::reported::SDP_RESULTS[0];
    let (_dense, sparse) = run_scenario(r)?;
    let e = &sparse.energy;
    let cat = |kinds: &[UnitKind]| -> f64 { kinds.iter().map(|&k| e.of(k)).sum() };
    let macros = cat(&[
        UnitKind::CimArray,
        UnitKind::AdderTree,
        UnitKind::ShiftAdd,
        UnitKind::Accumulator,
        UnitKind::LocalBuf,
    ]);
    let feature = cat(&[UnitKind::GlobalInBuf, UnitKind::GlobalOutBuf]);
    let weight = cat(&[UnitKind::WeightBuf]);
    let prepost = cat(&[UnitKind::PreProc, UnitKind::ZeroDetect, UnitKind::PostProc]);
    let index = cat(&[UnitKind::IndexMem, UnitKind::Mux]);
    let total = macros + feature + weight + prepost + index;
    let est = [
        ("cim_macros", macros / total),
        ("feature_buffers", feature / total),
        ("weight_path", weight / total),
        ("pre_post_proc", prepost / total),
        ("index_logic", index / total),
    ];
    Ok(SDP_POWER_BREAKDOWN
        .iter()
        .zip(est)
        .map(|(&(name, rep), (_, e))| (name, rep, e))
        .collect())
}

/// Mean and max error of a validation run (the Fig. 6(a) margin).
pub fn error_stats(points: &[ValidationPoint]) -> (f64, f64) {
    let errs: Vec<f64> = points.iter().map(|p| p.err_pct()).collect();
    let mean = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
    let max = errs.iter().cloned().fold(0.0, f64::max);
    (mean, max)
}

/// Pearson correlation of reported vs estimated — the Fig. 6(a)
/// scatter's agreement statistic.
pub fn correlation(points: &[ValidationPoint]) -> f64 {
    let xs: Vec<f64> = points.iter().map(|p| p.reported).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.estimated).collect();
    crate::util::stats::pearson(&xs, &ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenarios_simulate() {
        // smallest scenario end-to-end (MARS resnet18 CIFAR)
        let r = &super::super::reported::MARS_RESULTS[1];
        let (dense, sparse) = run_scenario(r).unwrap();
        assert!(sparse.total_cycles < dense.total_cycles);
        assert!(sparse.energy.total_pj < dense.energy.total_pj);
    }

    #[test]
    fn validation_points_have_both_sides() {
        // full run is exercised by bench_fig6; here just the scaffolding
        let p = ValidationPoint {
            design: "MARS",
            workload: "vgg16".into(),
            metric: "speedup",
            reported: 2.0,
            estimated: 2.1,
        };
        assert!((p.err_pct() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn err_pct_is_finite_for_zero_reported() {
        let mk = |reported: f64, estimated: f64| ValidationPoint {
            design: "MARS",
            workload: "vgg16".into(),
            metric: "speedup",
            reported,
            estimated,
        };
        // zero vs zero: perfect agreement, not NaN
        assert_eq!(mk(0.0, 0.0).err_pct(), 0.0);
        // zero vs non-zero: capped sentinel, not inf
        assert_eq!(mk(0.0, 2.0).err_pct(), ERR_PCT_CAP);
        assert!(mk(0.0, 2.0).err_pct().is_finite());
        // enormous relative error is capped too
        assert_eq!(mk(1e-6, 1e6).err_pct(), ERR_PCT_CAP);
        // negative reported values use the magnitude
        assert!((mk(-2.0, -2.1).err_pct() - 5.0).abs() < 1e-9);
    }
}
