//! Published results of the validation-target designs (Fig. 6's "R"
//! series).
//!
//! SUBSTITUTION NOTE (DESIGN.md §3): the paper validates against numbers
//! measured on MARS [19] and SDP [20] silicon/RTL. Those papers' result
//! tables are not machine-readable here, so the constants below are
//! approximate transcriptions of their published sparse-vs-dense
//! speedups, energy savings, component power splits and model
//! accuracies. They are *data*, not computation: the validation harness
//! compares CIMinus estimates against them exactly as Fig. 6 does.

/// Which design a number comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Design {
    Mars,
    Sdp,
}

/// One published (design, workload) result pair.
#[derive(Debug, Clone)]
pub struct ReportedResult {
    pub design: Design,
    pub workload: &'static str,
    /// Dataset in the original evaluation.
    pub dataset: &'static str,
    /// Sparse-over-dense inference speedup.
    pub speedup: f64,
    /// Sparse-over-dense energy saving factor.
    pub energy_saving: f64,
    /// (dense accuracy, pruned accuracy) in percent.
    pub accuracy: (f64, f64),
    /// Overall weight sparsity of the pruned model.
    pub sparsity: f64,
}

/// MARS: group-wise structured pruning, FullBlock(1,16), Conv layers
/// only, CIFAR models.
pub const MARS_RESULTS: [ReportedResult; 2] = [
    ReportedResult {
        design: Design::Mars,
        workload: "vgg16",
        dataset: "CIFAR-100",
        speedup: 2.57,
        energy_saving: 2.71,
        accuracy: (72.9, 72.1),
        sparsity: 0.65,
    },
    ReportedResult {
        design: Design::Mars,
        workload: "resnet18",
        dataset: "CIFAR-100",
        speedup: 2.18,
        energy_saving: 2.28,
        accuracy: (76.5, 75.8),
        sparsity: 0.60,
    },
];

/// SDP: double-broadcast hierarchical pruning, Intra(2,1)+Full(2,8),
/// whole-network, ImageNet models.
pub const SDP_RESULTS: [ReportedResult; 2] = [
    ReportedResult {
        design: Design::Sdp,
        workload: "resnet50",
        dataset: "ImageNet",
        speedup: 1.96,
        energy_saving: 1.74,
        accuracy: (76.1, 75.4),
        sparsity: 0.72,
    },
    ReportedResult {
        design: Design::Sdp,
        workload: "resnet18",
        dataset: "ImageNet",
        speedup: 2.06,
        energy_saving: 1.81,
        accuracy: (69.8, 69.1),
        sparsity: 0.75,
    },
];

/// SDP's published component power breakdown (fractions of total), the
/// Fig. 6(c) reference series: CIM macros dominate, then feature
/// buffers, weight path, pre/post-processing and sparsity-index logic.
pub const SDP_POWER_BREAKDOWN: [(&str, f64); 5] = [
    ("cim_macros", 0.58),
    ("feature_buffers", 0.19),
    ("weight_path", 0.12),
    ("pre_post_proc", 0.07),
    ("index_logic", 0.04),
];

pub fn all_results() -> Vec<ReportedResult> {
    MARS_RESULTS.iter().cloned().chain(SDP_RESULTS.iter().cloned()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reported_values_sane() {
        for r in all_results() {
            assert!(r.speedup > 1.0 && r.speedup < 10.0);
            assert!(r.energy_saving > 1.0 && r.energy_saving < 10.0);
            assert!(r.accuracy.0 >= r.accuracy.1, "pruning never helps here");
            assert!((0.0..1.0).contains(&r.sparsity));
        }
    }

    #[test]
    fn breakdown_sums_to_one() {
        let s: f64 = SDP_POWER_BREAKDOWN.iter().map(|(_, f)| f).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}
