//! Stable structural fingerprints for pipeline artifacts.
//!
//! Cache keys must be identical across processes and runs, so we hash
//! the `Debug` rendering of each stage input with FNV-1a/128 — a fixed,
//! dependency-free function with no per-process seed (unlike
//! `std::collections::hash_map::RandomState`). Every hashed type in
//! this crate derives `Debug` structurally and stores its collections
//! in `BTreeMap`/`Vec`, so the rendering — and therefore the key — is
//! deterministic. The `Debug` text is streamed straight into the hasher
//! through its `fmt::Write` impl; no intermediate `String` is built.

use std::fmt::{self, Debug, Write};

const FNV_OFFSET_128: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV_PRIME_128: u128 = 0x0000000001000000000000000000013B;

/// Hash-schema version mixed into every fingerprint. Bump whenever the
/// *layout* of a hashed artifact changes without its `Debug` rendering
/// changing (e.g. a field is reinterpreted, or stage boundaries move),
/// so stale cached stage results from an older build can never alias
/// a new build's keys.
pub const HASH_SCHEMA_VERSION: u32 = 1;

/// Streaming FNV-1a/128 hasher over bytes or `Debug` renderings.
pub struct StableHasher {
    state: u128,
}

impl StableHasher {
    pub fn new() -> Self {
        let mut h = Self {
            state: FNV_OFFSET_128,
        };
        h.write_bytes(&HASH_SCHEMA_VERSION.to_le_bytes());
        h
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u128;
            self.state = self.state.wrapping_mul(FNV_PRIME_128);
        }
    }

    pub fn write_u128(&mut self, v: u128) {
        self.write_bytes(&v.to_le_bytes());
    }

    /// Stream `value`'s `Debug` rendering into the hash state.
    pub fn write_debug<T: Debug>(&mut self, value: &T) {
        // fmt::Write for StableHasher is infallible.
        let _ = write!(self, "{value:?}");
    }

    pub fn finish(&self) -> u128 {
        self.state
    }
}

impl Default for StableHasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Write for StableHasher {
    fn write_str(&mut self, s: &str) -> fmt::Result {
        self.write_bytes(s.as_bytes());
        Ok(())
    }
}

/// Fingerprint of one value under a domain-separating label.
pub fn fingerprint<T: Debug>(label: &str, value: &T) -> u128 {
    let mut h = StableHasher::new();
    h.write_bytes(label.as_bytes());
    h.write_bytes(&[0xFF]); // label/value separator outside UTF-8
    h.write_debug(value);
    h.finish()
}

/// Combine already-computed fingerprints under a label, with explicit
/// separators so part boundaries cannot alias.
pub fn combine(label: &str, parts: &[u128]) -> u128 {
    let mut h = StableHasher::new();
    h.write_bytes(label.as_bytes());
    for &p in parts {
        h.write_bytes(&[0xFE]);
        h.write_u128(p);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn equal_values_hash_equal() {
        let a = vec![("conv1", 3usize), ("fc", 10)];
        let b = a.clone();
        assert_eq!(fingerprint("t", &a), fingerprint("t", &b));
    }

    #[test]
    fn different_values_hash_differently() {
        assert_ne!(fingerprint("t", &1u64), fingerprint("t", &2u64));
    }

    #[test]
    fn label_separates_domains() {
        assert_ne!(fingerprint("a", &1u64), fingerprint("b", &1u64));
    }

    #[test]
    fn combine_is_order_sensitive() {
        let (x, y) = (fingerprint("t", &1u64), fingerprint("t", &2u64));
        assert_ne!(combine("c", &[x, y]), combine("c", &[y, x]));
    }

    #[test]
    fn schema_version_is_mixed_into_every_hash() {
        // a fresh hasher already differs from the bare FNV offset basis,
        // so keys from builds without (or with another) schema version
        // cannot collide with this build's keys
        assert_ne!(StableHasher::new().finish(), FNV_OFFSET_128);
        let mut v0 = StableHasher {
            state: FNV_OFFSET_128,
        };
        v0.write_bytes(b"same payload");
        let mut v1 = StableHasher::new();
        v1.write_bytes(b"same payload");
        assert_ne!(v0.finish(), v1.finish());
    }
}
