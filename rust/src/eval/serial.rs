//! Hand-rolled binary codec for the pipeline's cached artifacts.
//!
//! The disk cache (`eval::diskcache`) persists stage outputs — prune
//! plans, mapping plans, input profiles, sim reports — across
//! processes. The crate has no serde dependency, so each artifact type
//! implements [`Persist`]: a flat little-endian encoding with no
//! self-description. That is safe because entries are only ever read
//! back under the exact content-hash key that produced them *and* the
//! store segregates by [`crate::eval::hash::HASH_SCHEMA_VERSION`] and
//! its own format version; any layout change must bump one of those.
//! Decoding is paranoid anyway — every length is bounds-checked and
//! every enum tag validated — because a torn or corrupted file must
//! surface as an error (→ cache miss), never as a panic or a subtly
//! wrong artifact.

use crate::hw::units::UnitKind;
use crate::mapping::duplication::Strategy;
use crate::mapping::loopnest::{Binding, Loop, LoopAxis, Loopnest};
use crate::mapping::planner::{FaultPlanSummary, MappingPlan, OpMapping};
use crate::mapping::tiling::{MacroTile, OpTiling, Round};
use crate::pruning::workflow::{LayerPrune, PrunePlan};
use crate::sim::access::Counters;
use crate::sim::energy::EnergyBreakdown;
use crate::sim::input_sparsity::{ActivationProfile, InputProfiles};
use crate::sim::report::{OpReport, SimReport};
use crate::sparsity::compress::CompressedLayout;
use crate::sparsity::flexblock::FlexBlock;
use crate::sparsity::index::IndexStorage;
use crate::sparsity::mask::LayerCtx;
use crate::sparsity::pattern::{BlockPattern, Dim, PatternKind};
use crate::util::bits::{BitMatrix, BitVec};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// Bounds-checked cursor over a decode buffer.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// The next `n` bytes, or an error on a short (torn) buffer.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "truncated artifact: need {n} bytes at offset {}, have {}",
            self.pos,
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Error unless the buffer was consumed exactly.
    pub fn done(&self) -> Result<()> {
        ensure!(
            self.remaining() == 0,
            "artifact has {} trailing bytes",
            self.remaining()
        );
        Ok(())
    }
}

/// Flat binary encoding for one artifact type. `put` is infallible
/// (appends to a growable buffer); `get` must reject any byte sequence
/// it did not itself produce.
pub trait Persist: Sized {
    fn put(&self, w: &mut Vec<u8>);
    fn get(r: &mut Reader<'_>) -> Result<Self>;
}

/// Serialize a value to a standalone byte buffer.
pub fn encode<T: Persist>(v: &T) -> Vec<u8> {
    let mut w = Vec::new();
    v.put(&mut w);
    w
}

/// Deserialize a value, requiring the buffer to be consumed exactly.
pub fn decode<T: Persist>(buf: &[u8]) -> Result<T> {
    let mut r = Reader::new(buf);
    let v = T::get(&mut r)?;
    r.done()?;
    Ok(v)
}

// ---------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------

macro_rules! persist_int {
    ($($ty:ty),+) => {$(
        impl Persist for $ty {
            fn put(&self, w: &mut Vec<u8>) {
                w.extend_from_slice(&self.to_le_bytes());
            }
            fn get(r: &mut Reader<'_>) -> Result<Self> {
                let n = std::mem::size_of::<$ty>();
                let mut b = [0u8; std::mem::size_of::<$ty>()];
                b.copy_from_slice(r.take(n)?);
                Ok(<$ty>::from_le_bytes(b))
            }
        }
    )+};
}

persist_int!(u8, u32, u64, u128);

impl Persist for usize {
    fn put(&self, w: &mut Vec<u8>) {
        (*self as u64).put(w);
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        let v = u64::get(r)?;
        usize::try_from(v).context("usize overflow in artifact")
    }
}

impl Persist for f64 {
    // Bit-exact roundtrip (NaN payloads, signed zero): the golden suite
    // asserts content digests over `Debug` renderings, so the decoded
    // value must be *the same bits*, not merely numerically close.
    fn put(&self, w: &mut Vec<u8>) {
        self.to_bits().put(w);
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        Ok(f64::from_bits(u64::get(r)?))
    }
}

impl Persist for bool {
    fn put(&self, w: &mut Vec<u8>) {
        w.push(u8::from(*self));
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        match u8::get(r)? {
            0 => Ok(false),
            1 => Ok(true),
            t => bail!("invalid bool tag {t}"),
        }
    }
}

impl Persist for String {
    fn put(&self, w: &mut Vec<u8>) {
        self.len().put(w);
        w.extend_from_slice(self.as_bytes());
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        let n = usize::get(r)?;
        let bytes = r.take(n)?;
        String::from_utf8(bytes.to_vec()).context("invalid UTF-8 in artifact string")
    }
}

impl<T: Persist> Persist for Vec<T> {
    fn put(&self, w: &mut Vec<u8>) {
        self.len().put(w);
        for v in self {
            v.put(w);
        }
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        let n = usize::get(r)?;
        // Every element encodes to >= 1 byte, so a length exceeding the
        // remaining buffer is corrupt — reject before reserving memory.
        ensure!(n <= r.remaining(), "vector length {n} exceeds buffer");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(T::get(r)?);
        }
        Ok(out)
    }
}

impl<T: Persist> Persist for Option<T> {
    fn put(&self, w: &mut Vec<u8>) {
        match self {
            None => w.push(0),
            Some(v) => {
                w.push(1);
                v.put(w);
            }
        }
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        match u8::get(r)? {
            0 => Ok(None),
            1 => Ok(Some(T::get(r)?)),
            t => bail!("invalid option tag {t}"),
        }
    }
}

impl<K: Persist + Ord, V: Persist> Persist for BTreeMap<K, V> {
    fn put(&self, w: &mut Vec<u8>) {
        self.len().put(w);
        for (k, v) in self {
            k.put(w);
            v.put(w);
        }
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        let n = usize::get(r)?;
        ensure!(n <= r.remaining(), "map length {n} exceeds buffer");
        let mut out = BTreeMap::new();
        for _ in 0..n {
            let k = K::get(r)?;
            let v = V::get(r)?;
            ensure!(out.insert(k, v).is_none(), "duplicate map key in artifact");
        }
        Ok(out)
    }
}

impl<A: Persist, B: Persist> Persist for (A, B) {
    fn put(&self, w: &mut Vec<u8>) {
        self.0.put(w);
        self.1.put(w);
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        Ok((A::get(r)?, B::get(r)?))
    }
}

impl<A: Persist, B: Persist, C: Persist> Persist for (A, B, C) {
    fn put(&self, w: &mut Vec<u8>) {
        self.0.put(w);
        self.1.put(w);
        self.2.put(w);
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        Ok((A::get(r)?, B::get(r)?, C::get(r)?))
    }
}

// ---------------------------------------------------------------------
// Bit containers
// ---------------------------------------------------------------------

impl Persist for BitVec {
    fn put(&self, w: &mut Vec<u8>) {
        self.len().put(w);
        self.words().to_vec().put(w);
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        let len = usize::get(r)?;
        let words = Vec::<u64>::get(r)?;
        BitVec::from_raw(len, words)
    }
}

impl Persist for BitMatrix {
    fn put(&self, w: &mut Vec<u8>) {
        self.rows().put(w);
        self.cols().put(w);
        self.bit_vec().put(w);
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        let rows = usize::get(r)?;
        let cols = usize::get(r)?;
        let bits = BitVec::get(r)?;
        BitMatrix::from_raw(rows, cols, bits)
    }
}

// ---------------------------------------------------------------------
// Enums (explicit u8 tags; adding a variant requires a new tag at the
// end plus a HASH_SCHEMA_VERSION or FORMAT_VERSION bump)
// ---------------------------------------------------------------------

impl Persist for Dim {
    fn put(&self, w: &mut Vec<u8>) {
        match self {
            Dim::Fixed(n) => {
                w.push(0);
                n.put(w);
            }
            Dim::Full => w.push(1),
            Dim::PerChannel => w.push(2),
        }
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        match u8::get(r)? {
            0 => Ok(Dim::Fixed(usize::get(r)?)),
            1 => Ok(Dim::Full),
            2 => Ok(Dim::PerChannel),
            t => bail!("invalid Dim tag {t}"),
        }
    }
}

impl Persist for PatternKind {
    fn put(&self, w: &mut Vec<u8>) {
        w.push(match self {
            PatternKind::FullBlock => 0,
            PatternKind::IntraBlock => 1,
        });
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        match u8::get(r)? {
            0 => Ok(PatternKind::FullBlock),
            1 => Ok(PatternKind::IntraBlock),
            t => bail!("invalid PatternKind tag {t}"),
        }
    }
}

impl Persist for Strategy {
    fn put(&self, w: &mut Vec<u8>) {
        w.push(match self {
            Strategy::Spatial => 0,
            Strategy::Duplicate => 1,
        });
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        match u8::get(r)? {
            0 => Ok(Strategy::Spatial),
            1 => Ok(Strategy::Duplicate),
            t => bail!("invalid Strategy tag {t}"),
        }
    }
}

impl Persist for LoopAxis {
    fn put(&self, w: &mut Vec<u8>) {
        w.push(match self {
            LoopAxis::RowTile => 0,
            LoopAxis::ColTile => 1,
            LoopAxis::Vector => 2,
            LoopAxis::Bit => 3,
            LoopAxis::Group => 4,
        });
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        match u8::get(r)? {
            0 => Ok(LoopAxis::RowTile),
            1 => Ok(LoopAxis::ColTile),
            2 => Ok(LoopAxis::Vector),
            3 => Ok(LoopAxis::Bit),
            4 => Ok(LoopAxis::Group),
            t => bail!("invalid LoopAxis tag {t}"),
        }
    }
}

impl Persist for Binding {
    fn put(&self, w: &mut Vec<u8>) {
        match self {
            Binding::Temporal => w.push(0),
            Binding::Spatial { dim } => {
                w.push(1);
                dim.put(w);
            }
        }
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        match u8::get(r)? {
            0 => Ok(Binding::Temporal),
            1 => Ok(Binding::Spatial {
                dim: usize::get(r)?,
            }),
            t => bail!("invalid Binding tag {t}"),
        }
    }
}

impl Persist for UnitKind {
    fn put(&self, w: &mut Vec<u8>) {
        w.push(match self {
            UnitKind::CimArray => 0,
            UnitKind::AdderTree => 1,
            UnitKind::ShiftAdd => 2,
            UnitKind::Accumulator => 3,
            UnitKind::PreProc => 4,
            UnitKind::ZeroDetect => 5,
            UnitKind::Mux => 6,
            UnitKind::PostProc => 7,
            UnitKind::IndexMem => 8,
            UnitKind::GlobalInBuf => 9,
            UnitKind::GlobalOutBuf => 10,
            UnitKind::WeightBuf => 11,
            UnitKind::LocalBuf => 12,
        });
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        match u8::get(r)? {
            0 => Ok(UnitKind::CimArray),
            1 => Ok(UnitKind::AdderTree),
            2 => Ok(UnitKind::ShiftAdd),
            3 => Ok(UnitKind::Accumulator),
            4 => Ok(UnitKind::PreProc),
            5 => Ok(UnitKind::ZeroDetect),
            6 => Ok(UnitKind::Mux),
            7 => Ok(UnitKind::PostProc),
            8 => Ok(UnitKind::IndexMem),
            9 => Ok(UnitKind::GlobalInBuf),
            10 => Ok(UnitKind::GlobalOutBuf),
            11 => Ok(UnitKind::WeightBuf),
            12 => Ok(UnitKind::LocalBuf),
            t => bail!("invalid UnitKind tag {t}"),
        }
    }
}

// ---------------------------------------------------------------------
// Artifact structs (field lists must stay exhaustive — a new field
// silently defaulting would poison cross-process determinism)
// ---------------------------------------------------------------------

macro_rules! persist_struct {
    ($ty:ty { $($field:ident),+ $(,)? }) => {
        impl Persist for $ty {
            fn put(&self, w: &mut Vec<u8>) {
                $(self.$field.put(w);)+
            }
            fn get(r: &mut Reader<'_>) -> Result<Self> {
                Ok(Self { $($field: Persist::get(r)?),+ })
            }
        }
    };
}

persist_struct!(BlockPattern { kind, m, n, ratio, pattern_set });
persist_struct!(FlexBlock { patterns, name });
persist_struct!(LayerCtx { per_channel });
persist_struct!(LayerPrune { fb, mask, ctx });
persist_struct!(PrunePlan { layers });

persist_struct!(MvmDims { rows, cols, n_vectors, groups });
persist_struct!(CompressedLayout {
    orig_rows,
    orig_cols,
    comp_rows,
    comp_cols,
    row_lengths,
    broadcast,
    nnz,
    block_index_count,
    elem_index_count,
    misaligned_cols,
    routed_rows,
});
persist_struct!(IndexStorage {
    block_index_bits,
    elem_index_bits,
    n_block_indices,
    n_elem_indices,
});
persist_struct!(MacroTile { rows_used, cols_used, occupied });
persist_struct!(Round {
    tiles,
    vectors_per_macro,
    weight_bytes,
    outputs,
    input_rows,
});
persist_struct!(OpTiling {
    tiles_r,
    tiles_c,
    rounds,
    utilization,
    groups_per_tile,
});
persist_struct!(Loop { axis, trips, binding });
persist_struct!(Loopnest { loops });
persist_struct!(OpMapping {
    op,
    name,
    dims,
    fb,
    layout,
    tiling,
    strategy,
    index,
    rearrange_moved_bytes,
    fault_moved_bytes,
    loopnest,
});
persist_struct!(FaultPlanSummary {
    total_macros,
    usable_macros,
    full_geometry,
    effective_geometry,
    capacity_loss,
    repair_fraction,
    baseline_rounds,
    degraded_rounds,
    repair_bytes,
});
persist_struct!(MappingPlan { arch_name, ops, faults });

persist_struct!(ActivationProfile { bit_zero_prob });
persist_struct!(InputProfiles { per_layer, fallback });

persist_struct!(EnergyBreakdown {
    dynamic_pj,
    static_pj,
    total_pj,
});
persist_struct!(Counters {
    compute,
    mem_reads,
    mem_writes,
});
persist_struct!(OpReport {
    op,
    name,
    kind,
    rounds,
    cycles,
    utilization,
    eff_bits,
    macs,
});

impl Persist for SimReport {
    // The cache-provenance note is deliberately NOT persisted: it
    // records how *this process* produced the report, which is
    // meaningless to a different process restoring the artifact.
    // `Evaluator::evaluate` stamps a fresh note on every returned
    // clone, and `content_digest` scrubs it, so cached and fresh
    // evaluations stay bit-identical.
    fn put(&self, w: &mut Vec<u8>) {
        self.arch.put(w);
        self.network.put(w);
        self.sparsity_label.put(w);
        self.total_cycles.put(w);
        self.latency_us.put(w);
        self.energy.put(w);
        self.counters.put(w);
        self.ops.put(w);
        self.mean_utilization.put(w);
        self.mean_skip_ratio.put(w);
        self.index_bytes.put(w);
        self.stage_totals.put(w);
        self.faults.put(w);
    }
    fn get(r: &mut Reader<'_>) -> Result<Self> {
        Ok(SimReport {
            arch: Persist::get(r)?,
            network: Persist::get(r)?,
            sparsity_label: Persist::get(r)?,
            total_cycles: Persist::get(r)?,
            latency_us: Persist::get(r)?,
            energy: Persist::get(r)?,
            counters: Persist::get(r)?,
            ops: Persist::get(r)?,
            mean_utilization: Persist::get(r)?,
            mean_skip_ratio: Persist::get(r)?,
            index_bytes: Persist::get(r)?,
            stage_totals: Persist::get(r)?,
            faults: Persist::get(r)?,
            cache: None,
        })
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn roundtrip<T: Persist + std::fmt::Debug + PartialEq>(v: T) {
        let bytes = encode(&v);
        let back: T = decode(&bytes).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn primitives_roundtrip() {
        roundtrip(0u8);
        roundtrip(u32::MAX);
        roundtrip(u64::MAX);
        roundtrip(u128::MAX);
        roundtrip(usize::MAX);
        roundtrip(true);
        roundtrip(f64::NEG_INFINITY);
        roundtrip(-0.0f64);
        roundtrip(String::from("héllo"));
        roundtrip(vec![1u64, 2, 3]);
        roundtrip(Option::<u64>::None);
        roundtrip(Some(7u64));
        roundtrip((1usize, 2u64, 3.5f64));
        let mut m = BTreeMap::new();
        m.insert(3usize, 9u64);
        m.insert(1usize, 4u64);
        roundtrip(m);
    }

    #[test]
    fn f64_roundtrip_is_bit_exact() {
        let v = f64::from_bits(0x7ff8_0000_dead_beef); // NaN with payload
        let back: f64 = decode(&encode(&v)).unwrap();
        assert_eq!(back.to_bits(), v.to_bits());
    }

    #[test]
    fn truncated_buffer_is_an_error() {
        let bytes = encode(&String::from("abcdef"));
        for cut in 0..bytes.len() {
            assert!(
                decode::<String>(&bytes[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn trailing_bytes_are_an_error() {
        let mut bytes = encode(&42u64);
        bytes.push(0);
        assert!(decode::<u64>(&bytes).is_err());
    }

    #[test]
    fn bad_enum_tags_are_errors_not_panics() {
        assert!(decode::<bool>(&[2]).is_err());
        assert!(decode::<Option<u8>>(&[9, 0]).is_err());
        assert!(decode::<UnitKind>(&[13]).is_err());
        assert!(decode::<Dim>(&[3]).is_err());
    }

    #[test]
    fn absurd_vector_length_is_rejected_without_allocation() {
        let bytes = encode(&u64::MAX); // "length" far beyond the buffer
        assert!(decode::<Vec<u64>>(&bytes).is_err());
    }

    #[test]
    fn bit_containers_roundtrip_and_validate() {
        let mut m = BitMatrix::zeros(5, 7);
        m.set(0, 0, true);
        m.set(4, 6, true);
        m.set(2, 3, true);
        let bytes = encode(&m);
        let back: BitMatrix = decode(&bytes).unwrap();
        assert_eq!(back.rows(), 5);
        assert_eq!(back.cols(), 7);
        assert_eq!(back.count_ones(), 3);
        assert!(back.get(4, 6));

        // A stray bit beyond `len` (torn/bit-flipped file) must be
        // rejected by BitVec::from_raw, not silently accepted.
        assert!(BitVec::from_raw(3, vec![0b1000]).is_err());
        assert!(BitVec::from_raw(3, vec![]).is_err());
        assert!(BitMatrix::from_raw(2, 3, BitVec::zeros(5)).is_err());
    }

    #[test]
    fn sim_report_roundtrips_with_identical_content_digest() {
        let mut dynamic_pj = BTreeMap::new();
        dynamic_pj.insert(UnitKind::CimArray, 12.5);
        dynamic_pj.insert(UnitKind::PostProc, 0.125);
        let energy = EnergyBreakdown {
            dynamic_pj,
            static_pj: 3.0,
            total_pj: 15.625,
        };
        let mut counters = Counters::new();
        counters.compute.insert(UnitKind::CimArray, 1000);
        counters.mem_reads.insert(UnitKind::WeightBuf, 17);
        let rep = SimReport {
            arch: "usecase".into(),
            network: "net".into(),
            sparsity_label: "Dense".into(),
            total_cycles: 123_456,
            latency_us: 0.625,
            energy,
            counters,
            ops: vec![OpReport {
                op: 0,
                name: "conv1".into(),
                kind: "Conv".into(),
                rounds: 4,
                cycles: 999,
                utilization: 0.75,
                eff_bits: 5.5,
                macs: 1 << 20,
            }],
            mean_utilization: 0.75,
            mean_skip_ratio: 0.25,
            index_bytes: 2048,
            stage_totals: (10, 20, 30),
            faults: Some(FaultPlanSummary {
                total_macros: 4,
                usable_macros: 3,
                full_geometry: (128, 128),
                effective_geometry: (112, 128),
                capacity_loss: 0.125,
                repair_fraction: 0.0,
                baseline_rounds: 10,
                degraded_rounds: 12,
                repair_bytes: 256,
            }),
            cache: None,
        };
        let back: SimReport = decode(&encode(&rep)).unwrap();
        assert_eq!(back.content_digest(), rep.content_digest());
        assert_eq!(back.total_cycles, rep.total_cycles);
        assert!(back.cache.is_none());
    }

    #[test]
    fn prune_plan_roundtrips() {
        let mut mask = BitMatrix::ones(8, 16);
        mask.set(3, 5, false);
        let mut layers = BTreeMap::new();
        layers.insert(
            2usize,
            LayerPrune {
                fb: FlexBlock::hybrid(2, 16, 0.8),
                mask,
                ctx: LayerCtx { per_channel: 9 },
            },
        );
        let plan = PrunePlan { layers };
        let back: PrunePlan = decode(&encode(&plan)).unwrap();
        assert_eq!(
            crate::eval::hash::fingerprint("p", &back),
            crate::eval::hash::fingerprint("p", &plan)
        );
    }
}
