//! Persistent, cross-process artifact store backing the in-memory
//! stage caches (docs/eval-pipeline.md).
//!
//! Layout: `<cache-dir>/v<FORMAT>-s<HASH_SCHEMA_VERSION>/<stage>/<key:032x>`,
//! one file per artifact, named by the stage's 128-bit content hash.
//! Bumping either version simply selects a different subdirectory, so
//! stale entries from an older hashing layout or file format can never
//! be read back — they just age out of the old subtree.
//!
//! Crash safety: writes go to a private `.tmp-<pid>-<seq>` file in the
//! store root and are published with an atomic `rename`, so readers
//! never observe a half-written entry under its final name. Each entry
//! carries a header (magic, versions, stage tag, key, payload length,
//! payload checksum); any mismatch — torn write, bit rot, truncation —
//! deletes the entry and reports a miss. The store is best-effort by
//! design: every I/O failure degrades to "cache miss" or "not spilled",
//! never to an evaluation error.
//!
//! Bounds are byte-based. `used` tracks an estimate maintained on
//! store; crossing `max_bytes` triggers [`DiskStore::gc`], which
//! rescans exact sizes and deletes least-recently-used entries (by
//! mtime — loads touch their entry) until the store fits.

use crate::eval::hash::{StableHasher, HASH_SCHEMA_VERSION};
use crate::eval::serial::{decode, encode, Persist, Reader};
use anyhow::{Context, Result};
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, SystemTime};

/// The four memoized pipeline stages, each with its own subdirectory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Prune,
    Mapping,
    Profiles,
    Sim,
}

impl Stage {
    pub const ALL: [Stage; 4] = [Stage::Prune, Stage::Mapping, Stage::Profiles, Stage::Sim];

    /// Tag byte stored in every entry header (guards against a file
    /// moved or hard-linked across stage directories).
    fn tag(self) -> u8 {
        match self {
            Stage::Prune => 0,
            Stage::Mapping => 1,
            Stage::Profiles => 2,
            Stage::Sim => 3,
        }
    }

    /// Subdirectory name.
    pub fn dir(self) -> &'static str {
        match self {
            Stage::Prune => "prune",
            Stage::Mapping => "mapping",
            Stage::Profiles => "profiles",
            Stage::Sim => "sim",
        }
    }
}

/// Default byte bound when `--cache-bytes` is not given: 1 GiB.
pub const DEFAULT_CACHE_BYTES: u64 = 1 << 30;

/// On-disk entry format version. Bump when the header or any
/// [`Persist`] encoding changes shape without a hash-schema bump.
const FORMAT_VERSION: u32 = 1;

const MAGIC: [u8; 4] = *b"CIMC";

/// magic + format + schema + stage tag + key + payload len + checksum.
const HEADER_LEN: usize = 4 + 4 + 4 + 1 + 16 + 8 + 16;

/// Orphaned temp files older than this are swept by `gc` (a crashed
/// writer's leftovers); younger ones may still be mid-write.
const TMP_MAX_AGE: Duration = Duration::from_secs(15 * 60);

/// A content-addressed, byte-bounded, crash-safe artifact store shared
/// by every process of a sweep. All methods are safe to call
/// concurrently from multiple threads and processes.
pub struct DiskStore {
    /// Version-qualified root (`<dir>/v1-s<schema>`).
    root: PathBuf,
    schema: u32,
    max_bytes: u64,
    /// Estimated stored bytes; refreshed exactly by `gc`.
    used: AtomicU64,
    /// Per-process temp-file discriminator.
    seq: AtomicU64,
}

/// Usage of one stage subdirectory.
#[derive(Debug, Clone, Copy)]
pub struct StageUsage {
    pub stage: Stage,
    pub entries: u64,
    pub bytes: u64,
}

/// Snapshot of the store for `ciminus cache stats`.
#[derive(Debug, Clone)]
pub struct DiskCacheStats {
    pub stages: Vec<StageUsage>,
    pub total_entries: u64,
    pub total_bytes: u64,
    pub max_bytes: u64,
    pub root: PathBuf,
}

impl DiskStore {
    /// Open (creating if needed) the store under `dir` for the crate's
    /// current hash schema. `max_bytes == 0` selects
    /// [`DEFAULT_CACHE_BYTES`].
    pub fn open(dir: &Path, max_bytes: u64) -> Result<Self> {
        Self::open_with_schema(dir, max_bytes, HASH_SCHEMA_VERSION)
    }

    /// Schema-parameterized open — lets tests prove that a
    /// `HASH_SCHEMA_VERSION` bump invalidates every existing entry.
    pub fn open_with_schema(dir: &Path, max_bytes: u64, schema: u32) -> Result<Self> {
        let root = dir.join(format!("v{FORMAT_VERSION}-s{schema}"));
        for stage in Stage::ALL {
            fs::create_dir_all(root.join(stage.dir()))
                .with_context(|| format!("creating cache dir under {}", root.display()))?;
        }
        let store = Self {
            root,
            schema,
            max_bytes: if max_bytes == 0 {
                DEFAULT_CACHE_BYTES
            } else {
                max_bytes
            },
            used: AtomicU64::new(0),
            seq: AtomicU64::new(0),
        };
        store.used.store(store.scan().1, Ordering::Relaxed);
        Ok(store)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn max_bytes(&self) -> u64 {
        self.max_bytes
    }

    fn entry_path(&self, stage: Stage, key: u128) -> PathBuf {
        self.root.join(stage.dir()).join(format!("{key:032x}"))
    }

    /// Restore and decode one artifact; `None` on any miss, including a
    /// torn or corrupted entry (which is deleted so it stops costing
    /// bytes). Never fails the evaluation.
    pub fn get<T: Persist>(&self, stage: Stage, key: u128) -> Option<T> {
        let payload = self.load(stage, key)?;
        match decode(&payload) {
            Ok(v) => Some(v),
            Err(_) => {
                // Checksum matched but the payload does not parse: a
                // producer with a different artifact layout wrote it
                // without bumping FORMAT_VERSION. Drop it.
                let _ = fs::remove_file(self.entry_path(stage, key));
                None
            }
        }
    }

    /// Encode and spill one artifact. Best-effort: errors are swallowed
    /// (a full disk must not fail the sweep).
    pub fn put<T: Persist>(&self, stage: Stage, key: u128, value: &T) {
        self.store(stage, key, &encode(value));
    }

    /// Raw payload restore with full header validation.
    fn load(&self, stage: Stage, key: u128) -> Option<Vec<u8>> {
        let path = self.entry_path(stage, key);
        let raw = fs::read(&path).ok()?;
        match validate_entry(&raw, self.schema, stage, key) {
            Ok(payload) => {
                touch(&path);
                Some(payload.to_vec())
            }
            Err(_) => {
                let _ = fs::remove_file(&path);
                None
            }
        }
    }

    /// Raw payload spill: atomic tmp-file + rename publish.
    fn store(&self, stage: Stage, key: u128, payload: &[u8]) {
        let path = self.entry_path(stage, key);
        if path.exists() {
            touch(&path); // refresh LRU position; contents are equal by key
            return;
        }
        let mut record = Vec::with_capacity(HEADER_LEN + payload.len());
        record.extend_from_slice(&MAGIC);
        record.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        record.extend_from_slice(&self.schema.to_le_bytes());
        record.push(stage.tag());
        record.extend_from_slice(&key.to_le_bytes());
        record.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        record.extend_from_slice(&checksum(payload).to_le_bytes());
        record.extend_from_slice(payload);

        let tmp = self.root.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            self.seq.fetch_add(1, Ordering::Relaxed)
        ));
        if fs::write(&tmp, &record).is_err() {
            let _ = fs::remove_file(&tmp);
            return;
        }
        if fs::rename(&tmp, &path).is_err() {
            let _ = fs::remove_file(&tmp);
            return;
        }
        let used = self
            .used
            .fetch_add(record.len() as u64, Ordering::Relaxed)
            .saturating_add(record.len() as u64);
        if used > self.max_bytes {
            let _ = self.gc();
        }
    }

    /// Enumerate live entries and their exact sizes. Returns
    /// `(entries, total_bytes)`; I/O errors skip the affected entry.
    fn scan(&self) -> (Vec<(PathBuf, u64, SystemTime)>, u64) {
        let mut entries = Vec::new();
        let mut total = 0u64;
        for stage in Stage::ALL {
            let Ok(dir) = fs::read_dir(self.root.join(stage.dir())) else {
                continue;
            };
            for ent in dir.flatten() {
                let Ok(meta) = ent.metadata() else { continue };
                if !meta.is_file() {
                    continue;
                }
                let mtime = meta.modified().unwrap_or(SystemTime::UNIX_EPOCH);
                total = total.saturating_add(meta.len());
                entries.push((ent.path(), meta.len(), mtime));
            }
        }
        (entries, total)
    }

    /// Garbage-collect: sweep orphaned temp files, then delete
    /// least-recently-used entries until the store fits `max_bytes`.
    /// Returns the bytes reclaimed. Safe to race with other processes —
    /// a concurrent deletion just makes our removal a no-op.
    pub fn gc(&self) -> Result<u64> {
        let now = SystemTime::now();
        if let Ok(dir) = fs::read_dir(&self.root) {
            for ent in dir.flatten() {
                let name = ent.file_name();
                let stale = name.to_string_lossy().starts_with(".tmp-")
                    && ent
                        .metadata()
                        .and_then(|m| m.modified())
                        .map(|t| now.duration_since(t).unwrap_or_default() > TMP_MAX_AGE)
                        .unwrap_or(true);
                if stale {
                    let _ = fs::remove_file(ent.path());
                }
            }
        }
        let (mut entries, mut total) = self.scan();
        entries.sort_by_key(|(_, _, mtime)| *mtime);
        let mut reclaimed = 0u64;
        let mut oldest_first = entries.into_iter();
        while total > self.max_bytes {
            let Some((path, len, _)) = oldest_first.next() else {
                break;
            };
            if fs::remove_file(&path).is_ok() {
                reclaimed = reclaimed.saturating_add(len);
            }
            // Subtract even on a racing removal: the bytes are gone.
            total = total.saturating_sub(len);
        }
        self.used.store(total, Ordering::Relaxed);
        Ok(reclaimed)
    }

    /// Exact usage snapshot (rescans the directory tree).
    pub fn stats(&self) -> DiskCacheStats {
        let mut stages = Vec::with_capacity(Stage::ALL.len());
        let mut total_entries = 0u64;
        let mut total_bytes = 0u64;
        for stage in Stage::ALL {
            let mut entries = 0u64;
            let mut bytes = 0u64;
            if let Ok(dir) = fs::read_dir(self.root.join(stage.dir())) {
                for ent in dir.flatten() {
                    let Ok(meta) = ent.metadata() else { continue };
                    if meta.is_file() {
                        entries += 1;
                        bytes = bytes.saturating_add(meta.len());
                    }
                }
            }
            total_entries += entries;
            total_bytes = total_bytes.saturating_add(bytes);
            stages.push(StageUsage {
                stage,
                entries,
                bytes,
            });
        }
        DiskCacheStats {
            stages,
            total_entries,
            total_bytes,
            max_bytes: self.max_bytes,
            root: self.root.clone(),
        }
    }
}

fn checksum(payload: &[u8]) -> u128 {
    let mut h = StableHasher::new();
    h.write_bytes(payload);
    h.finish()
}

/// Validate an entry's header against what the reader expects; returns
/// the payload slice on success.
fn validate_entry(raw: &[u8], schema: u32, stage: Stage, key: u128) -> Result<&[u8]> {
    let mut r = Reader::new(raw);
    let magic = r.take(4)?;
    anyhow::ensure!(magic == MAGIC, "bad magic");
    let format = u32::get(&mut r)?;
    anyhow::ensure!(format == FORMAT_VERSION, "format version {format}");
    let got_schema = u32::get(&mut r)?;
    anyhow::ensure!(got_schema == schema, "hash schema {got_schema}");
    let tag = u8::get(&mut r)?;
    anyhow::ensure!(tag == stage.tag(), "stage tag {tag}");
    let got_key = u128::get(&mut r)?;
    anyhow::ensure!(got_key == key, "key mismatch");
    let len = u64::get(&mut r)?;
    let sum = u128::get(&mut r)?;
    anyhow::ensure!(len == r.remaining() as u64, "payload length mismatch");
    let payload = r.take(len as usize)?;
    anyhow::ensure!(checksum(payload) == sum, "checksum mismatch");
    Ok(payload)
}

/// Refresh an entry's mtime so GC sees it as recently used. Best
/// effort; on filesystems without mtime updates LRU degrades to FIFO.
fn touch(path: &Path) {
    if let Ok(f) = fs::File::options().write(true).open(path) {
        let _ = f.set_modified(SystemTime::now());
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ciminus-diskcache-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_and_lru_touch() {
        let dir = tmp_dir("roundtrip");
        let store = DiskStore::open(&dir, 0).unwrap();
        assert_eq!(store.get::<u64>(Stage::Sim, 7), None);
        store.put(Stage::Sim, 7, &42u64);
        assert_eq!(store.get::<u64>(Stage::Sim, 7), Some(42));
        // A second open sees the same entry (cross-process behaviour).
        let store2 = DiskStore::open(&dir, 0).unwrap();
        assert_eq!(store2.get::<u64>(Stage::Sim, 7), Some(42));
        // Same key under a different stage is distinct.
        assert_eq!(store2.get::<u64>(Stage::Prune, 7), None);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn schema_bump_invalidates_everything() {
        let dir = tmp_dir("schema");
        let store = DiskStore::open_with_schema(&dir, 0, 1).unwrap();
        store.put(Stage::Mapping, 9, &1234u64);
        assert_eq!(store.get::<u64>(Stage::Mapping, 9), Some(1234));
        let bumped = DiskStore::open_with_schema(&dir, 0, 2).unwrap();
        assert_eq!(bumped.get::<u64>(Stage::Mapping, 9), None);
        assert_eq!(bumped.stats().total_entries, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_and_truncated_entries_are_misses_and_deleted() {
        let dir = tmp_dir("corrupt");
        let store = DiskStore::open(&dir, 0).unwrap();
        store.put(Stage::Profiles, 3, &String::from("payload-bytes"));
        let path = store.entry_path(Stage::Profiles, 3);
        // Flip one payload byte.
        let mut raw = fs::read(&path).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0x40;
        fs::write(&path, &raw).unwrap();
        assert_eq!(store.get::<String>(Stage::Profiles, 3), None);
        assert!(!path.exists(), "corrupt entry is deleted");
        // Torn trailing write (truncation).
        store.put(Stage::Profiles, 3, &String::from("payload-bytes"));
        let raw = fs::read(&path).unwrap();
        fs::write(&path, &raw[..raw.len() / 2]).unwrap();
        assert_eq!(store.get::<String>(Stage::Profiles, 3), None);
        assert!(!path.exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_enforces_byte_bound() {
        let dir = tmp_dir("gc");
        // Tiny bound: every entry is ~100 bytes, so 3 entries overflow.
        let store = DiskStore::open(&dir, 256).unwrap();
        for k in 0..6u128 {
            store.put(Stage::Sim, k, &vec![k as u64; 8]);
        }
        let _ = store.gc();
        let stats = store.stats();
        assert!(
            stats.total_bytes <= 256,
            "gc left {} bytes over the 256-byte bound",
            stats.total_bytes
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_reports_per_stage_usage() {
        let dir = tmp_dir("stats");
        let store = DiskStore::open(&dir, 0).unwrap();
        store.put(Stage::Prune, 1, &1u64);
        store.put(Stage::Sim, 1, &2u64);
        store.put(Stage::Sim, 2, &3u64);
        let s = store.stats();
        assert_eq!(s.total_entries, 3);
        let sim = s.stages.iter().find(|u| u.stage == Stage::Sim).unwrap();
        assert_eq!(sim.entries, 2);
        assert!(s.total_bytes > 0 && s.max_bytes == DEFAULT_CACHE_BYTES);
        let _ = fs::remove_dir_all(&dir);
    }
}
