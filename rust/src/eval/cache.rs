//! Bounded per-stage artifact caches with hit/miss/eviction counters.
//!
//! One `Cache<T>` holds one artifact type (prune plans, mapping plans,
//! input profiles, sim reports) keyed by the stage's content hash.
//! Eviction is least-recently-used over a logical tick counter; the
//! scan is O(n) on insert-at-capacity, which is fine at the default
//! capacity (a few hundred entries). Artifact construction runs
//! *outside* the map lock so concurrent sweep workers never serialize
//! behind a slow plan; two workers racing on the same key may both
//! compute (the second insert wins), which is harmless because keys are
//! content hashes of the inputs and the pipeline is deterministic.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Counters for one pipeline stage's cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StageStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl StageStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }
}

struct Entry<T> {
    value: Arc<T>,
    last_used: u64,
}

struct Inner<T> {
    entries: BTreeMap<u128, Entry<T>>,
    tick: u64,
}

pub(crate) struct Cache<T> {
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    inner: Mutex<Inner<T>>,
}

impl<T> Cache<T> {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                entries: BTreeMap::new(),
                tick: 0,
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner<T>> {
        // A poisoned lock means a worker panicked mid-insert; the map
        // itself is still structurally valid (BTreeMap ops are not
        // interrupted by our code between invariant updates).
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn lookup(&self, key: u128) -> Option<Arc<T>> {
        let mut g = self.lock();
        g.tick += 1;
        let tick = g.tick;
        match g.entries.get_mut(&key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.value.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, key: u128, value: Arc<T>) {
        let mut g = self.lock();
        g.tick += 1;
        let tick = g.tick;
        if !g.entries.contains_key(&key) && g.entries.len() >= self.capacity {
            if let Some(oldest) = g
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
            {
                g.entries.remove(&oldest);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        g.entries.insert(
            key,
            Entry {
                value,
                last_used: tick,
            },
        );
    }

    /// Return the cached artifact for `key`, or build, cache, and
    /// return it. The bool is true on a cache hit. `build` runs outside
    /// the lock.
    pub fn get_or_try(
        &self,
        key: u128,
        build: impl FnOnce() -> anyhow::Result<T>,
    ) -> anyhow::Result<(Arc<T>, bool)> {
        if let Some(v) = self.lookup(key) {
            return Ok((v, true));
        }
        let v = Arc::new(build()?);
        self.insert(key, v.clone());
        Ok((v, false))
    }

    pub fn stats(&self) -> StageStats {
        StageStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn hit_miss_counting_and_reuse() {
        let c: Cache<u64> = Cache::new(8);
        let (a, hit) = c.get_or_try(1, || Ok(10)).unwrap();
        assert!(!hit);
        assert_eq!(*a, 10);
        let (b, hit) = c.get_or_try(1, || Ok(99)).unwrap();
        assert!(hit, "second lookup is a hit");
        assert_eq!(*b, 10, "cached value wins; builder not re-run");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
    }

    #[test]
    fn bounded_capacity_evicts_least_recently_used() {
        let c: Cache<u64> = Cache::new(2);
        c.insert(1, Arc::new(1));
        c.insert(2, Arc::new(2));
        assert!(c.lookup(1).is_some()); // 1 is now more recent than 2
        c.insert(3, Arc::new(3)); // evicts 2
        assert!(c.lookup(2).is_none());
        assert!(c.lookup(1).is_some());
        assert!(c.lookup(3).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn build_error_propagates_and_caches_nothing() {
        let c: Cache<u64> = Cache::new(2);
        assert!(c.get_or_try(7, || anyhow::bail!("boom")).is_err());
        let (_, hit) = c.get_or_try(7, || Ok(1)).unwrap();
        assert!(!hit, "failed build left no entry behind");
    }
}
