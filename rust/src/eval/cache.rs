//! Bounded per-stage artifact caches with hit/miss/eviction counters.
//!
//! One `Cache<T>` holds one artifact type (prune plans, mapping plans,
//! input profiles, sim reports) keyed by the stage's content hash.
//! Eviction is least-recently-used over a logical tick counter; the
//! scan is O(n) on insert-at-capacity, which is fine at the default
//! capacity (a few hundred entries). Artifact construction runs
//! *outside* the map lock so concurrent sweep workers never serialize
//! behind a slow plan; two workers racing on the same key may both
//! compute (the second insert wins), which is harmless because keys are
//! content hashes of the inputs and the pipeline is deterministic.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Where one stage's artifact came from. Ordered by cost: a memory hit
/// is free, a disk hit pays deserialization, a compute re-runs the
/// stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StageHit {
    /// Cache miss — the stage was (re)computed.
    #[default]
    Computed,
    /// Served from this process's in-memory cache.
    Memory,
    /// Restored from the shared disk store (`eval::diskcache`).
    Disk,
}

impl StageHit {
    /// True when the stage did not recompute (memory or disk).
    pub fn hit(self) -> bool {
        !matches!(self, StageHit::Computed)
    }

    pub fn from_disk(self) -> bool {
        matches!(self, StageHit::Disk)
    }
}

/// Counters for one pipeline stage's cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StageStats {
    /// In-memory hits.
    pub hits: u64,
    /// Artifacts restored from the disk store.
    pub disk_hits: u64,
    /// Full recomputes.
    pub misses: u64,
    pub evictions: u64,
}

impl StageStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.disk_hits + self.misses
    }

    /// Fold another stage's counters into this one (worker → supervisor
    /// aggregation over the frame protocol).
    pub fn merge(&mut self, other: &StageStats) {
        self.hits += other.hits;
        self.disk_hits += other.disk_hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
    }
}

struct Entry<T> {
    value: Arc<T>,
    last_used: u64,
}

struct Inner<T> {
    entries: BTreeMap<u128, Entry<T>>,
    tick: u64,
}

pub(crate) struct Cache<T> {
    capacity: usize,
    hits: AtomicU64,
    disk_hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    inner: Mutex<Inner<T>>,
}

impl<T> Cache<T> {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                entries: BTreeMap::new(),
                tick: 0,
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner<T>> {
        // A poisoned lock means a worker panicked mid-insert; the map
        // itself is still structurally valid (BTreeMap ops are not
        // interrupted by our code between invariant updates).
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn lookup(&self, key: u128) -> Option<Arc<T>> {
        let mut g = self.lock();
        g.tick += 1;
        let tick = g.tick;
        match g.entries.get_mut(&key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.value.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, key: u128, value: Arc<T>) {
        let mut g = self.lock();
        g.tick += 1;
        let tick = g.tick;
        if !g.entries.contains_key(&key) && g.entries.len() >= self.capacity {
            if let Some(oldest) = g
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
            {
                g.entries.remove(&oldest);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        g.entries.insert(
            key,
            Entry {
                value,
                last_used: tick,
            },
        );
    }

    /// Memory-only probe: counts a hit on success and *nothing* on a
    /// miss — the caller decides whether the miss becomes a disk
    /// restore or a recompute, so `lookups()` never double-counts.
    fn probe(&self, key: u128) -> Option<Arc<T>> {
        let mut g = self.lock();
        g.tick += 1;
        let tick = g.tick;
        let e = g.entries.get_mut(&key)?;
        e.last_used = tick;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(e.value.clone())
    }

    /// Three-level lookup: memory, then `restore` (the disk store),
    /// then `build`. A freshly built artifact is handed to `spill` so
    /// the disk layer can persist it. `restore` and `build` run outside
    /// the lock; two workers racing on one key may both compute (the
    /// second insert wins), which is harmless because the pipeline is
    /// deterministic.
    pub fn get_or_restore(
        &self,
        key: u128,
        restore: impl FnOnce() -> Option<T>,
        spill: impl FnOnce(&T),
        build: impl FnOnce() -> anyhow::Result<T>,
    ) -> anyhow::Result<(Arc<T>, StageHit)> {
        if let Some(v) = self.probe(key) {
            return Ok((v, StageHit::Memory));
        }
        if let Some(v) = restore() {
            self.disk_hits.fetch_add(1, Ordering::Relaxed);
            let v = Arc::new(v);
            self.insert(key, v.clone());
            return Ok((v, StageHit::Disk));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let v = Arc::new(build()?);
        self.insert(key, v.clone());
        spill(&v);
        Ok((v, StageHit::Computed))
    }

    /// Return the cached artifact for `key`, or build, cache, and
    /// return it. The bool is true on a cache hit. `build` runs outside
    /// the lock.
    pub fn get_or_try(
        &self,
        key: u128,
        build: impl FnOnce() -> anyhow::Result<T>,
    ) -> anyhow::Result<(Arc<T>, bool)> {
        let (v, hit) = self.get_or_restore(key, || None, |_| {}, build)?;
        Ok((v, hit.hit()))
    }

    /// Fold a worker process's counters into this cache's totals (the
    /// supervisor's summary line then reflects the whole sweep).
    pub fn absorb(&self, s: &StageStats) {
        self.hits.fetch_add(s.hits, Ordering::Relaxed);
        self.disk_hits.fetch_add(s.disk_hits, Ordering::Relaxed);
        self.misses.fetch_add(s.misses, Ordering::Relaxed);
        self.evictions.fetch_add(s.evictions, Ordering::Relaxed);
    }

    pub fn stats(&self) -> StageStats {
        StageStats {
            hits: self.hits.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn hit_miss_counting_and_reuse() {
        let c: Cache<u64> = Cache::new(8);
        let (a, hit) = c.get_or_try(1, || Ok(10)).unwrap();
        assert!(!hit);
        assert_eq!(*a, 10);
        let (b, hit) = c.get_or_try(1, || Ok(99)).unwrap();
        assert!(hit, "second lookup is a hit");
        assert_eq!(*b, 10, "cached value wins; builder not re-run");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
    }

    #[test]
    fn bounded_capacity_evicts_least_recently_used() {
        let c: Cache<u64> = Cache::new(2);
        c.insert(1, Arc::new(1));
        c.insert(2, Arc::new(2));
        assert!(c.lookup(1).is_some()); // 1 is now more recent than 2
        c.insert(3, Arc::new(3)); // evicts 2
        assert!(c.lookup(2).is_none());
        assert!(c.lookup(1).is_some());
        assert!(c.lookup(3).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn restore_path_counts_disk_hits_and_spills_fresh_builds() {
        use std::cell::Cell;
        let c: Cache<u64> = Cache::new(8);
        let spilled = Cell::new(0u64);
        // Miss everywhere: builds, then spills.
        let (v, how) = c
            .get_or_restore(1, || None, |v| spilled.set(*v), || Ok(5))
            .unwrap();
        assert_eq!((*v, how), (5, StageHit::Computed));
        assert_eq!(spilled.get(), 5, "fresh build handed to spill");
        // Memory hit: restore/build untouched.
        let (_, how) = c
            .get_or_restore(1, || panic!("no restore"), |_| (), || panic!("no build"))
            .unwrap();
        assert_eq!(how, StageHit::Memory);
        // Disk hit on a cold key: restored value is cached.
        let (v, how) = c
            .get_or_restore(2, || Some(9), |_| panic!("no spill"), || panic!("no build"))
            .unwrap();
        assert_eq!((*v, how), (9, StageHit::Disk));
        let (_, how) = c
            .get_or_restore(2, || None, |_| (), || panic!("no build"))
            .unwrap();
        assert_eq!(how, StageHit::Memory, "restored value entered memory");
        let s = c.stats();
        assert_eq!((s.hits, s.disk_hits, s.misses), (2, 1, 1));
        assert_eq!(s.lookups(), 4);
    }

    #[test]
    fn absorb_folds_external_counters() {
        let c: Cache<u64> = Cache::new(2);
        c.absorb(&StageStats {
            hits: 3,
            disk_hits: 2,
            misses: 1,
            evictions: 4,
        });
        let s = c.stats();
        assert_eq!((s.hits, s.disk_hits, s.misses, s.evictions), (3, 2, 1, 4));
    }

    #[test]
    fn build_error_propagates_and_caches_nothing() {
        let c: Cache<u64> = Cache::new(2);
        assert!(c.get_or_try(7, || anyhow::bail!("boom")).is_err());
        let (_, hit) = c.get_or_try(7, || Ok(1)).unwrap();
        assert!(!hit, "failed build left no entry behind");
    }
}
