//! Unified staged evaluation pipeline (docs/eval-pipeline.md).
//!
//! Every entry point in the crate — the figure studies, the design
//! search, the CLI commands, the validation harness, and the examples —
//! evaluates a design point through the same four typed stages:
//!
//! ```text
//! PruneSpec ──► PrunePlan ─┐
//!                          ├─► MappingPlan ─┐
//! Architecture (planning   │                ├─► SimReport
//!   view) ─────────────────┘                │
//! ProfileSpec ──► InputProfiles ────────────┘
//! ```
//!
//! A [`Scenario`] names one point (workload + prune spec + mapping
//! options + profile spec + architecture + sim options); an
//! [`Evaluator`] runs it, memoizing each stage in a bounded in-memory
//! artifact cache keyed by a stable content hash of that stage's
//! inputs. Sharing one evaluator across a sweep means points that vary
//! only downstream knobs (e.g. fig11's input-skipping on/off pair, the
//! rearrange ablation's strategy column) skip replanning entirely. The
//! mapping stage hashes [`Architecture::planning_view`] — the
//! architecture with simulation-only knobs canonicalized — so archs
//! differing only in those knobs share one cached plan.
#![warn(clippy::unwrap_used)]

pub mod cache;
pub mod diskcache;
pub mod hash;
pub mod serial;

use crate::hw::arch::Architecture;
use crate::mapping::planner::{plan_prevalidated, MappingOptions, MappingPlan};
use crate::pruning::workflow::{PrunePlan, PruningWorkflow};
use crate::sim::engine::{simulate, SimOptions};
use crate::sim::input_sparsity::InputProfiles;
use crate::sim::report::{CacheNote, SimReport};
use crate::sparsity::flexblock::FlexBlock;
use crate::util::json::Json;
use crate::workload::graph::Network;
use cache::{Cache, StageHit, StageStats};
use diskcache::{DiskStore, Stage};
use serial::Persist;
use std::collections::BTreeSet;
use std::fmt;
use std::sync::{Arc, Mutex};

/// How the prune stage produces its `PrunePlan`.
#[derive(Debug, Clone)]
pub enum PruneSpec {
    /// Dense: no pruning, the mapping stage receives no plan.
    None,
    /// Run a uniform FlexBlock pruning workflow over the network.
    Uniform {
        fb: FlexBlock,
        workflow: PruningWorkflow,
    },
    /// Use an externally produced plan (e.g. measured masks from the
    /// PJRT pruning session) as-is.
    Provided(Arc<PrunePlan>),
}

/// How the profile stage produces its `InputProfiles`.
#[derive(Debug, Clone)]
pub enum ProfileSpec {
    /// No activation profiles (input-skipping simulates as dense).
    None,
    /// Deterministic synthetic profiles ([`InputProfiles::synthetic`]).
    Synthetic { bits: usize, zero_frac: f64, seed: u64 },
    /// Externally measured profiles, used as-is.
    Provided(Arc<InputProfiles>),
}

/// One evaluatable design point: everything the pipeline needs, and
/// nothing it has to guess. Cheap to clone (the workload and arch are
/// shared behind `Arc`s).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub arch: Arc<Architecture>,
    pub net: Arc<Network>,
    pub prune: PruneSpec,
    pub mapping: MappingOptions,
    pub profiles: ProfileSpec,
    pub sim: SimOptions,
}

impl Scenario {
    pub fn new(arch: impl Into<Arc<Architecture>>, net: impl Into<Arc<Network>>) -> Self {
        Self {
            arch: arch.into(),
            net: net.into(),
            prune: PruneSpec::None,
            mapping: MappingOptions::default(),
            profiles: ProfileSpec::None,
            sim: SimOptions::default(),
        }
    }

    /// Uniform pruning with the default workflow. A dense FlexBlock is
    /// a no-op (the prune stage is skipped entirely).
    pub fn prune_uniform(self, fb: &FlexBlock) -> Self {
        self.prune_with(PruningWorkflow::default(), fb)
    }

    /// Uniform pruning with a custom workflow. A dense FlexBlock is a
    /// no-op.
    pub fn prune_with(mut self, workflow: PruningWorkflow, fb: &FlexBlock) -> Self {
        self.prune = if fb.is_dense() {
            PruneSpec::None
        } else {
            PruneSpec::Uniform {
                fb: fb.clone(),
                workflow,
            }
        };
        self
    }

    pub fn prune_provided(mut self, p: Arc<PrunePlan>) -> Self {
        self.prune = PruneSpec::Provided(p);
        self
    }

    pub fn with_mapping(mut self, opts: MappingOptions) -> Self {
        self.mapping = opts;
        self
    }

    pub fn synthetic_profiles(mut self, bits: usize, zero_frac: f64, seed: u64) -> Self {
        self.profiles = ProfileSpec::Synthetic {
            bits,
            zero_frac,
            seed,
        };
        self
    }

    pub fn provided_profiles(mut self, p: Arc<InputProfiles>) -> Self {
        self.profiles = ProfileSpec::Provided(p);
        self
    }

    pub fn with_sim(mut self, sim: SimOptions) -> Self {
        self.sim = sim;
        self
    }
}

/// Per-stage cache keys for one scenario, derived once per evaluation.
struct Keys {
    arch: u128,
    net: u128,
    prune: Option<u128>,
    profiles: Option<u128>,
    mapping: u128,
}

fn keys_of(s: &Scenario) -> Keys {
    let arch = hash::fingerprint("arch", s.arch.as_ref());
    let plan_arch = hash::fingerprint("arch/planning", &s.arch.planning_view());
    let net = hash::fingerprint("net", s.net.as_ref());
    let prune = match &s.prune {
        PruneSpec::None => None,
        spec => Some(hash::combine(
            "prune",
            &[net, hash::fingerprint("prune-spec", spec)],
        )),
    };
    let profiles = match &s.profiles {
        ProfileSpec::None => None,
        spec => Some(hash::combine(
            "profiles",
            &[net, hash::fingerprint("profiles-spec", spec)],
        )),
    };
    let mapping = hash::combine(
        "mapping",
        &[
            plan_arch,
            net,
            prune.unwrap_or(0),
            hash::fingerprint("mapping-opts", &s.mapping),
        ],
    );
    Keys {
        arch,
        net,
        prune,
        profiles,
        mapping,
    }
}

/// Aggregate cache counters across the four stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EvalStats {
    pub prune: StageStats,
    pub mapping: StageStats,
    pub profiles: StageStats,
    pub sim: StageStats,
}

impl EvalStats {
    pub fn total_hits(&self) -> u64 {
        self.prune.hits + self.mapping.hits + self.profiles.hits + self.sim.hits
    }

    pub fn total_disk_hits(&self) -> u64 {
        self.prune.disk_hits + self.mapping.disk_hits + self.profiles.disk_hits
            + self.sim.disk_hits
    }

    pub fn total_misses(&self) -> u64 {
        self.prune.misses + self.mapping.misses + self.profiles.misses + self.sim.misses
    }

    pub fn total_evictions(&self) -> u64 {
        self.prune.evictions + self.mapping.evictions + self.profiles.evictions + self.sim.evictions
    }

    /// Fold another evaluator's counters into this one (worker →
    /// supervisor aggregation).
    pub fn merge(&mut self, other: &EvalStats) {
        self.prune.merge(&other.prune);
        self.mapping.merge(&other.mapping);
        self.profiles.merge(&other.profiles);
        self.sim.merge(&other.sim);
    }

    /// JSON shape carried on the worker protocol's `done` frame.
    pub fn to_json(&self) -> Json {
        fn stage(s: &StageStats) -> Json {
            Json::from_pairs(vec![
                ("hits", Json::Num(s.hits as f64)),
                ("disk_hits", Json::Num(s.disk_hits as f64)),
                ("misses", Json::Num(s.misses as f64)),
                ("evictions", Json::Num(s.evictions as f64)),
            ])
        }
        Json::from_pairs(vec![
            ("prune", stage(&self.prune)),
            ("mapping", stage(&self.mapping)),
            ("profiles", stage(&self.profiles)),
            ("sim", stage(&self.sim)),
        ])
    }

    /// Lenient inverse of [`EvalStats::to_json`]: missing fields read
    /// as zero, so frames from an older worker still aggregate.
    pub fn from_json(j: &Json) -> EvalStats {
        fn stage(j: Option<&Json>) -> StageStats {
            let Some(j) = j else {
                return StageStats::default();
            };
            StageStats {
                hits: j.opt_f64("hits", 0.0) as u64,
                disk_hits: j.opt_f64("disk_hits", 0.0) as u64,
                misses: j.opt_f64("misses", 0.0) as u64,
                evictions: j.opt_f64("evictions", 0.0) as u64,
            }
        }
        EvalStats {
            prune: stage(j.get("prune")),
            mapping: stage(j.get("mapping")),
            profiles: stage(j.get("profiles")),
            sim: stage(j.get("sim")),
        }
    }
}

impl fmt::Display for EvalStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "prune {}/{} | mapping {}/{} | profiles {}/{} | sim {}/{} (hits/lookups), \
             {} disk hits, {} replans, {} evicted",
            self.prune.hits + self.prune.disk_hits,
            self.prune.lookups(),
            self.mapping.hits + self.mapping.disk_hits,
            self.mapping.lookups(),
            self.profiles.hits + self.profiles.disk_hits,
            self.profiles.lookups(),
            self.sim.hits + self.sim.disk_hits,
            self.sim.lookups(),
            self.total_disk_hits(),
            self.mapping.misses,
            self.total_evictions(),
        )
    }
}

/// Default per-stage cache capacity (entries, not bytes). Mapping plans
/// and sim reports for the usecase networks are a few hundred KB each,
/// so this bounds the cache to tens of MB worst case.
pub const DEFAULT_CACHE_CAPACITY: usize = 128;

/// Runs [`Scenario`]s through the staged pipeline with per-stage
/// content-hashed memoization. Thread-safe: share one evaluator across
/// all workers of a sweep (see `EvalCtx`).
pub struct Evaluator {
    prune: Cache<PrunePlan>,
    mapping: Cache<MappingPlan>,
    profiles: Cache<InputProfiles>,
    sim: Cache<SimReport>,
    /// Shared cross-process store; stages spill fresh artifacts here
    /// and restore from it on in-memory misses (docs/eval-pipeline.md).
    disk: Option<Arc<DiskStore>>,
    /// Content hashes of architectures already validated — the
    /// `arch.validate()` that used to run on every `plan()`/`simulate()`
    /// call is hoisted here and paid once per distinct architecture.
    validated: Mutex<BTreeSet<u128>>,
}

impl Evaluator {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_CACHE_CAPACITY)
    }

    /// Evaluator with a custom per-stage cache capacity.
    pub fn with_capacity(capacity: usize) -> Self {
        Self::with_capacity_and_disk(capacity, None)
    }

    /// Evaluator backed by a persistent disk store (`--cache-dir`).
    pub fn with_disk(disk: Arc<DiskStore>) -> Self {
        Self::with_capacity_and_disk(DEFAULT_CACHE_CAPACITY, Some(disk))
    }

    fn with_capacity_and_disk(capacity: usize, disk: Option<Arc<DiskStore>>) -> Self {
        Self {
            prune: Cache::new(capacity),
            mapping: Cache::new(capacity),
            profiles: Cache::new(capacity),
            sim: Cache::new(capacity),
            disk,
            validated: Mutex::new(BTreeSet::new()),
        }
    }

    /// The disk store backing this evaluator, if any.
    pub fn disk(&self) -> Option<&Arc<DiskStore>> {
        self.disk.as_ref()
    }

    /// Fold a worker process's counters into this evaluator's totals.
    pub fn absorb(&self, stats: &EvalStats) {
        self.prune.absorb(&stats.prune);
        self.mapping.absorb(&stats.mapping);
        self.profiles.absorb(&stats.profiles);
        self.sim.absorb(&stats.sim);
    }

    fn disk_get<T: Persist>(&self, stage: Stage, key: u128) -> Option<T> {
        self.disk.as_ref().and_then(|d| d.get(stage, key))
    }

    fn disk_put<T: Persist>(&self, stage: Stage, key: u128, value: &T) {
        if let Some(d) = &self.disk {
            d.put(stage, key, value);
        }
    }

    fn ensure_valid(&self, arch: &Architecture, key: u128) -> anyhow::Result<()> {
        {
            let seen = self.validated.lock().unwrap_or_else(|p| p.into_inner());
            if seen.contains(&key) {
                return Ok(());
            }
        }
        arch.validate()?;
        let mut seen = self.validated.lock().unwrap_or_else(|p| p.into_inner());
        if seen.len() >= 4096 {
            seen.clear(); // bound memory; re-validation is cheap
        }
        seen.insert(key);
        Ok(())
    }

    /// Prune stage. Returns the plan (None for dense scenarios) and
    /// where it came from (None when the stage did not run: dense, or
    /// an externally provided plan).
    fn prune_stage(
        &self,
        s: &Scenario,
        keys: &Keys,
    ) -> anyhow::Result<(Option<Arc<PrunePlan>>, Option<StageHit>)> {
        match &s.prune {
            PruneSpec::None => Ok((None, None)),
            PruneSpec::Provided(p) => Ok((Some(p.clone()), None)),
            PruneSpec::Uniform { fb, workflow } => {
                let key = keys.prune.unwrap_or(0);
                let (net, fb, wf) = (s.net.clone(), fb.clone(), workflow.clone());
                let (v, hit) = self.prune.get_or_restore(
                    key,
                    || self.disk_get(Stage::Prune, key),
                    |p| self.disk_put(Stage::Prune, key, p),
                    move || wf.run_uniform(&net, &fb, None),
                )?;
                Ok((Some(v), Some(hit)))
            }
        }
    }

    /// Mapping stage. Validation of the architecture happens here, once
    /// per distinct arch; the planner entry point skips its own check.
    fn mapping_stage(
        &self,
        s: &Scenario,
        keys: &Keys,
        prune: Option<Arc<PrunePlan>>,
    ) -> anyhow::Result<(Arc<MappingPlan>, StageHit)> {
        self.ensure_valid(&s.arch, keys.arch)?;
        let arch = s.arch.clone();
        let net = s.net.clone();
        let opts = s.mapping;
        let key = keys.mapping;
        self.mapping.get_or_restore(
            key,
            || self.disk_get(Stage::Mapping, key),
            |p| self.disk_put(Stage::Mapping, key, p),
            move || plan_prevalidated(&arch, &net, prune.as_deref(), opts),
        )
    }

    /// Profile stage. Hit flag is None when the stage did not run
    /// (no profiles, or externally provided ones).
    fn profiles_stage(
        &self,
        s: &Scenario,
        keys: &Keys,
    ) -> anyhow::Result<(Option<Arc<InputProfiles>>, Option<StageHit>)> {
        match &s.profiles {
            ProfileSpec::None => Ok((None, None)),
            ProfileSpec::Provided(p) => Ok((Some(p.clone()), None)),
            ProfileSpec::Synthetic {
                bits,
                zero_frac,
                seed,
            } => {
                let key = keys.profiles.unwrap_or(0);
                let (net, bits, zero_frac, seed) = (s.net.clone(), *bits, *zero_frac, *seed);
                let (v, hit) = self.profiles.get_or_restore(
                    key,
                    || self.disk_get(Stage::Profiles, key),
                    |p| self.disk_put(Stage::Profiles, key, p),
                    move || Ok(InputProfiles::synthetic(&net, bits, zero_frac, seed)),
                )?;
                Ok((Some(v), Some(hit)))
            }
        }
    }

    /// The pruned-plan artifact for a scenario (None for dense).
    pub fn pruned_for(&self, s: &Scenario) -> anyhow::Result<Option<Arc<PrunePlan>>> {
        let keys = keys_of(s);
        Ok(self.prune_stage(s, &keys)?.0)
    }

    /// The mapping-plan artifact for a scenario.
    pub fn mapping_for(&self, s: &Scenario) -> anyhow::Result<Arc<MappingPlan>> {
        let keys = keys_of(s);
        let (prune, _) = self.prune_stage(s, &keys)?;
        Ok(self.mapping_stage(s, &keys, prune)?.0)
    }

    /// The input-profile artifact for a scenario (None when the
    /// scenario carries no profile spec).
    pub fn profiles_for(&self, s: &Scenario) -> anyhow::Result<Option<Arc<InputProfiles>>> {
        let keys = keys_of(s);
        Ok(self.profiles_stage(s, &keys)?.0)
    }

    /// Run the full pipeline. The returned report is stamped with a
    /// [`CacheNote`] recording which stages were served from cache;
    /// [`SimReport::content_digest`] excludes the note, so cached and
    /// fresh evaluations of the same scenario stay bit-identical.
    pub fn evaluate(&self, s: &Scenario) -> anyhow::Result<SimReport> {
        let keys = keys_of(s);
        let (prune, prune_hit) = self.prune_stage(s, &keys)?;
        let (mapping, mapping_hit) = self.mapping_stage(s, &keys, prune)?;
        let (profiles, profiles_hit) = self.profiles_stage(s, &keys)?;
        let sim_key = hash::combine(
            "sim",
            &[
                keys.arch,
                keys.net,
                keys.mapping,
                keys.profiles.unwrap_or(0),
                hash::fingerprint("sim-opts", &s.sim),
            ],
        );
        let arch = s.arch.clone();
        let net = s.net.clone();
        let opts = s.sim;
        let (rep, sim_hit) = self.sim.get_or_restore(
            sim_key,
            || self.disk_get(Stage::Sim, sim_key),
            |r| self.disk_put(Stage::Sim, sim_key, r),
            move || simulate(&arch, &net, &mapping, profiles.as_deref(), opts),
        )?;
        let mut out = (*rep).clone();
        out.cache = Some(CacheNote {
            prune_hit,
            mapping_hit,
            profiles_hit,
            sim_hit,
        });
        Ok(out)
    }

    pub fn stats(&self) -> EvalStats {
        EvalStats {
            prune: self.prune.stats(),
            mapping: self.mapping.stats(),
            profiles: self.profiles.stats(),
            sim: self.sim.stats(),
        }
    }
}

impl Default for Evaluator {
    fn default() -> Self {
        Self::new()
    }
}

/// The evaluation context a study or CLI command threads through a
/// sweep: one shared evaluator plus the sim options every point should
/// use. Clone is cheap (the evaluator is behind an `Arc`), which is
/// what lets sweep closures (which must be `'static`) share the cache.
#[derive(Clone, Default)]
pub struct EvalCtx {
    pub evaluator: Arc<Evaluator>,
    pub sim: SimOptions,
}

impl EvalCtx {
    pub fn new(sim: SimOptions) -> Self {
        Self {
            evaluator: Arc::new(Evaluator::new()),
            sim,
        }
    }

    /// Context whose evaluator spills to / restores from a shared
    /// disk store (`--cache-dir`).
    pub fn with_disk(sim: SimOptions, disk: Arc<DiskStore>) -> Self {
        Self {
            evaluator: Arc::new(Evaluator::with_disk(disk)),
            sim,
        }
    }
}
