//! Minimal argument parser (no clap offline): `--key value` / `--flag`
//! pairs plus positional arguments, with typed accessors and helpful
//! errors.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got `{v}`")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got `{v}`")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_args() {
        let a = parse(&["simulate", "--arch", "mars", "--ratio", "0.8", "--rearrange"]);
        assert_eq!(a.positional, vec!["simulate"]);
        assert_eq!(a.str_or("arch", "x"), "mars");
        assert_eq!(a.f64_or("ratio", 0.0).unwrap(), 0.8);
        assert!(a.bool("rearrange"));
        assert!(!a.bool("missing"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--threads=4", "--name=my model"]);
        assert_eq!(a.usize_or("threads", 0).unwrap(), 4);
        assert_eq!(a.str_or("name", ""), "my model");
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["--ratio", "abc"]);
        assert!(a.f64_or("ratio", 0.0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.bool("verbose"));
    }
}
