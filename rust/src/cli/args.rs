//! Minimal argument parser (no clap offline): `--key value` / `--flag`
//! pairs plus positional arguments, with typed accessors and helpful
//! errors.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = iter.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got `{v}`")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got `{v}`")),
        }
    }

    /// Optional number: `None` when the flag is absent, an error when
    /// it is present but malformed.
    pub fn f64_opt(&self, key: &str) -> anyhow::Result<Option<f64>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| anyhow::anyhow!("--{key} expects a number, got `{v}`"))
            })
            .transpose()
    }

    /// Optional integer: `None` when the flag is absent, an error when
    /// it is present but malformed.
    pub fn usize_opt(&self, key: &str) -> anyhow::Result<Option<usize>> {
        self.get(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got `{v}`"))
            })
            .transpose()
    }

    /// Comma-separated float list, e.g. `--rates 0,0.01,0.05`.
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> anyhow::Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse().map_err(|_| {
                        anyhow::anyhow!("--{key} expects comma-separated numbers, got `{s}`")
                    })
                })
                .collect(),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_args() {
        let a = parse(&["simulate", "--arch", "mars", "--ratio", "0.8", "--rearrange"]);
        assert_eq!(a.positional, vec!["simulate"]);
        assert_eq!(a.str_or("arch", "x"), "mars");
        assert_eq!(a.f64_or("ratio", 0.0).unwrap(), 0.8);
        assert!(a.bool("rearrange"));
        assert!(!a.bool("missing"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--threads=4", "--name=my model"]);
        assert_eq!(a.usize_or("threads", 0).unwrap(), 4);
        assert_eq!(a.str_or("name", ""), "my model");
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["--ratio", "abc"]);
        assert!(a.f64_or("ratio", 0.0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.bool("verbose"));
    }

    #[test]
    fn float_lists() {
        let a = parse(&["--rates", "0, 0.01,0.05"]);
        assert_eq!(a.f64_list_or("rates", &[9.0]).unwrap(), vec![0.0, 0.01, 0.05]);
        assert_eq!(a.f64_list_or("missing", &[9.0]).unwrap(), vec![9.0]);
        assert!(parse(&["--rates", "0,abc"]).f64_list_or("rates", &[]).is_err());
    }

    #[test]
    fn optional_accessors() {
        let a = parse(&["--job-timeout", "2.5", "--max-failures", "3"]);
        assert_eq!(a.f64_opt("job-timeout").unwrap(), Some(2.5));
        assert_eq!(a.usize_opt("max-failures").unwrap(), Some(3));
        assert_eq!(a.f64_opt("absent").unwrap(), None);
        assert_eq!(a.usize_opt("absent").unwrap(), None);
        assert!(parse(&["--job-timeout", "abc"]).f64_opt("job-timeout").is_err());
        assert!(parse(&["--max-failures", "-1"]).usize_opt("max-failures").is_err());
    }

    #[test]
    fn flag_followed_by_flag_keeps_both() {
        let a = parse(&["--json", "--arch", "mars"]);
        assert!(a.bool("json"));
        assert_eq!(a.str_or("arch", "x"), "mars");
    }
}
