//! The `ciminus` command-line interface: simulate | validate | explore |
//! faults | prune | profile | zoo | report.

pub mod args;
pub mod pattern;

use crate::eval::diskcache::DiskStore;
use crate::eval::{EvalCtx, Scenario};
use crate::explore::{
    ablation_study, executor, fault_study, input_study, mapping_study, sparsity_study,
};
use crate::explore::{IsolationMode, Sweep, SweepConfig, SweepFailure, TaskSpec};
use crate::hw::arch::Architecture;
use crate::hw::faults::FaultSpatial;
use crate::hw::presets;
use crate::mapping::duplication::{Strategy, StrategyPolicy};
use crate::mapping::planner::MappingOptions;
use crate::pruning::workflow::PruningWorkflow;
use crate::runtime::{Artifacts, ModelSession, Runtime};
use crate::sim::engine::SimOptions;
use crate::util::json::Json;
use crate::workload::{graph::Network, import, zoo};
use anyhow::{Context, Result};
use args::Args;
use pattern::parse_pattern;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// Process exit codes. `1` is reserved for hard errors: `main` prints
/// the `anyhow` chain and exits 1 whenever a command returns `Err`.
pub const EXIT_OK: i32 = 0;
/// Bad invocation: unknown command/study or malformed flag value.
pub const EXIT_USAGE: i32 = 2;
/// The command completed but some sweep points failed (panic, timeout,
/// error, abort); partial results were produced and reported.
pub const EXIT_PARTIAL: i32 = 3;

pub const USAGE: &str = "\
ciminus — cost modeling for sparse DNN workloads on SRAM-based digital CIM
usage: ciminus <command> [options]

commands:
  zoo [model]                      list/describe built-in workloads
  simulate  --arch <preset|file> --model <zoo|file.json>
            [--pattern P --ratio R] [--strategy auto|sp|dp] [--rearrange]
            [--no-input-sparsity] [--postproc-throughput N] [--detail]
  validate                         Fig. 6 validation vs MARS/SDP
  explore   --study fig8|fig9|fig10|fig11|fig12|ablation|smoke
            [--model M] [--smoke-points N --smoke-job-ms MS]
            [sweep options]
  faults    --arch <preset|file>[,...] [--model M] [--pattern P --ratio R]
            [--rates r1,r2,...] [--spatial uniform|row|column|cluster]
            [--seed N] [--json] [sweep options]
                                   fault-injection resilience curves
  prune     --model <mini> --pattern P --ratio R [--artifacts DIR]
                                   PJRT accuracy eval of pruned artifacts
  profile   --model <mini> [--artifacts DIR]
                                   PJRT activation bit-plane profiling
  report    --all [--out DIR]      regenerate all tables (ASCII + CSV)
  search    --model M [--macros N] [--max-sparsity S] [--min-util U]
            [sweep options]        Pareto design-space search
  trace     --model M [--arch A] [--pattern P --ratio R] [--limit N]
                                   per-round schedule + bound analysis
  journal   merge --into <canonical.jsonl> <shard.jsonl>...
                                   fold shard journals into a canonical
                                   checkpoint (last-writer-wins keys)
  cache     stats|gc --cache-dir DIR [--cache-bytes N]
                                   inspect or shrink a persistent
                                   artifact store

sweep options (explore / faults / search):
  --threads N        worker threads (0 = available parallelism)
  --job-timeout S    per-job timeout in seconds; soft in thread mode
                     (stuck jobs are shed and reported), hard in
                     process mode (the worker is killed and respawned)
  --retries N        retry transient job errors up to N times
  --max-failures N   abort remaining jobs after N failures
  --checkpoint PATH  append finished points to a JSONL journal
  --resume           skip points already present in --checkpoint
  --isolation MODE   thread (default) runs jobs in-process; process
                     forks one worker per shard, surviving aborts,
                     OOM kills and segfaults as structured failures
  --shards N         worker processes in process mode (0 = auto)

simulation options (simulate / explore / faults / search):
  --postproc-throughput N  elements per cycle per post-processing lane
                           (default 4)

cache options (simulate / explore / faults / search / trace):
  --cache-dir DIR    persist stage artifacts (prune plans, mappings,
                     profiles, sim reports) to a content-addressed
                     on-disk store shared across runs and process
                     shards; unchanged points restore instead of
                     recomputing
  --cache-bytes N    byte bound for the store, K/M/G suffixes accepted
                     (default 1G); least-recently-used entries are
                     evicted once the bound is exceeded

exit codes: 0 ok | 1 hard error | 2 usage error | 3 completed with failures

patterns: row_wise | row_block[:w] | column_wise | channel_wise |
          column_block[:h] | intra:m | hybrid:m[:w] | hybrid_row_wise:m |
          full:MxN | dense
";

pub(crate) fn load_arch(spec: &str) -> Result<Architecture> {
    if spec.ends_with(".json") {
        let j = Json::parse_file(std::path::Path::new(spec))
            .with_context(|| format!("reading architecture file `{spec}`"))?;
        Architecture::from_json(&j)
            .with_context(|| format!("parsing architecture from `{spec}`"))
    } else {
        presets::by_name(spec)
    }
}

pub(crate) fn load_net(spec: &str) -> Result<Network> {
    if spec.ends_with(".json") {
        import::network_from_file(std::path::Path::new(spec))
            .with_context(|| format!("loading network from `{spec}`"))
    } else {
        zoo::by_name(spec, 32, 100)
    }
}

/// Parse a byte-size flag value with an optional binary K/M/G suffix
/// (`512M` = 512 MiB).
pub(crate) fn parse_bytes(s: &str) -> Result<u64> {
    let t = s.trim();
    let (digits, mult) = match t.as_bytes().last() {
        Some(b'k' | b'K') => (&t[..t.len() - 1], 1u64 << 10),
        Some(b'm' | b'M') => (&t[..t.len() - 1], 1u64 << 20),
        Some(b'g' | b'G') => (&t[..t.len() - 1], 1u64 << 30),
        _ => (t, 1),
    };
    let n: u64 = digits
        .trim()
        .parse()
        .with_context(|| format!("expected a byte count like `64M` or `1G`, got `{s}`"))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("byte count `{s}` overflows u64"))
}

/// Parse the shared `--cache-dir` / `--cache-bytes` pair. Returns the
/// directory (if any) and the byte bound (0 = the store default).
fn cache_flags(a: &Args) -> Result<(Option<PathBuf>, u64)> {
    let dir = a.get("cache-dir").map(PathBuf::from);
    let bytes = match a.get("cache-bytes") {
        Some(v) => {
            let b = parse_bytes(v)?;
            anyhow::ensure!(b > 0, "--cache-bytes expects a positive size, got `{v}`");
            anyhow::ensure!(
                dir.is_some(),
                "--cache-bytes requires --cache-dir <path>"
            );
            b
        }
        None => 0,
    };
    Ok((dir, bytes))
}

/// Build the evaluation context for a command: shared in-memory stage
/// caches, plus the persistent `--cache-dir` disk store when one was
/// requested.
fn eval_ctx(a: &Args) -> Result<EvalCtx> {
    let sim = sim_options(a)?;
    match cache_flags(a)? {
        (Some(dir), bytes) => {
            let store = DiskStore::open(&dir, bytes)
                .with_context(|| format!("opening artifact cache at {}", dir.display()))?;
            Ok(EvalCtx::with_disk(sim, Arc::new(store)))
        }
        (None, _) => Ok(EvalCtx::new(sim)),
    }
}

/// Fold worker-process stage counters back into the supervising
/// evaluator, so the `artifact cache:` summary printed after a
/// process-isolated sweep covers work done inside the shards.
fn hook_worker_stats(cfg: &mut SweepConfig, ectx: &EvalCtx) {
    let ev = ectx.evaluator.clone();
    cfg.worker_stats = Some(executor::StatsHook(Arc::new(move |s| ev.absorb(s))));
}

/// Build the executor configuration from the shared sweep flags.
fn sweep_config(a: &Args) -> Result<SweepConfig> {
    let mut cfg = SweepConfig::with_threads(a.usize_or("threads", 0)?);
    if let Some(secs) = a.f64_opt("job-timeout")? {
        anyhow::ensure!(
            secs.is_finite() && secs > 0.0,
            "--job-timeout expects a positive number of seconds, got `{secs}`"
        );
        cfg.job_timeout = Some(Duration::from_secs_f64(secs));
    }
    cfg.max_retries = a.usize_or("retries", 0)? as u32;
    cfg.max_failures = a.usize_opt("max-failures")?;
    cfg.checkpoint = a.get("checkpoint").map(PathBuf::from);
    cfg.resume = a.bool("resume");
    anyhow::ensure!(
        !cfg.resume || cfg.checkpoint.is_some(),
        "--resume requires --checkpoint <path>"
    );
    if let Some(mode) = a.get("isolation") {
        cfg.isolation = IsolationMode::parse(mode)?;
    }
    cfg.shards = a.usize_or("shards", 0)?;
    let (cache_dir, cache_bytes) = cache_flags(a)?;
    cfg.cache_dir = cache_dir;
    cfg.cache_bytes = cache_bytes;
    Ok(cfg)
}

/// Stamp the process-mode task descriptor for one sub-sweep onto a copy
/// of the shared sweep config. Inert in thread mode; in process mode
/// each worker re-builds exactly this job list from the descriptor.
fn task_cfg(cfg: &SweepConfig, a: &Args, name: &str, extra: &[(&str, Json)]) -> Result<SweepConfig> {
    let mut p = Json::obj();
    if let Some(t) = a.usize_opt("postproc-throughput")? {
        p.set("postproc", Json::Num(t as f64));
    }
    for (k, v) in extra {
        p.set(k, v.clone());
    }
    Ok(cfg.tasked(TaskSpec::new(name, p)))
}

/// Build the simulation options from the shared `--postproc-throughput`
/// flag (previously hardcoded to the [`SimOptions`] default).
fn sim_options(a: &Args) -> Result<SimOptions> {
    let mut sim = SimOptions::default();
    if let Some(t) = a.usize_opt("postproc-throughput")? {
        anyhow::ensure!(
            t > 0,
            "--postproc-throughput expects a positive elements-per-cycle count"
        );
        sim.postproc_throughput = t;
    }
    Ok(sim)
}

/// Aggregates one or more [`Sweep`]s run by a single command into a
/// summary line and an exit code.
#[derive(Default)]
struct SweepAgg {
    ok: usize,
    resumed: usize,
    failures: Vec<SweepFailure>,
}

impl SweepAgg {
    fn add<P>(&mut self, sweep: &Sweep<P>) {
        self.ok += sweep.total - sweep.failures.len();
        self.resumed += sweep.resumed;
        self.failures.extend(sweep.failures.iter().cloned());
    }

    /// Print the run summary and per-failure detail (to stderr, so
    /// piped stdout data like `--json` output stays clean) and
    /// translate into an exit code.
    fn finish(self) -> i32 {
        eprintln!(
            "sweep: {}",
            executor::summary_line(self.ok, &self.failures, self.resumed)
        );
        for f in &self.failures {
            eprintln!("  failed {}: {}", f.key, f.error);
        }
        if self.failures.is_empty() {
            EXIT_OK
        } else {
            EXIT_PARTIAL
        }
    }
}

/// Entry point used by `main.rs`; returns the process exit code.
pub fn run<I: IntoIterator<Item = String>>(raw: I) -> Result<i32> {
    let a = Args::parse(raw);
    let cmd = a.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(EXIT_OK)
        }
        "zoo" => cmd_zoo(&a),
        "simulate" => cmd_simulate(&a),
        "validate" => cmd_validate(&a),
        "explore" => cmd_explore(&a),
        "faults" => cmd_faults(&a),
        "prune" => cmd_prune(&a),
        "profile" => cmd_profile(&a),
        "report" => cmd_report(&a),
        "search" => cmd_search(&a),
        "trace" => cmd_trace(&a),
        "journal" => cmd_journal(&a),
        "cache" => cmd_cache(&a),
        // hidden mode: this process was re-exec'd by the
        // process-isolation supervisor to run one sweep shard
        "__worker" => crate::explore::worker::worker_main(),
        other => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            Ok(EXIT_USAGE)
        }
    }
}

fn cmd_zoo(a: &Args) -> Result<i32> {
    if let Some(model) = a.positional.get(1) {
        let net = load_net(model)?;
        println!("{}", net.describe());
        let s = net.stats();
        println!(
            "params: {:.2} M   MACs: {:.3} G   conv {} / dwconv {} / fc {}",
            s.params as f64 / 1e6,
            s.macs as f64 / 1e9,
            s.n_conv,
            s.n_dwconv,
            s.n_fc
        );
    } else {
        println!("available workloads: {}", zoo::ZOO_NAMES.join(", "));
        println!("architecture presets: mars, sdp, usecase4, usecase16");
    }
    Ok(EXIT_OK)
}

fn cmd_simulate(a: &Args) -> Result<i32> {
    let arch_spec = a.str_or("arch", "usecase4");
    let mut arch = load_arch(arch_spec)?;
    let net = load_net(a.str_or("model", "resnet50"))?;
    let ratio = a.f64_or("ratio", 0.8)?;
    let fb = parse_pattern(a.str_or("pattern", "dense"), ratio)?;
    if a.bool("no-input-sparsity") {
        arch.sparsity.input_skipping = false;
    }
    let policy = match a.str_or("strategy", "auto") {
        "auto" => StrategyPolicy::Auto,
        s => StrategyPolicy::Fixed(Strategy::parse(s)?),
    };
    let opts = MappingOptions {
        policy,
        rearrange: a.bool("rearrange"),
        rearrange_slice: a.usize_or("rearrange-slice", 16)?,
        ..Default::default()
    };
    let ectx = eval_ctx(a)?;
    let mut s = Scenario::new(arch.clone(), net)
        .with_mapping(opts)
        .synthetic_profiles(arch.input_bits, 0.55, 0xC1A0)
        .with_sim(sim_options(a)?);
    if !fb.is_dense() {
        s = s.prune_uniform(&fb);
    }
    let rep = ectx.evaluator.evaluate(&s)?;
    println!("{}", arch.describe());
    println!("{}", rep.summary());
    if a.bool("detail") {
        println!("{}", rep.op_table().render());
        println!("{}", rep.energy_table().render());
    }
    if ectx.evaluator.disk().is_some() {
        eprintln!("artifact cache: {}", ectx.evaluator.stats());
    }
    Ok(EXIT_OK)
}

fn cmd_validate(_a: &Args) -> Result<i32> {
    println!("{}", crate::report::tab1().render());
    let points = crate::validate::run_validation()?;
    println!("{}", crate::report::fig6_table(&points).render());
    let (mean, max) = crate::validate::error_stats(&points);
    let r = crate::validate::harness::correlation(&points);
    println!("error margin: mean {mean:.2}%  max {max:.2}%  pearson r = {r:.3}");
    let bd = crate::validate::sdp_power_breakdown()?;
    println!("{}", crate::report::fig6c_table(&bd).render());
    Ok(EXIT_OK)
}

const STUDIES: &str = "fig8, fig9, fig10, fig11, fig12, ablation, smoke";

fn cmd_explore(a: &Args) -> Result<i32> {
    let mut cfg = sweep_config(a)?;
    let ectx = eval_ctx(a)?;
    hook_worker_stats(&mut cfg, &ectx);
    let study = a.str_or("study", "fig8");
    let mut agg = SweepAgg::default();
    match study {
        "fig8" => {
            let model = a.str_or("model", "resnet50");
            let net = load_net(model)?;
            let cfg = task_cfg(&cfg, a, "fig8", &[("model", Json::Str(model.to_string()))])?;
            let sweep =
                sparsity_study::run_fig8_robust(&net, &sparsity_study::RATIOS, &ectx, &cfg)?;
            println!(
                "{}",
                crate::report::sparsity_table(
                    &format!("Fig. 8: sparsity patterns on {}", net.name),
                    &sweep.points
                )
                .render()
            );
            agg.add(&sweep);
        }
        "fig9" => {
            let model = a.str_or("model", "resnet50");
            let net = load_net(model)?;
            let cfg_a = task_cfg(&cfg, a, "fig9a", &[("model", Json::Str(model.to_string()))])?;
            let sweep_a = sparsity_study::run_fig9a_robust(&net, &ectx, &cfg_a)?;
            println!(
                "{}",
                crate::report::sparsity_table("Fig. 9(a): block sizes @80%", &sweep_a.points)
                    .render()
            );
            agg.add(&sweep_a);
            let r50 = zoo::resnet50(32, 100);
            let v16 = zoo::vgg16(32, 100);
            let mb = zoo::mobilenetv2(32, 100);
            let cfg_b = task_cfg(&cfg, a, "fig9b", &[])?;
            let sweep_b = sparsity_study::run_fig9b_robust(&[&r50, &v16, &mb], &ectx, &cfg_b)?;
            let flat: Vec<_> = sweep_b
                .points
                .iter()
                .cloned()
                .map(|(m, mut p)| {
                    p.pattern = format!("{m}/{}", p.pattern);
                    p
                })
                .collect();
            println!(
                "{}",
                crate::report::sparsity_table("Fig. 9(b): models @80%", &flat).render()
            );
            agg.add(&sweep_b);
        }
        "fig10" => {
            let r50 = zoo::resnet50(32, 100);
            let v16 = zoo::vgg16(32, 100);
            let mb = zoo::mobilenetv2(32, 100);
            let cfg_d = task_cfg(&cfg, a, "fig10-dense", &[("zero_frac", Json::Num(0.55))])?;
            let dense =
                input_study::run_dense_models_robust(&[&r50, &v16, &mb], 0.55, &ectx, &cfg_d)?;
            println!(
                "{}",
                crate::report::input_sparsity_table("Fig. 10: dense models", &dense.points)
                    .render()
            );
            agg.add(&dense);
            let cfg_p =
                task_cfg(&cfg, a, "fig10-pattern", &[("model", Json::Str("resnet50".into()))])?;
            let pats = input_study::run_weight_patterns_robust(&r50, &ectx, &cfg_p)?;
            println!(
                "{}",
                crate::report::input_sparsity_table(
                    "Fig. 10: weight patterns @80%",
                    &pats.points
                )
                .render()
            );
            agg.add(&pats);
            let cfg_r =
                task_cfg(&cfg, a, "fig10-ratio", &[("model", Json::Str("resnet50".into()))])?;
            let ratios = input_study::run_ratio_sweep_robust(
                &r50,
                &[0.5, 0.6, 0.7, 0.8, 0.9],
                &ectx,
                &cfg_r,
            )?;
            println!(
                "{}",
                crate::report::input_sparsity_table(
                    "Fig. 10: ratio sweep (row-wise)",
                    &ratios.points
                )
                .render()
            );
            agg.add(&ratios);
        }
        "fig11" => {
            let r50 = zoo::resnet50(32, 100);
            let v16 = zoo::vgg16(32, 100);
            let cfg = task_cfg(&cfg, a, "fig11", &[])?;
            let sweep = mapping_study::run_fig11_robust(&[&r50, &v16], &ectx, &cfg)?;
            println!("{}", crate::report::mapping_table(&sweep.points).render());
            agg.add(&sweep);
        }
        "fig12" => {
            let model = a.str_or("model", "resnet50");
            let net = load_net(model)?;
            let cfg = task_cfg(&cfg, a, "fig12", &[("model", Json::Str(model.to_string()))])?;
            let sweep = mapping_study::run_fig12_robust(&net, &ectx, &cfg)?;
            println!("{}", crate::report::rearrange_table(&sweep.points).render());
            agg.add(&sweep);
        }
        "ablation" => {
            let model = a.str_or("model", "resnet_mini");
            let net = load_net(model)?;
            let cfg = task_cfg(&cfg, a, "ablation", &[("model", Json::Str(model.to_string()))])?;
            let sweep = ablation_study::run_all_robust(&net, &ectx, &cfg)?;
            let mut t = crate::util::table::Table::new(&[
                "label", "cycles", "energy(uJ)", "skip%",
            ])
            .with_title("Modeling ablations");
            for group in &sweep.points {
                for p in group {
                    t.row(vec![
                        p.label.clone(),
                        p.cycles.to_string(),
                        format!("{:.3}", p.energy_pj / 1e6),
                        format!("{:.1}", p.skip_ratio * 100.0),
                    ]);
                }
            }
            println!("{}", t.render());
            agg.add(&sweep);
        }
        // a tiny built-in sweep with one panicking and one hanging job:
        // exercises the full failure/checkpoint path without the
        // simulator (used by CI and for demoing --resume)
        "smoke" => {
            let points = a.usize_opt("smoke-points")?;
            let job_ms = a.usize_or("smoke-job-ms", 0)? as u64;
            let mut extra = vec![("job_ms", Json::Num(job_ms as f64))];
            if let Some(n) = points {
                extra.push(("points", Json::Num(n as f64)));
            }
            let cfg = task_cfg(&cfg, a, "smoke", &extra)?;
            let sweep = executor::smoke_sweep_sized(&cfg, points, job_ms)?;
            println!(
                "smoke sweep: {} of {} points completed",
                sweep.points.len(),
                sweep.total
            );
            agg.add(&sweep);
        }
        other => {
            eprintln!("unknown study `{other}` (valid: {STUDIES})");
            return Ok(EXIT_USAGE);
        }
    }
    eprintln!("artifact cache: {}", ectx.evaluator.stats());
    Ok(agg.finish())
}

fn cmd_faults(a: &Args) -> Result<i32> {
    let mut cfg = sweep_config(a)?;
    let ectx = eval_ctx(a)?;
    hook_worker_stats(&mut cfg, &ectx);
    let net = load_net(a.str_or("model", "resnet_mini"))?;
    let ratio = a.f64_or("ratio", 0.8)?;
    let fb = parse_pattern(a.str_or("pattern", "dense"), ratio)?;
    let rates = a.f64_list_or("rates", &fault_study::DEFAULT_RATES)?;
    let spatial = FaultSpatial::parse(a.str_or("spatial", "uniform"))?;
    let seed = a.usize_or("seed", 0xC1A0)? as u64;
    let fb_opt = (!fb.is_dense()).then_some(&fb);
    let mut agg = SweepAgg::default();
    let mut all_points = Vec::new();
    for spec in a.str_or("arch", "usecase4,mars").split(',') {
        let spec = spec.trim();
        if spec.is_empty() {
            continue;
        }
        let arch = load_arch(spec)?;
        let fcfg = task_cfg(
            &cfg,
            a,
            "faults",
            &[
                ("arch", Json::Str(spec.to_string())),
                ("model", Json::Str(a.str_or("model", "resnet_mini").to_string())),
                ("pattern", Json::Str(a.str_or("pattern", "dense").to_string())),
                ("ratio", Json::Num(ratio)),
                ("rates", Json::Arr(rates.iter().map(|r| Json::Num(*r)).collect())),
                ("spatial", Json::Str(a.str_or("spatial", "uniform").to_string())),
                ("seed", Json::Num(seed as f64)),
            ],
        )?;
        let sweep = fault_study::run_resilience_robust(
            &arch, &net, fb_opt, &rates, spatial, seed, &ectx, &fcfg,
        )?;
        if !a.bool("json") {
            println!(
                "{}",
                crate::report::fault_table(
                    &format!("Fault resilience: {} on {} [{}]", net.name, arch.name, fb.name),
                    &sweep.points
                )
                .render()
            );
        }
        all_points.extend(sweep.points.iter().cloned());
        agg.add(&sweep);
    }
    if a.bool("json") {
        println!("{}", fault_study::points_to_json(&all_points).pretty());
    }
    eprintln!("artifact cache: {}", ectx.evaluator.stats());
    Ok(agg.finish())
}

fn artifacts_from(a: &Args) -> Result<Artifacts> {
    let dir = a
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Artifacts::default_dir);
    anyhow::ensure!(
        Artifacts::available(&dir),
        "artifacts not found in {} — run `make artifacts`",
        dir.display()
    );
    Artifacts::load(&dir)
}

fn cmd_prune(a: &Args) -> Result<i32> {
    let arts = artifacts_from(a)?;
    let model = a.str_or("model", "resnet_mini");
    let ratio = a.f64_or("ratio", 0.8)?;
    let fb = parse_pattern(a.str_or("pattern", "row_wise"), ratio)?;
    let rt = Runtime::cpu()?;
    let session = ModelSession::new(&rt, &arts, model)?;
    let net = zoo::by_name(model, 32, 100)?;
    let wf = PruningWorkflow::default();
    let ev = session.prune_and_eval(&net, &fb, &wf)?;
    println!(
        "{model} + {}: accuracy {:.2}% (dense {:.2}%), weight sparsity {:.1}%",
        fb.name,
        ev.accuracy * 100.0,
        ev.dense_accuracy * 100.0,
        ev.weight_sparsity * 100.0
    );
    Ok(EXIT_OK)
}

fn cmd_profile(a: &Args) -> Result<i32> {
    let arts = artifacts_from(a)?;
    let model = a.str_or("model", "resnet_mini");
    let rt = Runtime::cpu()?;
    let session = ModelSession::new(&rt, &arts, model)?;
    let ma = arts.model(model)?;
    let profiles = session.profile_activations(&ma.blob, 8)?;
    println!("activation bit-plane profiles for {model} (8-bit, calib batch):");
    for (name, p) in &profiles {
        println!(
            "  {name:<20} skip@G=1 {:>5.1}%  G=2 {:>5.1}%  G=32 {:>5.1}%",
            p.skip_ratio(1) * 100.0,
            p.skip_ratio(2) * 100.0,
            p.skip_ratio(32) * 100.0
        );
    }
    Ok(EXIT_OK)
}

fn cmd_report(a: &Args) -> Result<i32> {
    let out_dir = std::path::PathBuf::from(a.str_or("out", "report_out"));
    std::fs::create_dir_all(&out_dir)?;
    let threads = a.usize_or("threads", 0)?;
    let t1 = crate::report::tab1();
    let t2 = crate::report::tab2();
    println!("{}", t1.render());
    println!("{}", t2.render());
    t1.write_csv(&out_dir.join("tab1.csv"))?;
    t2.write_csv(&out_dir.join("tab2.csv"))?;
    if a.bool("all") {
        let points = crate::validate::run_validation()?;
        let f6 = crate::report::fig6_table(&points);
        println!("{}", f6.render());
        f6.write_csv(&out_dir.join("fig6.csv"))?;
        let net = zoo::resnet50(32, 100);
        let pts = sparsity_study::run_fig8(&net, &sparsity_study::RATIOS, threads)?;
        let f8 = crate::report::sparsity_table("Fig. 8", &pts);
        f8.write_csv(&out_dir.join("fig8.csv"))?;
        println!("{}", f8.render());
        let v16 = zoo::vgg16(32, 100);
        let f11 = crate::report::mapping_table(&mapping_study::run_fig11(&[&net, &v16], threads)?);
        f11.write_csv(&out_dir.join("fig11.csv"))?;
        println!("{}", f11.render());
        let f12 = crate::report::rearrange_table(&mapping_study::run_fig12(&net, threads)?);
        f12.write_csv(&out_dir.join("fig12.csv"))?;
        println!("{}", f12.render());
    }
    println!("CSV written to {}", out_dir.display());
    Ok(EXIT_OK)
}

fn cmd_search(a: &Args) -> Result<i32> {
    use crate::explore::search::{candidates, search_robust, Constraints};
    let mut cfg = sweep_config(a)?;
    let ectx = eval_ctx(a)?;
    hook_worker_stats(&mut cfg, &ectx);
    let net = load_net(a.str_or("model", "resnet50"))?;
    let n_macros = a.usize_or("macros", 16)?;
    let cons = Constraints {
        max_sparsity: a.f64_opt("max-sparsity")?,
        min_utilization: a.f64_opt("min-util")?,
    };
    let ratios = [0.5, 0.7, 0.8, 0.9];
    println!(
        "searching {} candidates on {} macros...",
        candidates(n_macros, &ratios).len(),
        n_macros
    );
    let mut extra = vec![
        ("model", Json::Str(a.str_or("model", "resnet50").to_string())),
        ("macros", Json::Num(n_macros as f64)),
    ];
    if let Some(s) = cons.max_sparsity {
        extra.push(("max_sparsity", Json::Num(s)));
    }
    if let Some(u) = cons.min_utilization {
        extra.push(("min_util", Json::Num(u)));
    }
    let cfg = task_cfg(&cfg, a, "search", &extra)?;
    let (sweep, pareto) = search_robust(&net, n_macros, &ratios, cons, &ectx, &cfg)?;
    let feasible = sweep.points.iter().flatten().count();
    println!("{} feasible points, {} Pareto-optimal:\n", feasible, pareto.len());
    let mut t = crate::util::table::Table::new(&[
        "pattern", "sparsity", "org", "strategy", "cycles", "energy(uJ)", "util%",
    ])
    .with_title("Pareto frontier (latency vs energy)");
    let mut sorted = pareto.clone();
    sorted.sort_by_key(|p| p.cycles);
    for p in &sorted {
        t.row(vec![
            p.pattern.clone(),
            format!("{:.2}", p.ratio),
            format!("{}x{}", p.org.0, p.org.1),
            p.strategy.to_string(),
            p.cycles.to_string(),
            format!("{:.3}", p.energy_pj / 1e6),
            format!("{:.1}", p.utilization * 100.0),
        ]);
    }
    println!("{}", t.render());
    eprintln!("artifact cache: {}", ectx.evaluator.stats());
    let mut agg = SweepAgg::default();
    agg.add(&sweep);
    Ok(agg.finish())
}

/// `ciminus journal merge --into <canonical> <shard>...`: offline
/// last-writer-wins merge of shard journals (e.g. from independently
/// run or killed sweeps) into one canonical checkpoint.
fn cmd_journal(a: &Args) -> Result<i32> {
    const MERGE_USAGE: &str =
        "usage: ciminus journal merge --into <canonical.jsonl> <shard.jsonl>...";
    if a.positional.get(1).map(|s| s.as_str()) != Some("merge") {
        eprintln!("{MERGE_USAGE}");
        return Ok(EXIT_USAGE);
    }
    let into = match a.get("into") {
        Some(p) => PathBuf::from(p),
        None => {
            eprintln!("journal merge: missing --into <canonical.jsonl>\n{MERGE_USAGE}");
            return Ok(EXIT_USAGE);
        }
    };
    let shards: Vec<PathBuf> = a.positional[2..].iter().map(PathBuf::from).collect();
    if shards.is_empty() {
        eprintln!("journal merge: no shard journals given\n{MERGE_USAGE}");
        return Ok(EXIT_USAGE);
    }
    let n = executor::Journal::merge_files(&into, &shards)?;
    println!("merged {n} new entries into {}", into.display());
    Ok(EXIT_OK)
}

/// `ciminus cache stats|gc --cache-dir <dir>`: inspect or shrink a
/// persistent artifact store without running a simulation.
fn cmd_cache(a: &Args) -> Result<i32> {
    const CACHE_USAGE: &str =
        "usage: ciminus cache stats|gc --cache-dir <dir> [--cache-bytes N[K|M|G]]";
    let sub = a.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    if sub != "stats" && sub != "gc" {
        eprintln!("{CACHE_USAGE}");
        return Ok(EXIT_USAGE);
    }
    let (dir, bytes) = cache_flags(a)?;
    let Some(dir) = dir else {
        eprintln!("cache {sub}: missing --cache-dir <dir>\n{CACHE_USAGE}");
        return Ok(EXIT_USAGE);
    };
    let store = DiskStore::open(&dir, bytes)
        .with_context(|| format!("opening artifact cache at {}", dir.display()))?;
    if sub == "stats" {
        let st = store.stats();
        println!("artifact cache at {}", st.root.display());
        for s in &st.stages {
            println!(
                "  {:<9} {:>6} entries  {:>12} bytes",
                s.stage.dir(),
                s.entries,
                s.bytes
            );
        }
        println!(
            "  total     {:>6} entries  {:>12} bytes (bound {})",
            st.total_entries, st.total_bytes, st.max_bytes
        );
    } else {
        let before = store.stats().total_bytes;
        let after = store.gc()?;
        println!(
            "gc reclaimed {} bytes, {} bytes remain (bound {})",
            before.saturating_sub(after),
            after,
            store.max_bytes()
        );
    }
    Ok(EXIT_OK)
}

fn cmd_trace(a: &Args) -> Result<i32> {
    let arch = load_arch(a.str_or("arch", "usecase4"))?;
    let net = load_net(a.str_or("model", "resnet_mini"))?;
    let ratio = a.f64_or("ratio", 0.8)?;
    let fb = parse_pattern(a.str_or("pattern", "dense"), ratio)?;
    let mut s = Scenario::new(arch.clone(), net.clone());
    if !fb.is_dense() {
        s = s.prune_uniform(&fb);
    }
    let ectx = eval_ctx(a)?;
    let mapping = ectx.evaluator.mapping_for(&s)?;
    let t = crate::sim::trace::trace_mapping(&arch, &net, &mapping, arch.input_bits as f64);
    println!("{}", t.render(a.usize_or("limit", 40)?));
    println!("bound histogram:");
    for (b, f) in t.bound_histogram() {
        println!("  {:<10} {:>5.1}%", b.label(), f * 100.0);
    }
    println!("\nhotspots:");
    for (op, cyc) in t.hotspots(8) {
        println!("  {op:<26} {cyc}");
    }
    Ok(EXIT_OK)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn run_args(args: &[&str]) -> Result<i32> {
        run(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn search_and_trace_commands_run() {
        assert_eq!(
            run_args(&["search", "--model", "resnet_mini", "--macros", "4"]).unwrap(),
            0
        );
        assert_eq!(
            run_args(&["trace", "--model", "resnet_mini", "--pattern", "row_wise", "--limit", "5"])
                .unwrap(),
            0
        );
    }

    #[test]
    fn load_arch_presets_and_errors() {
        assert!(load_arch("mars").is_ok());
        assert!(load_arch("nope").is_err());
    }

    #[test]
    fn help_runs() {
        assert_eq!(run_args(&["help"]).unwrap(), 0);
    }

    #[test]
    fn unknown_command_exit_code() {
        assert_eq!(run_args(&["frobnicate"]).unwrap(), EXIT_USAGE);
    }

    #[test]
    fn unknown_study_exit_code() {
        assert_eq!(
            run_args(&["explore", "--study", "fig99"]).unwrap(),
            EXIT_USAGE
        );
    }

    #[test]
    fn zoo_lists() {
        assert_eq!(run_args(&["zoo"]).unwrap(), 0);
        assert_eq!(run_args(&["zoo", "vgg_mini"]).unwrap(), 0);
    }

    #[test]
    fn faults_command_runs() {
        let args = ["faults", "--model", "resnet_mini", "--arch", "usecase4", "--rates", "0,0.05"];
        assert_eq!(run_args(&args).unwrap(), 0);
        let args = [
            "faults", "--model", "resnet_mini", "--arch", "usecase4", "--rates", "0", "--json",
        ];
        assert_eq!(run_args(&args).unwrap(), 0);
    }

    #[test]
    fn simulate_small_model() {
        let args = [
            "simulate", "--model", "resnet_mini", "--pattern", "row_wise", "--ratio", "0.8",
        ];
        assert_eq!(run_args(&args).unwrap(), 0);
    }

    #[test]
    fn sweep_config_parses_flags() {
        let a = Args::parse(
            [
                "explore",
                "--threads",
                "4",
                "--job-timeout",
                "1.5",
                "--retries",
                "2",
                "--max-failures",
                "10",
                "--checkpoint",
                "/tmp/x.jsonl",
                "--resume",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let cfg = sweep_config(&a).unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.job_timeout, Some(Duration::from_millis(1500)));
        assert_eq!(cfg.max_retries, 2);
        assert_eq!(cfg.max_failures, Some(10));
        assert!(cfg.resume);
        assert_eq!(cfg.checkpoint.as_deref(), Some(std::path::Path::new("/tmp/x.jsonl")));
    }

    #[test]
    fn sim_options_parses_postproc_throughput() {
        let a = Args::parse(["--postproc-throughput", "8"].iter().map(|s| s.to_string()));
        assert_eq!(sim_options(&a).unwrap().postproc_throughput, 8);
        let dflt = Args::parse(std::iter::empty::<String>());
        assert_eq!(
            sim_options(&dflt).unwrap().postproc_throughput,
            SimOptions::default().postproc_throughput
        );
        let bad = Args::parse(["--postproc-throughput", "0"].iter().map(|s| s.to_string()));
        assert!(sim_options(&bad).is_err(), "zero throughput rejected");
    }

    #[test]
    fn sweep_config_rejects_bad_flags() {
        let resume_only = Args::parse(["--resume"].iter().map(|s| s.to_string()));
        assert!(sweep_config(&resume_only).is_err(), "--resume needs --checkpoint");
        let bad_timeout = Args::parse(
            ["--job-timeout", "-1"].iter().map(|s| s.to_string()),
        );
        assert!(sweep_config(&bad_timeout).is_err());
    }

    #[test]
    fn sweep_config_parses_isolation_and_shards() {
        let a = Args::parse(
            ["--isolation", "process", "--shards", "3"].iter().map(|s| s.to_string()),
        );
        let cfg = sweep_config(&a).unwrap();
        assert_eq!(cfg.isolation, IsolationMode::Process);
        assert_eq!(cfg.shards, 3);
        let dflt = Args::parse(std::iter::empty::<String>());
        assert_eq!(sweep_config(&dflt).unwrap().isolation, IsolationMode::Thread);
        let bad = Args::parse(["--isolation", "vm"].iter().map(|s| s.to_string()));
        assert!(sweep_config(&bad).is_err(), "unknown isolation mode rejected");
    }

    #[test]
    fn parse_bytes_accepts_suffixes() {
        assert_eq!(parse_bytes("123").unwrap(), 123);
        assert_eq!(parse_bytes("4K").unwrap(), 4 << 10);
        assert_eq!(parse_bytes("64m").unwrap(), 64 << 20);
        assert_eq!(parse_bytes("2G").unwrap(), 2 << 30);
        assert_eq!(parse_bytes(" 1 G ").unwrap(), 1 << 30);
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("12Q").is_err());
        assert!(parse_bytes("-5M").is_err());
        assert!(parse_bytes("99999999999999999999G").is_err(), "overflow rejected");
    }

    #[test]
    fn sweep_config_parses_cache_flags() {
        let a = Args::parse(
            ["--cache-dir", "/tmp/cim-cache", "--cache-bytes", "64M"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = sweep_config(&a).unwrap();
        assert_eq!(cfg.cache_dir.as_deref(), Some(std::path::Path::new("/tmp/cim-cache")));
        assert_eq!(cfg.cache_bytes, 64 << 20);
        let dflt = Args::parse(std::iter::empty::<String>());
        let cfg = sweep_config(&dflt).unwrap();
        assert_eq!(cfg.cache_dir, None);
        assert_eq!(cfg.cache_bytes, 0, "0 defers to the store default");
        let orphan = Args::parse(["--cache-bytes", "1M"].iter().map(|s| s.to_string()));
        assert!(sweep_config(&orphan).is_err(), "--cache-bytes needs --cache-dir");
        let zero = Args::parse(
            ["--cache-dir", "/tmp/x", "--cache-bytes", "0"].iter().map(|s| s.to_string()),
        );
        assert!(sweep_config(&zero).is_err(), "zero bound rejected");
    }

    #[test]
    fn cache_command_usage_errors() {
        assert_eq!(run_args(&["cache"]).unwrap(), EXIT_USAGE);
        assert_eq!(run_args(&["cache", "frobnicate"]).unwrap(), EXIT_USAGE);
        assert_eq!(run_args(&["cache", "stats"]).unwrap(), EXIT_USAGE, "missing --cache-dir");
        assert_eq!(run_args(&["cache", "gc"]).unwrap(), EXIT_USAGE, "missing --cache-dir");
    }

    #[test]
    fn cache_stats_and_gc_on_empty_store() {
        let dir = std::env::temp_dir().join(format!(
            "ciminus-cli-cache-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let dir_s = dir.to_str().unwrap().to_string();
        assert_eq!(run_args(&["cache", "stats", "--cache-dir", &dir_s]).unwrap(), EXIT_OK);
        assert_eq!(
            run_args(&["cache", "gc", "--cache-dir", &dir_s, "--cache-bytes", "1M"]).unwrap(),
            EXIT_OK
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_merge_usage_errors() {
        assert_eq!(run_args(&["journal"]).unwrap(), EXIT_USAGE);
        assert_eq!(run_args(&["journal", "frobnicate"]).unwrap(), EXIT_USAGE);
        assert_eq!(run_args(&["journal", "merge", "/tmp/s.jsonl"]).unwrap(), EXIT_USAGE);
        assert_eq!(
            run_args(&["journal", "merge", "--into", "/tmp/c.jsonl"]).unwrap(),
            EXIT_USAGE,
            "no shard journals given"
        );
    }

    #[test]
    fn journal_merge_folds_shards_into_canonical() {
        let dir = std::env::temp_dir().join(format!(
            "ciminus-cli-merge-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let canon = dir.join("canon.jsonl");
        let shard = dir.join("canon.jsonl.shard-0");
        std::fs::write(&canon, "{\"key\":\"a\",\"ok\":1}\n").unwrap();
        std::fs::write(&shard, "{\"key\":\"a\",\"ok\":1}\n{\"key\":\"b\",\"ok\":2}\n").unwrap();
        let code = run_args(&[
            "journal",
            "merge",
            "--into",
            canon.to_str().unwrap(),
            shard.to_str().unwrap(),
        ])
        .unwrap();
        assert_eq!(code, EXIT_OK);
        let map = executor::Journal::load_map(&canon).unwrap();
        assert_eq!(map.len(), 2, "duplicate key skipped, new key appended");
        assert_eq!(map.get("b").and_then(|v| v.as_f64()), Some(2.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn smoke_study_reports_partial_failure_and_resumes() {
        let dir = std::env::temp_dir().join(format!(
            "ciminus-cli-smoke-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = dir.join("smoke.jsonl");
        let _ = std::fs::remove_file(&ckpt);
        let ckpt_s = ckpt.to_str().unwrap();
        let code = run_args(&[
            "explore", "--study", "smoke", "--job-timeout", "0.3", "--checkpoint", ckpt_s,
        ])
        .unwrap();
        assert_eq!(code, EXIT_PARTIAL, "panicking + hanging points fail the sweep");
        let journal = std::fs::read_to_string(&ckpt).unwrap();
        assert_eq!(
            journal.lines().count(),
            6,
            "6 of 8 smoke points completed and were journaled:\n{journal}"
        );
        // resume: completed points replay from the journal, the bad two
        // fail again, exit code is still partial
        let code = run_args(&[
            "explore", "--study", "smoke", "--job-timeout", "0.3", "--checkpoint", ckpt_s,
            "--resume",
        ])
        .unwrap();
        assert_eq!(code, EXIT_PARTIAL);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
