//! CLI sparsity-pattern mini-language:
//!
//! `row_wise` | `row_block[:w]` | `column_wise` | `channel_wise` |
//! `column_block[:h]` | `intra:m` | `hybrid:m[:w]` | `hybrid_row_wise:m`
//! | `full:MxN` | `dense`
//!
//! combined with a `--ratio` value (overall sparsity).

use crate::sparsity::flexblock::FlexBlock;

pub fn parse_pattern(spec: &str, ratio: f64) -> anyhow::Result<FlexBlock> {
    let parts: Vec<&str> = spec.split(':').collect();
    let usize_at = |i: usize, default: usize| -> anyhow::Result<usize> {
        match parts.get(i) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad size `{v}` in pattern `{spec}`")),
        }
    };
    let fb = match parts[0] {
        "dense" => FlexBlock::dense(),
        "row_wise" | "rw" => FlexBlock::row_wise(ratio),
        "row_block" | "rb" => FlexBlock::row_block(usize_at(1, 16)?, ratio),
        "column_wise" | "cw" | "filter_wise" => FlexBlock::column_wise(ratio),
        "channel_wise" | "ch" => FlexBlock::channel_wise(ratio),
        "column_block" | "cb" => FlexBlock::column_block(usize_at(1, 16)?, ratio),
        "intra" => FlexBlock::intra(usize_at(1, 2)?, ratio),
        "hybrid" => FlexBlock::hybrid(usize_at(1, 2)?, usize_at(2, 16)?, ratio),
        "hybrid_row_wise" | "hrw" => FlexBlock::hybrid_row_wise(usize_at(1, 2)?, ratio),
        "full" => {
            let dims = parts
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("full pattern needs MxN, e.g. full:2x8"))?;
            let (m, n) = dims
                .split_once('x')
                .ok_or_else(|| anyhow::anyhow!("bad dims `{dims}`"))?;
            FlexBlock::full_block(m.parse()?, n.parse()?, ratio)
        }
        other => anyhow::bail!(
            "unknown pattern `{other}` (row_wise|row_block[:w]|column_wise|channel_wise|\
             column_block[:h]|intra:m|hybrid:m[:w]|hybrid_row_wise:m|full:MxN|dense)"
        ),
    };
    fb.validate()?;
    Ok(fb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_forms() {
        for (spec, name) in [
            ("row_wise", "Row-wise"),
            ("rb:8", "Row-block(8)"),
            ("column_wise", "Column-wise"),
            ("channel_wise", "Channel-wise"),
            ("cb:32", "Column-block(32)"),
            ("intra:4", "Intra(4,1)"),
            ("hybrid:2:16", "1:2+Row-block(16)"),
            ("hrw:2", "1:2+Row-wise"),
            ("full:2x8", "FullBlock(2,8)"),
        ] {
            let fb = parse_pattern(spec, 0.8).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(fb.name, name, "{spec}");
        }
        assert!(parse_pattern("dense", 0.8).unwrap().is_dense());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_pattern("wat", 0.8).is_err());
        assert!(parse_pattern("full:2", 0.8).is_err());
        assert!(parse_pattern("rb:x", 0.8).is_err());
        // invalid ratio caught by validate
        assert!(parse_pattern("row_wise", 1.5).is_err());
    }
}
