//! `ciminus` binary entry point. All logic lives in the library
//! (`ciminus::cli`) so integration tests and examples share it.
//!
//! Exit codes: 0 success, 1 hard error (the `Err` arm below),
//! 2 usage error, 3 completed with sweep failures — see
//! `cli::{EXIT_OK, EXIT_USAGE, EXIT_PARTIAL}` and docs/robust-sweeps.md.

fn main() {
    let code = match ciminus::cli::run(std::env::args().skip(1)) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}
