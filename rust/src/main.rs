//! `ciminus` binary entry point. All logic lives in the library
//! (`ciminus::cli`) so integration tests and examples share it.

fn main() {
    let code = match ciminus::cli::run(std::env::args().skip(1)) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}
