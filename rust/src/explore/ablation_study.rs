//! Ablations of CIMinus's own modeling choices (DESIGN.md §6): which
//! parts of the architecture/model drive the headline numbers.
//!
//! 1. zero-detect granularity: sub-array height sets the OR-group size —
//!    the knob separating MARS-like (64-row) from SDP-like (1-row)
//!    input-sparsity behavior;
//! 2. buffer double-buffering: the Eq. 3 overlap terms on/off;
//! 3. mapping policy: Auto vs forced spatial vs forced duplication.
//!
//! All groups evaluate through a shared [`EvalCtx`]: the subarray and
//! policy groups reuse cached profiles/prune plans across their points,
//! and the overlap group's ping-pong flip reuses one cached mapping
//! plan (ping-pong is a simulation-only knob).

use super::executor::{run_sweep, Codec, Job, Sweep, SweepConfig};
use crate::eval::{EvalCtx, Scenario};
use crate::hw::presets;
use crate::mapping::duplication::{Strategy, StrategyPolicy};
use crate::mapping::planner::MappingOptions;
use crate::sparsity::flexblock::FlexBlock;
use crate::util::json::Json;
use crate::workload::graph::Network;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct AblationPoint {
    pub label: String,
    pub cycles: u64,
    pub energy_pj: f64,
    pub skip_ratio: f64,
}

fn point_to_json(p: &AblationPoint) -> Json {
    let mut j = Json::obj();
    j.set("label", Json::Str(p.label.clone()))
        .set("cycles", Json::Num(p.cycles as f64))
        .set("energy_pj", Json::Num(p.energy_pj))
        .set("skip_ratio", Json::Num(p.skip_ratio));
    j
}

fn point_from_json(j: &Json) -> anyhow::Result<AblationPoint> {
    Ok(AblationPoint {
        label: j.req_str("label")?.to_string(),
        cycles: j.req_f64("cycles")? as u64,
        energy_pj: j.req_f64("energy_pj")?,
        skip_ratio: j.req_f64("skip_ratio")?,
    })
}

fn group_to_json(pts: &[AblationPoint]) -> Json {
    Json::Arr(pts.iter().map(point_to_json).collect())
}

fn group_from_json(j: &Json) -> anyhow::Result<Vec<AblationPoint>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("ablation group is not an array"))?
        .iter()
        .map(point_from_json)
        .collect()
}

/// Checkpoint-journal codec for one ablation group (a `Vec` of points).
pub fn ablation_codec() -> Codec<Vec<AblationPoint>> {
    Codec::new(|g: &Vec<AblationPoint>| group_to_json(g), group_from_json)
}

/// The ablation groups `run_all_robust` sweeps, in report order.
pub const GROUPS: [&str; 4] = ["subarray", "overlap", "policy", "bits"];

fn point_of(label: String, rep: &crate::sim::report::SimReport) -> AblationPoint {
    AblationPoint {
        label,
        cycles: rep.total_cycles,
        energy_pj: rep.energy.total_pj,
        skip_ratio: rep.mean_skip_ratio,
    }
}

/// Ablation 1: sub-array height ∈ {1, 8, 32} at fixed macro geometry.
pub fn subarray_granularity(net: &Network, ctx: &EvalCtx) -> anyhow::Result<Vec<AblationPoint>> {
    let net = Arc::new(net.clone());
    let mut out = Vec::new();
    for sub_rows in [1usize, 8, 32] {
        let mut arch = presets::usecase_arch(4, (2, 2));
        arch.cim.sub_rows = sub_rows;
        arch.name = format!("usecase_sub{sub_rows}");
        let s = Scenario::new(arch, net.clone())
            .synthetic_profiles(8, 0.55, 0xAB1)
            .with_sim(ctx.sim);
        let rep = ctx.evaluator.evaluate(&s)?;
        out.push(point_of(format!("sub_rows={sub_rows}"), &rep));
    }
    Ok(out)
}

/// Ablation 2: ping-pong buffering on/off (Eq. 3 overlap).
pub fn pipeline_overlap(net: &Network, ctx: &EvalCtx) -> anyhow::Result<Vec<AblationPoint>> {
    let net = Arc::new(net.clone());
    let mut out = Vec::new();
    for pp in [true, false] {
        let mut arch = presets::usecase_arch(4, (2, 2));
        arch.global_in_buf.ping_pong = pp;
        arch.global_out_buf.ping_pong = pp;
        let s = Scenario::new(arch, net.clone())
            .synthetic_profiles(8, 0.55, 0xAB2)
            .with_sim(ctx.sim);
        let rep = ctx.evaluator.evaluate(&s)?;
        out.push(point_of(format!("ping_pong={pp}"), &rep));
    }
    Ok(out)
}

/// Ablation 3: mapping policy comparison under sparsity.
pub fn policy_comparison(net: &Network, ctx: &EvalCtx) -> anyhow::Result<Vec<AblationPoint>> {
    let net = Arc::new(net.clone());
    let fb = FlexBlock::hybrid(2, 16, 0.8);
    let mut out = Vec::new();
    for (label, policy) in [
        ("auto", StrategyPolicy::Auto),
        ("spatial", StrategyPolicy::Fixed(Strategy::Spatial)),
        ("duplicate", StrategyPolicy::Fixed(Strategy::Duplicate)),
    ] {
        let arch = presets::usecase_arch(16, (4, 4));
        let opts = MappingOptions {
            policy,
            ..Default::default()
        };
        let s = Scenario::new(arch, net.clone())
            .prune_uniform(&fb)
            .with_mapping(opts)
            .synthetic_profiles(8, 0.55, 0xAB3)
            .with_sim(ctx.sim);
        let rep = ctx.evaluator.evaluate(&s)?;
        out.push(point_of(label.to_string(), &rep));
    }
    Ok(out)
}

/// Ablation 4: activation bit width (bit-serial depth) ∈ {4, 8, 12}.
/// Latency scales ~linearly with bits; the zero-bit skip ratio shifts
/// because low-precision quantization concentrates values.
pub fn bit_width(net: &Network, ctx: &EvalCtx) -> anyhow::Result<Vec<AblationPoint>> {
    let net = Arc::new(net.clone());
    let mut out = Vec::new();
    for bits in [4usize, 8, 12] {
        let mut arch = presets::usecase_arch(4, (2, 2));
        arch.input_bits = bits;
        let s = Scenario::new(arch, net.clone())
            .synthetic_profiles(bits, 0.55, 0xAB4)
            .with_sim(ctx.sim);
        let rep = ctx.evaluator.evaluate(&s)?;
        out.push(point_of(format!("input_bits={bits}"), &rep));
    }
    Ok(out)
}

/// All four ablation groups under the resilient executor: one job per
/// group, each returning its group's point list. A crash in one group
/// (e.g. an architecture invariant violated by an extreme knob value)
/// no longer discards the other three.
pub fn run_all_robust(
    net: &Network,
    ctx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<Sweep<Vec<AblationPoint>>> {
    let net = Arc::new(net.clone());
    let ctx = ctx.clone();
    let jobs: Vec<Job<&'static str>> = GROUPS
        .iter()
        .map(|&g| Job {
            key: format!("ablation:{g}"),
            input: g,
        })
        .collect();
    let report = run_sweep(jobs, cfg, Some(ablation_codec()), move |&group: &&'static str| {
        match group {
            "subarray" => subarray_granularity(&net, &ctx),
            "overlap" => pipeline_overlap(&net, &ctx),
            "policy" => policy_comparison(&net, &ctx),
            "bits" => bit_width(&net, &ctx),
            other => anyhow::bail!("unknown ablation group '{other}'"),
        }
    })?;
    Ok(Sweep::from_report(report))
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn finer_subarrays_skip_more() {
        let net = zoo::resnet_mini();
        let pts = subarray_granularity(&net, &EvalCtx::default()).unwrap();
        // skip ratio strictly decreases with group size
        assert!(pts[0].skip_ratio > pts[1].skip_ratio);
        assert!(pts[1].skip_ratio > pts[2].skip_ratio);
        // and buys latency
        assert!(pts[0].cycles < pts[2].cycles);
    }

    #[test]
    fn overlap_never_slower() {
        let net = zoo::resnet_mini();
        let ctx = EvalCtx::default();
        let pts = pipeline_overlap(&net, &ctx).unwrap();
        assert!(pts[0].cycles <= pts[1].cycles, "ping-pong helps or ties");
        // ping-pong is sim-only: the pair shares one cached mapping plan
        let s = ctx.evaluator.stats();
        assert_eq!(s.mapping.misses, 1, "{s}");
        assert_eq!(s.mapping.hits, 1, "{s}");
    }

    #[test]
    fn more_bits_cost_more_cycles() {
        let net = zoo::resnet_mini();
        let pts = bit_width(&net, &EvalCtx::default()).unwrap();
        assert!(pts[0].cycles < pts[1].cycles);
        assert!(pts[1].cycles < pts[2].cycles);
    }

    #[test]
    fn auto_policy_at_least_as_good_as_worst_fixed() {
        let net = zoo::resnet_mini();
        let ctx = EvalCtx::default();
        let pts = policy_comparison(&net, &ctx).unwrap();
        let auto = pts[0].cycles;
        let worst = pts.iter().skip(1).map(|p| p.cycles).max().unwrap();
        assert!(auto <= worst, "auto {auto} > worst fixed {worst}");
        // the three policies share one prune plan and one profile set
        let s = ctx.evaluator.stats();
        assert_eq!(s.prune.misses, 1, "{s}");
        assert_eq!(s.prune.hits, 2, "{s}");
        assert_eq!(s.profiles.misses, 1, "{s}");
        assert_eq!(s.profiles.hits, 2, "{s}");
    }

    #[test]
    fn robust_runner_covers_all_groups() {
        let net = zoo::resnet_mini();
        let sweep = run_all_robust(&net, &EvalCtx::default(), &SweepConfig::default()).unwrap();
        assert_eq!(sweep.total, GROUPS.len());
        assert!(sweep.failures.is_empty(), "{:?}", sweep.failures);
        let groups = sweep.strict().unwrap();
        assert_eq!(groups.len(), GROUPS.len());
        assert!(groups.iter().all(|g| !g.is_empty()));
    }

    #[test]
    fn ablation_codec_roundtrips() {
        let group = vec![
            AblationPoint {
                label: "sub_rows=1".into(),
                cycles: 100,
                energy_pj: 1.0,
                skip_ratio: 0.5,
            },
            AblationPoint {
                label: "sub_rows=8".into(),
                cycles: 80,
                energy_pj: 0.9,
                skip_ratio: 0.3,
            },
        ];
        let c = ablation_codec();
        let back = c.decode(&c.encode(&group)).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1].label, "sub_rows=8");
        assert_eq!(back[0].cycles, 100);
    }
}
