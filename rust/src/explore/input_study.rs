//! Input-sparsity exploitation study (Fig. 10): skippable ratios and the
//! speedups/energy savings they buy, across models, weight-sparsity
//! patterns and ratios.

use super::sweep::parallel_map;
use crate::hw::presets;
use crate::mapping::planner::{plan, MappingOptions};
use crate::pruning::workflow::PruningWorkflow;
use crate::sim::engine::{simulate, SimOptions};
use crate::sim::input_sparsity::InputProfiles;
use crate::sparsity::flexblock::FlexBlock;
use crate::workload::graph::Network;

/// One Fig. 10 measurement: the same configuration with (I) and without
/// (W) input-sparsity support.
#[derive(Debug, Clone)]
pub struct InputSparsityPoint {
    pub label: String,
    pub skip_ratio: f64,
    pub speedup_from_input: f64,
    pub energy_saving_from_input: f64,
}

fn run_pair(
    net: &Network,
    fb: Option<&FlexBlock>,
    profiles: &InputProfiles,
    label: &str,
) -> anyhow::Result<InputSparsityPoint> {
    let mut arch = presets::usecase_arch(4, (2, 2));
    let prune = match fb {
        Some(fb) => Some(PruningWorkflow::default().run_uniform(net, fb, None)?),
        None => None,
    };
    let mapping = plan(&arch, net, prune.as_ref(), MappingOptions::default())?;
    arch.sparsity.input_skipping = false;
    let without = simulate(&arch, net, &mapping, Some(profiles), SimOptions::default())?;
    arch.sparsity.input_skipping = true;
    let with = simulate(&arch, net, &mapping, Some(profiles), SimOptions::default())?;
    Ok(InputSparsityPoint {
        label: label.to_string(),
        skip_ratio: with.mean_skip_ratio,
        speedup_from_input: with.speedup_vs(&without),
        energy_saving_from_input: with.energy_saving_vs(&without),
    })
}

/// Fig. 10 left: input sparsity on dense models.
pub fn run_dense_models(
    nets: &[&Network],
    zero_frac: f64,
    threads: usize,
) -> anyhow::Result<Vec<InputSparsityPoint>> {
    let jobs: Vec<&Network> = nets.to_vec();
    let results = parallel_map(jobs, threads, |net| {
        let profiles = InputProfiles::synthetic(net, 8, zero_frac, 0xF16_10);
        run_pair(net, None, &profiles, &format!("{} (dense)", net.name))
    });
    results.into_iter().collect()
}

/// Fig. 10 middle: interaction with weight-sparsity patterns at 80%.
/// Sparser weights shift activation distributions toward more zeros
/// (`zero_frac` raised with weight sparsity, the paper's observation).
pub fn run_weight_patterns(
    net: &Network,
    threads: usize,
) -> anyhow::Result<Vec<InputSparsityPoint>> {
    let patterns = vec![
        FlexBlock::row_wise(0.8),
        FlexBlock::column_wise(0.8),
        FlexBlock::channel_wise(0.8),
        FlexBlock::row_block(16, 0.8),
        FlexBlock::hybrid(2, 16, 0.8),
        FlexBlock::intra(2, 0.5),
    ];
    let results = parallel_map(patterns, threads, |fb| {
        let profiles = InputProfiles::synthetic(net, 8, 0.62, 0xF16_10);
        run_pair(net, Some(&fb), &profiles, &fb.name)
    });
    results.into_iter().collect()
}

/// Fig. 10 right: row-wise pattern across weight-sparsity ratios.
pub fn run_ratio_sweep(
    net: &Network,
    ratios: &[f64],
    threads: usize,
) -> anyhow::Result<Vec<InputSparsityPoint>> {
    let jobs: Vec<f64> = ratios.to_vec();
    let results = parallel_map(jobs, threads, |r| {
        // activation zero-fraction grows with weight sparsity
        let zero_frac = 0.5 + 0.25 * r;
        let profiles = InputProfiles::synthetic(net, 8, zero_frac, 0xF16_10);
        let fb = FlexBlock::row_wise(r);
        run_pair(net, Some(&fb), &profiles, &format!("Row-wise@{r:.1}"))
    });
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn dense_models_gain_from_input_sparsity() {
        let a = zoo::resnet_mini();
        let b = zoo::vgg_mini();
        let pts = run_dense_models(&[&a, &b], 0.55, 0).unwrap();
        for p in &pts {
            assert!(p.speedup_from_input >= 1.0, "{}: {}", p.label, p.speedup_from_input);
            assert!(p.skip_ratio > 0.0);
        }
    }

    #[test]
    fn intra_skips_less_than_coarse() {
        let net = zoo::resnet_mini();
        let pts = run_weight_patterns(&net, 0).unwrap();
        let row = pts.iter().find(|p| p.label == "Row-wise").unwrap();
        let intra = pts.iter().find(|p| p.label.starts_with("Intra")).unwrap();
        assert!(
            intra.skip_ratio <= row.skip_ratio + 1e-9,
            "intra {} vs row {}",
            intra.skip_ratio,
            row.skip_ratio
        );
    }

    #[test]
    fn gains_grow_with_weight_sparsity() {
        let net = zoo::resnet_mini();
        let pts = run_ratio_sweep(&net, &[0.5, 0.9], 0).unwrap();
        assert!(
            pts[1].speedup_from_input >= pts[0].speedup_from_input * 0.98,
            "sparser model skips more: {} vs {}",
            pts[1].speedup_from_input,
            pts[0].speedup_from_input
        );
    }
}
