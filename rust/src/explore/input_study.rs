//! Input-sparsity exploitation study (Fig. 10): skippable ratios and the
//! speedups/energy savings they buy, across models, weight-sparsity
//! patterns and ratios.

use super::executor::{run_sweep, Codec, Job, Sweep, SweepConfig};
use crate::eval::{EvalCtx, Evaluator, Scenario};
use crate::hw::arch::Architecture;
use crate::hw::presets;
use crate::sim::engine::SimOptions;
use crate::sparsity::flexblock::FlexBlock;
use crate::util::json::Json;
use crate::workload::graph::Network;
use std::sync::Arc;

/// One Fig. 10 measurement: the same configuration with (I) and without
/// (W) input-sparsity support.
#[derive(Debug, Clone)]
pub struct InputSparsityPoint {
    pub label: String,
    pub skip_ratio: f64,
    pub speedup_from_input: f64,
    pub energy_saving_from_input: f64,
}

fn point_to_json(p: &InputSparsityPoint) -> Json {
    let mut j = Json::obj();
    j.set("label", Json::Str(p.label.clone()))
        .set("skip_ratio", Json::Num(p.skip_ratio))
        .set("speedup_from_input", Json::Num(p.speedup_from_input))
        .set(
            "energy_saving_from_input",
            Json::Num(p.energy_saving_from_input),
        );
    j
}

fn point_from_json(j: &Json) -> anyhow::Result<InputSparsityPoint> {
    Ok(InputSparsityPoint {
        label: j.req_str("label")?.to_string(),
        skip_ratio: j.req_f64("skip_ratio")?,
        speedup_from_input: j.req_f64("speedup_from_input")?,
        energy_saving_from_input: j.req_f64("energy_saving_from_input")?,
    })
}

/// Checkpoint-journal codec for [`InputSparsityPoint`] sweeps.
pub fn input_codec() -> Codec<InputSparsityPoint> {
    Codec::new(point_to_json, point_from_json)
}

/// Evaluate the same configuration with and without input-skipping.
/// The two scenarios differ only in `sparsity.input_skipping` — a
/// simulation-only knob canonicalized out of the planning-stage cache
/// key — so the pair shares one cached `MappingPlan` (and its prune
/// plan and profiles), replanning nothing.
fn run_pair(
    ev: &Evaluator,
    net: &Arc<Network>,
    fb: Option<&FlexBlock>,
    zero_frac: f64,
    sim: SimOptions,
    label: &str,
) -> anyhow::Result<InputSparsityPoint> {
    let mut arch = presets::usecase_arch(4, (2, 2));
    arch.sparsity.input_skipping = false;
    let scenario = |a: &Architecture| {
        let mut s = Scenario::new(a.clone(), net.clone())
            .synthetic_profiles(8, zero_frac, 0xF16_10)
            .with_sim(sim);
        if let Some(fb) = fb {
            s = s.prune_uniform(fb);
        }
        s
    };
    let without = ev.evaluate(&scenario(&arch))?;
    arch.sparsity.input_skipping = true;
    let with = ev.evaluate(&scenario(&arch))?;
    Ok(InputSparsityPoint {
        label: label.to_string(),
        skip_ratio: with.mean_skip_ratio,
        speedup_from_input: with.speedup_vs(&without),
        energy_saving_from_input: with.energy_saving_vs(&without),
    })
}

/// Fig. 10 left: input sparsity on dense models, under the resilient
/// executor.
pub fn run_dense_models_robust(
    nets: &[&Network],
    zero_frac: f64,
    ctx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<Sweep<InputSparsityPoint>> {
    let jobs: Vec<Job<Arc<Network>>> = nets
        .iter()
        .map(|n| Job {
            key: format!("fig10-dense:{}", n.name),
            input: Arc::new((*n).clone()),
        })
        .collect();
    let ev = ctx.evaluator.clone();
    let sim = ctx.sim;
    let report = run_sweep(jobs, cfg, Some(input_codec()), move |net: &Arc<Network>| {
        run_pair(
            &ev,
            net,
            None,
            zero_frac,
            sim,
            &format!("{} (dense)", net.name),
        )
    })?;
    Ok(Sweep::from_report(report))
}

pub fn run_dense_models(
    nets: &[&Network],
    zero_frac: f64,
    threads: usize,
) -> anyhow::Result<Vec<InputSparsityPoint>> {
    run_dense_models_robust(
        nets,
        zero_frac,
        &EvalCtx::default(),
        &SweepConfig::with_threads(threads),
    )?
    .strict()
}

/// Fig. 10 middle: interaction with weight-sparsity patterns at 80%,
/// under the resilient executor. Sparser weights shift activation
/// distributions toward more zeros (`zero_frac` raised with weight
/// sparsity, the paper's observation).
pub fn run_weight_patterns_robust(
    net: &Network,
    ctx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<Sweep<InputSparsityPoint>> {
    let net = Arc::new(net.clone());
    let patterns = vec![
        FlexBlock::row_wise(0.8),
        FlexBlock::column_wise(0.8),
        FlexBlock::channel_wise(0.8),
        FlexBlock::row_block(16, 0.8),
        FlexBlock::hybrid(2, 16, 0.8),
        FlexBlock::intra(2, 0.5),
    ];
    let jobs: Vec<Job<FlexBlock>> = patterns
        .into_iter()
        .map(|fb| Job {
            key: format!("fig10-pattern:{}", fb.name),
            input: fb,
        })
        .collect();
    let ev = ctx.evaluator.clone();
    let sim = ctx.sim;
    let report = run_sweep(jobs, cfg, Some(input_codec()), move |fb: &FlexBlock| {
        run_pair(&ev, &net, Some(fb), 0.62, sim, &fb.name)
    })?;
    Ok(Sweep::from_report(report))
}

pub fn run_weight_patterns(
    net: &Network,
    threads: usize,
) -> anyhow::Result<Vec<InputSparsityPoint>> {
    run_weight_patterns_robust(
        net,
        &EvalCtx::default(),
        &SweepConfig::with_threads(threads),
    )?
    .strict()
}

/// Fig. 10 right: row-wise pattern across weight-sparsity ratios, under
/// the resilient executor.
pub fn run_ratio_sweep_robust(
    net: &Network,
    ratios: &[f64],
    ctx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<Sweep<InputSparsityPoint>> {
    let net = Arc::new(net.clone());
    let jobs: Vec<Job<f64>> = ratios
        .iter()
        .map(|&r| Job {
            key: format!("fig10-ratio:{r:.3}"),
            input: r,
        })
        .collect();
    let ev = ctx.evaluator.clone();
    let sim = ctx.sim;
    let report = run_sweep(jobs, cfg, Some(input_codec()), move |&r: &f64| {
        // activation zero-fraction grows with weight sparsity
        let zero_frac = 0.5 + 0.25 * r;
        let fb = FlexBlock::row_wise(r);
        run_pair(
            &ev,
            &net,
            Some(&fb),
            zero_frac,
            sim,
            &format!("Row-wise@{r:.1}"),
        )
    })?;
    Ok(Sweep::from_report(report))
}

pub fn run_ratio_sweep(
    net: &Network,
    ratios: &[f64],
    threads: usize,
) -> anyhow::Result<Vec<InputSparsityPoint>> {
    run_ratio_sweep_robust(
        net,
        ratios,
        &EvalCtx::default(),
        &SweepConfig::with_threads(threads),
    )?
    .strict()
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn dense_models_gain_from_input_sparsity() {
        let a = zoo::resnet_mini();
        let b = zoo::vgg_mini();
        let pts = run_dense_models(&[&a, &b], 0.55, 0).unwrap();
        for p in &pts {
            assert!(p.speedup_from_input >= 1.0, "{}: {}", p.label, p.speedup_from_input);
            assert!(p.skip_ratio > 0.0);
        }
    }

    #[test]
    fn intra_skips_less_than_coarse() {
        let net = zoo::resnet_mini();
        let pts = run_weight_patterns(&net, 0).unwrap();
        let row = pts.iter().find(|p| p.label == "Row-wise").unwrap();
        let intra = pts.iter().find(|p| p.label.starts_with("Intra")).unwrap();
        assert!(
            intra.skip_ratio <= row.skip_ratio + 1e-9,
            "intra {} vs row {}",
            intra.skip_ratio,
            row.skip_ratio
        );
    }

    #[test]
    fn gains_grow_with_weight_sparsity() {
        let net = zoo::resnet_mini();
        let pts = run_ratio_sweep(&net, &[0.5, 0.9], 0).unwrap();
        assert!(
            pts[1].speedup_from_input >= pts[0].speedup_from_input * 0.98,
            "sparser model skips more: {} vs {}",
            pts[1].speedup_from_input,
            pts[0].speedup_from_input
        );
    }

    #[test]
    fn skip_pair_reuses_planning_artifacts() {
        let net = Arc::new(zoo::resnet_mini());
        let ev = Evaluator::new();
        let fb = FlexBlock::hybrid(2, 16, 0.8);
        run_pair(&ev, &net, Some(&fb), 0.55, SimOptions::default(), "pair").unwrap();
        let s = ev.stats();
        assert_eq!(s.mapping.misses, 1, "pair planned once: {s}");
        assert_eq!(s.mapping.hits, 1, "second leg hit the plan cache: {s}");
        assert_eq!(s.prune.misses, 1);
        assert_eq!(s.prune.hits, 1);
        assert_eq!(s.sim.misses, 2, "both legs simulated");
    }

    #[test]
    fn input_point_codec_roundtrips() {
        let p = InputSparsityPoint {
            label: "Row-wise@0.8".into(),
            skip_ratio: 0.42,
            speedup_from_input: 1.6,
            energy_saving_from_input: 1.3,
        };
        let c = input_codec();
        let back = c.decode(&c.encode(&p)).unwrap();
        assert_eq!(back.label, p.label);
        assert_eq!(back.skip_ratio, p.skip_ratio);
    }
}
