//! Process-level shard workers for `--isolation=process` sweeps.
//!
//! The in-thread engine (`explore::executor`) survives panics, but a
//! job that calls `abort()`, gets OOM-killed, or segfaults (e.g. inside
//! the optional PJRT runtime) still takes the whole sweep down, and a
//! truly hung job can only be shed, never stopped. This module trades a
//! process boundary for both problems:
//!
//! * [`supervise`] partitions the pending job queue into shards and
//!   forks one worker process per shard by re-exec'ing the `ciminus`
//!   binary in the hidden `__worker` mode;
//! * each worker re-builds the study's job list from a [`TaskSpec`]
//!   header frame, runs only its assigned keys in-thread, checkpoints
//!   to a per-shard journal, and streams per-job result frames
//!   (length-prefixed JSON) back over its stdout pipe;
//! * the shard manager enforces the configured `job_timeout` as a
//!   **hard** timeout — the worker is killed and respawned with the
//!   remaining keys — and turns abnormal worker deaths into structured
//!   [`JobError::Crashed`] failures for exactly the in-flight job;
//! * at end of run the shard journals are merged into the canonical
//!   checkpoint journal (last-writer-wins), so `--resume` works the
//!   same in both isolation modes, and even a SIGKILL'd supervisor
//!   leaves mergeable shard journals behind.
//!
//! Workers that outlive a killed supervisor notice re-parenting (ppid
//! becomes 1) at their next progress event and exit instead of burning
//! CPU on a sweep nobody will collect.

use super::executor::{
    lock, Codec, IsolationMode, JobError, JobOutcome, Journal, ProgressEvent, ProgressHook,
    SweepConfig, SweepReport, TaskSpec,
};
use crate::eval::diskcache::DiskStore;
use crate::eval::{EvalCtx, EvalStats};
use crate::sim::engine::SimOptions;
use crate::util::json::Json;
use crate::workload::{graph::Network, zoo};
use anyhow::Context;
use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufReader, Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on a single protocol frame; a length prefix beyond this
/// means the stream is corrupt, not that a result is this large.
const MAX_FRAME: usize = 64 << 20;

/// Manager poll granularity for hard-timeout checks.
const SHARD_TICK: Duration = Duration::from_millis(25);

/// Consecutive worker spawns that die without resolving a single job
/// before the manager gives up on the shard.
const MAX_BARREN_SPAWNS: u32 = 2;

// ---------------------------------------------------------------------
// frame protocol
// ---------------------------------------------------------------------

/// Write one `u32`-length-prefixed (little-endian) JSON frame.
pub(crate) fn write_frame<W: Write>(w: &mut W, frame: &Json) -> std::io::Result<()> {
    let bytes = frame.to_string().into_bytes();
    w.write_all(&(bytes.len() as u32).to_le_bytes())?;
    w.write_all(&bytes)?;
    w.flush()
}

/// Read one frame. `Ok(None)` is a clean EOF at a frame boundary; a
/// torn header/body (stream killed mid-write) or an over-long length
/// prefix is an error.
pub(crate) fn read_frame<R: Read>(r: &mut R) -> anyhow::Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => anyhow::bail!("torn frame header ({got} of 4 length bytes)"),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    anyhow::ensure!(len <= MAX_FRAME, "frame length {len} exceeds {MAX_FRAME}");
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)
        .map_err(|e| anyhow::anyhow!("torn frame body: {e}"))?;
    let text = std::str::from_utf8(&buf)
        .map_err(|e| anyhow::anyhow!("frame is not UTF-8: {e}"))?;
    let frame = Json::parse(text).map_err(|e| anyhow::anyhow!("frame parse error: {e}"))?;
    Ok(Some(frame))
}

// ---------------------------------------------------------------------
// platform shims
// ---------------------------------------------------------------------

#[cfg(unix)]
fn exit_signal(status: &std::process::ExitStatus) -> Option<i32> {
    use std::os::unix::process::ExitStatusExt;
    status.signal()
}

#[cfg(not(unix))]
fn exit_signal(_status: &std::process::ExitStatus) -> Option<i32> {
    None
}

/// True when this worker's supervisor is gone and init adopted us.
#[cfg(unix)]
fn orphaned() -> bool {
    std::os::unix::process::parent_id() == 1
}

#[cfg(not(unix))]
fn orphaned() -> bool {
    false
}

// ---------------------------------------------------------------------
// supervisor side
// ---------------------------------------------------------------------

enum RawResult {
    Ok(Json),
    Err(JobError),
}

/// Results and failure accounting shared by all shard managers. Raw
/// (still-encoded) results are kept here because `Codec` closures are
/// not `Send`; the main thread decodes after the managers join.
struct ShardState {
    results: Mutex<Vec<Option<RawResult>>>,
    failures: AtomicUsize,
    abort: AtomicBool,
    max_failures: Option<usize>,
    /// Artifact-cache counters reported by workers on their `done`
    /// frames, merged across shards and respawns.
    worker_stats: Mutex<EvalStats>,
}

impl ShardState {
    fn record(&self, idx: usize, r: RawResult) {
        let mut slots = lock(&self.results);
        if slots[idx].is_some() {
            return; // first writer wins (e.g. late frame after a kill)
        }
        let is_err = matches!(r, RawResult::Err(_));
        slots[idx] = Some(r);
        drop(slots);
        if is_err {
            let f = self.failures.fetch_add(1, Ordering::Relaxed) + 1;
            if let Some(maxf) = self.max_failures {
                if f >= maxf {
                    self.abort.store(true, Ordering::Relaxed);
                }
            }
        }
    }
}

fn shard_count(requested: usize, n_pending: usize) -> usize {
    let want = if requested == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(4)
    } else {
        requested
    };
    want.clamp(1, n_pending.max(1))
}

fn header_for(task: &TaskSpec, cfg: &SweepConfig, shard: usize, journal: &Path) -> Json {
    let mut h = Json::obj();
    h.set("task", Json::Str(task.name.clone()));
    h.set("params", task.params.clone());
    h.set("shard", Json::Num(shard as f64));
    h.set("journal", Json::Str(journal.display().to_string()));
    h.set("retries", Json::Num(cfg.max_retries as f64));
    h.set("backoff_ms", Json::Num(cfg.retry_backoff.as_millis() as f64));
    h.set(
        "backoff_cap_ms",
        Json::Num(cfg.backoff_cap.as_millis() as f64),
    );
    if let Some(dir) = &cfg.cache_dir {
        // all shards open the same store: entries are content-addressed
        // and published atomically, so concurrent writers are safe
        h.set("cache_dir", Json::Str(dir.display().to_string()));
        h.set("cache_bytes", Json::Num(cfg.cache_bytes as f64));
    }
    h
}

/// Run the pending jobs of a sweep in per-shard worker processes and
/// assemble the full report. Called by `run_sweep` once resume replay
/// has filled `outcomes` for already-completed keys.
pub(crate) fn supervise<R>(
    keys: Vec<String>,
    mut outcomes: Vec<Option<JobOutcome<R>>>,
    cfg: &SweepConfig,
    codec: &Codec<R>,
    task: &TaskSpec,
) -> anyhow::Result<SweepReport<R>> {
    let n = keys.len();
    let pending: Vec<usize> = (0..n).filter(|&i| outcomes[i].is_none()).collect();
    if pending.is_empty() {
        let outcomes = outcomes
            .into_iter()
            .map(|o| o.expect("every job has an outcome"))
            .collect();
        return Ok(SweepReport { outcomes });
    }
    let exe =
        std::env::current_exe().context("locating the ciminus binary for worker re-exec")?;

    let nshards = shard_count(cfg.shards, pending.len());
    // round-robin partition keeps shards balanced even when expensive
    // jobs cluster at one end of the queue
    let mut partitions: Vec<Vec<(usize, String)>> = vec![Vec::new(); nshards];
    for (pos, &idx) in pending.iter().enumerate() {
        partitions[pos % nshards].push((idx, keys[idx].clone()));
    }

    // shard journals live next to the canonical journal — a killed
    // supervisor leaves them behind for `--resume` to fold in — or in a
    // temp dir for checkpoint-less sweeps
    let (journal_base, temp_dir) = match cfg.checkpoint.as_ref() {
        Some(p) => (p.clone(), None),
        None => {
            let dir =
                std::env::temp_dir().join(format!("ciminus-shards-{}", std::process::id()));
            std::fs::create_dir_all(&dir)
                .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
            (dir.join("sweep.jsonl"), Some(dir))
        }
    };
    let shard_paths: Vec<PathBuf> = (0..nshards)
        .map(|i| PathBuf::from(format!("{}.shard-{i}", journal_base.display())))
        .collect();

    let state = Arc::new(ShardState {
        results: Mutex::new((0..n).map(|_| None).collect()),
        failures: AtomicUsize::new(0),
        abort: AtomicBool::new(false),
        max_failures: cfg.max_failures,
        worker_stats: Mutex::new(EvalStats::default()),
    });

    let mut managers = Vec::new();
    for (shard_id, assigned) in partitions.into_iter().enumerate() {
        if assigned.is_empty() {
            continue;
        }
        let st = Arc::clone(&state);
        let header = header_for(task, cfg, shard_id, &shard_paths[shard_id]);
        let exe = exe.clone();
        let hard = cfg.job_timeout;
        let m = std::thread::Builder::new()
            .name(format!("ciminus-shard-{shard_id}"))
            .spawn(move || run_shard(shard_id, assigned, st, exe, header, hard))
            .map_err(|e| anyhow::anyhow!("spawning shard manager {shard_id}: {e}"))?;
        managers.push(m);
    }
    for m in managers {
        let _ = m.join();
    }

    // hand the merged worker cache counters back to the caller so the
    // summary line reflects the whole sweep, not just the supervisor
    if let Some(hook) = &cfg.worker_stats {
        let ws = *lock(&state.worker_stats);
        hook.0(&ws);
    }

    // fold the shard journals into the canonical journal so a plain
    // `--resume` (and thread-mode runs) see one coherent checkpoint
    if cfg.checkpoint.is_some() {
        match Journal::merge_files(&journal_base, &shard_paths) {
            Ok(_) => {
                for p in &shard_paths {
                    let _ = std::fs::remove_file(p);
                }
            }
            Err(e) => {
                eprintln!("warning: shard journal merge failed (shard files kept): {e}")
            }
        }
    } else {
        for p in &shard_paths {
            let _ = std::fs::remove_file(p);
        }
        if let Some(dir) = temp_dir {
            let _ = std::fs::remove_file(&journal_base);
            let _ = std::fs::remove_dir(&dir);
        }
    }

    // decode on the main thread (codecs are not Send)
    let mut slots = lock(&state.results);
    for (i, slot) in slots.iter_mut().enumerate() {
        if outcomes[i].is_some() {
            continue;
        }
        let (attempts, result) = match slot.take() {
            Some(RawResult::Ok(v)) => match codec.decode(&v) {
                Ok(r) => (1, Ok(r)),
                Err(e) => (
                    1,
                    Err(JobError::Failed(format!("decoding worker result: {e:#}"))),
                ),
            },
            Some(RawResult::Err(e)) => (1, Err(e)),
            None => (
                0,
                Err(JobError::Aborted("no worker produced this point".into())),
            ),
        };
        outcomes[i] = Some(JobOutcome {
            key: keys[i].clone(),
            index: i,
            attempts,
            from_checkpoint: false,
            result,
        });
    }
    drop(slots);

    let outcomes = outcomes
        .into_iter()
        .map(|o| o.expect("every job has an outcome"))
        .collect();
    Ok(SweepReport { outcomes })
}

enum ShardMsg {
    Frame(Json),
    Eof,
}

/// Own one shard: spawn a worker over the unresolved keys, relay its
/// frames into results, kill it on hard timeout or crash, and respawn
/// until the shard is drained or hopeless.
fn run_shard(
    shard: usize,
    assigned: Vec<(usize, String)>,
    state: Arc<ShardState>,
    exe: PathBuf,
    header_base: Json,
    hard_timeout: Option<Duration>,
) {
    let key_to_idx: BTreeMap<&str, usize> =
        assigned.iter().map(|(i, k)| (k.as_str(), *i)).collect();
    let mut resolved: BTreeSet<usize> = BTreeSet::new();
    let mut barren = 0u32;
    let mut last_signal = 0i32;
    loop {
        if state.abort.load(Ordering::Relaxed) {
            break;
        }
        let remaining: Vec<(usize, String)> = assigned
            .iter()
            .filter(|(i, _)| !resolved.contains(i))
            .cloned()
            .collect();
        if remaining.is_empty() {
            break;
        }
        let mut header = header_base.clone();
        header.set(
            "keys",
            Json::Arr(remaining.iter().map(|(_, k)| Json::Str(k.clone())).collect()),
        );
        let mut child = match Command::new(&exe)
            .arg("__worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
        {
            Ok(c) => c,
            Err(e) => {
                for (i, _) in &remaining {
                    state.record(
                        *i,
                        RawResult::Err(JobError::Failed(format!("spawning worker: {e}"))),
                    );
                    resolved.insert(*i);
                }
                break;
            }
        };
        if let Some(mut stdin) = child.stdin.take() {
            // a write failure means the worker died instantly; the
            // event loop below sees EOF and handles it as a crash
            let _ = write_frame(&mut stdin, &header);
        }
        let stdout = match child.stdout.take() {
            Some(s) => s,
            None => {
                let _ = child.kill();
                let _ = child.wait();
                for (i, _) in &remaining {
                    state.record(
                        *i,
                        RawResult::Err(JobError::Failed("worker stdout unavailable".into())),
                    );
                    resolved.insert(*i);
                }
                break;
            }
        };
        let (tx, rx) = mpsc::channel::<ShardMsg>();
        let reader = std::thread::spawn(move || {
            let mut r = BufReader::new(stdout);
            loop {
                match read_frame(&mut r) {
                    Ok(Some(frame)) => {
                        if tx.send(ShardMsg::Frame(frame)).is_err() {
                            return;
                        }
                    }
                    // clean EOF, or a frame torn by a kill: either way
                    // this worker's stream is over
                    _ => {
                        let _ = tx.send(ShardMsg::Eof);
                        return;
                    }
                }
            }
        });

        let mut in_flight: Option<(usize, Option<Instant>)> = None;
        let mut progressed = false;
        let mut got_done = false;
        let mut killed_by_us = false;
        loop {
            match rx.recv_timeout(SHARD_TICK) {
                Ok(ShardMsg::Frame(frame)) => {
                    let idx_of = |f: &Json| -> Option<usize> {
                        f.get("key")
                            .and_then(|k| k.as_str())
                            .and_then(|k| key_to_idx.get(k).copied())
                    };
                    match frame.opt_str("ev", "") {
                        "start" => {
                            if let Some(idx) = idx_of(&frame) {
                                in_flight =
                                    Some((idx, hard_timeout.map(|t| Instant::now() + t)));
                            }
                        }
                        "ok" => {
                            if let Some(idx) = idx_of(&frame) {
                                let val = frame.get("val").cloned().unwrap_or(Json::Null);
                                state.record(idx, RawResult::Ok(val));
                                resolved.insert(idx);
                                progressed = true;
                            }
                            in_flight = None;
                        }
                        "err" => {
                            if let Some(idx) = idx_of(&frame) {
                                let msg = frame.opt_str("msg", "").to_string();
                                let err = match frame.opt_str("kind", "error") {
                                    "panic" => JobError::Panic(msg),
                                    "aborted" => JobError::Aborted(msg),
                                    "timeout" => JobError::Timeout(
                                        hard_timeout.unwrap_or(Duration::ZERO),
                                    ),
                                    _ => JobError::Failed(msg),
                                };
                                state.record(idx, RawResult::Err(err));
                                resolved.insert(idx);
                                progressed = true;
                            }
                            in_flight = None;
                        }
                        "done" => {
                            got_done = true;
                            if let Some(s) = frame.get("stats") {
                                lock(&state.worker_stats).merge(&EvalStats::from_json(s));
                            }
                        }
                        "fatal" => {
                            // the worker could not even build the job
                            // list (bad task/model spec): fail the
                            // whole shard, respawning cannot help
                            let msg = frame.opt_str("msg", "worker failed").to_string();
                            let left: Vec<usize> = assigned
                                .iter()
                                .map(|(i, _)| *i)
                                .filter(|i| !resolved.contains(i))
                                .collect();
                            for i in left {
                                state.record(
                                    i,
                                    RawResult::Err(JobError::Failed(format!(
                                        "worker for shard {shard}: {msg}"
                                    ))),
                                );
                                resolved.insert(i);
                            }
                            progressed = true;
                        }
                        _ => {}
                    }
                }
                Ok(ShardMsg::Eof) | Err(mpsc::RecvTimeoutError::Disconnected) => break,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if state.abort.load(Ordering::Relaxed) && !killed_by_us {
                        let _ = child.kill();
                        killed_by_us = true;
                    }
                    if let Some((idx, Some(deadline))) = in_flight {
                        if Instant::now() >= deadline {
                            // hard timeout: kill the worker process; a
                            // respawn picks up the rest of the shard
                            let _ = child.kill();
                            killed_by_us = true;
                            state.record(
                                idx,
                                RawResult::Err(JobError::Timeout(
                                    hard_timeout.unwrap_or(Duration::ZERO),
                                )),
                            );
                            resolved.insert(idx);
                            progressed = true;
                            in_flight = None;
                        }
                    }
                }
            }
        }
        let status = child.wait();
        let _ = reader.join();

        if got_done {
            // a worker that said `done` but skipped assigned keys has
            // an inconsistent job list — an engine bug, not transient
            let left: Vec<(usize, String)> = assigned
                .iter()
                .filter(|(i, _)| !resolved.contains(i))
                .cloned()
                .collect();
            for (i, k) in left {
                state.record(
                    i,
                    RawResult::Err(JobError::Failed(format!(
                        "worker for shard {shard} completed without reporting `{k}`"
                    ))),
                );
                resolved.insert(i);
            }
            break;
        }
        if !killed_by_us {
            // abnormal worker death: attribute it to the in-flight job
            let signal = status.ok().and_then(|s| exit_signal(&s)).unwrap_or(0);
            last_signal = signal;
            if let Some((idx, _)) = in_flight.take() {
                if !resolved.contains(&idx) {
                    state.record(idx, RawResult::Err(JobError::Crashed { signal, shard }));
                    resolved.insert(idx);
                    progressed = true;
                }
            }
        }
        if state.abort.load(Ordering::Relaxed) {
            break;
        }
        if progressed {
            barren = 0;
        } else {
            barren += 1;
        }
        if barren >= MAX_BARREN_SPAWNS {
            // repeated spawns died before resolving anything (e.g. a
            // crash during job-list construction): stop burning
            // processes and fail what is left of the shard
            let left: Vec<usize> = assigned
                .iter()
                .map(|(i, _)| *i)
                .filter(|i| !resolved.contains(i))
                .collect();
            for i in left {
                state.record(
                    i,
                    RawResult::Err(JobError::Crashed {
                        signal: last_signal,
                        shard,
                    }),
                );
                resolved.insert(i);
            }
            break;
        }
    }
}

// ---------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------

fn emit_frame(frame: &Json) {
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let _ = write_frame(&mut out, frame);
}

/// Progress hook that streams per-job frames to the supervisor and
/// exits if the supervisor is gone.
fn stdout_sink() -> ProgressHook {
    ProgressHook(Arc::new(|ev: &ProgressEvent| {
        if orphaned() {
            std::process::exit(17);
        }
        let mut f = Json::obj();
        match ev {
            ProgressEvent::Started { key } => {
                f.set("ev", Json::Str("start".into()));
                f.set("key", Json::Str(key.clone()));
            }
            ProgressEvent::Ok { key, value } => {
                f.set("ev", Json::Str("ok".into()));
                f.set("key", Json::Str(key.clone()));
                f.set("val", value.clone());
            }
            ProgressEvent::Failed { key, kind, message } => {
                f.set("ev", Json::Str("err".into()));
                f.set("key", Json::Str(key.clone()));
                f.set("kind", Json::Str((*kind).to_string()));
                f.set("msg", Json::Str(message.clone()));
            }
        }
        emit_frame(&f);
    }))
}

/// Entry point for the hidden `ciminus __worker` mode: read the header
/// frame from stdin, re-build the study's job list, run only the
/// assigned keys in-thread (checkpointing to the shard journal and
/// streaming result frames on stdout), then report `done`. Returns the
/// process exit code.
pub fn worker_main() -> anyhow::Result<i32> {
    let header = {
        let stdin = std::io::stdin();
        let mut input = stdin.lock();
        match read_frame(&mut input)? {
            Some(h) => h,
            None => anyhow::bail!("worker started without a header frame"),
        }
    };
    let task = header.req_str("task")?.to_string();
    let params = header.get("params").cloned().unwrap_or_else(Json::obj);
    let journal = PathBuf::from(header.req_str("journal")?);
    let keys: BTreeSet<String> = header
        .req_arr("keys")?
        .iter()
        .filter_map(|k| k.as_str().map(str::to_string))
        .collect();
    let cfg = SweepConfig {
        // one in-flight job per worker keeps hard-timeout and crash
        // attribution unambiguous; parallelism comes from --shards
        threads: 1,
        // the supervisor enforces the (hard) timeout by killing us
        job_timeout: None,
        max_retries: header.opt_usize("retries", 0) as u32,
        retry_backoff: Duration::from_millis(header.opt_usize("backoff_ms", 50) as u64),
        backoff_cap: Duration::from_millis(header.opt_usize("backoff_cap_ms", 2000) as u64),
        max_failures: None,
        checkpoint: Some(journal),
        resume: false,
        isolation: IsolationMode::Thread,
        shards: 0,
        task: None,
        key_filter: Some(keys),
        progress: Some(stdout_sink()),
        cache_dir: header
            .get("cache_dir")
            .and_then(|v| v.as_str())
            .map(PathBuf::from),
        cache_bytes: header.opt_usize("cache_bytes", 0) as u64,
        worker_stats: None,
    };
    let ectx = ectx_of(&params, &cfg);
    match dispatch(&task, &params, &cfg, &ectx) {
        Ok(()) => {
            let mut f = Json::obj();
            f.set("ev", Json::Str("done".into()));
            // report this process's cache counters so the supervisor
            // can fold them into the sweep-wide summary
            f.set("stats", ectx.evaluator.stats().to_json());
            emit_frame(&f);
            Ok(0)
        }
        Err(e) => {
            let mut f = Json::obj();
            f.set("ev", Json::Str("fatal".into()));
            f.set("msg", Json::Str(format!("{e:#}")));
            emit_frame(&f);
            Ok(1)
        }
    }
}

// ---------------------------------------------------------------------
// task registry
// ---------------------------------------------------------------------

fn ectx_of(p: &Json, cfg: &SweepConfig) -> EvalCtx {
    let mut sim = SimOptions::default();
    if let Some(t) = p.get("postproc").and_then(|v| v.as_usize()) {
        if t > 0 {
            sim.postproc_throughput = t;
        }
    }
    match &cfg.cache_dir {
        Some(dir) => match DiskStore::open(dir, cfg.cache_bytes) {
            Ok(store) => EvalCtx::with_disk(sim, Arc::new(store)),
            Err(e) => {
                // an unusable store must not fail the sweep; fall back
                // to the process-local memory cache
                eprintln!("warning: disk cache at {} disabled: {e:#}", dir.display());
                EvalCtx::new(sim)
            }
        },
        None => EvalCtx::new(sim),
    }
}

fn f64s(p: &Json, key: &str, default: &[f64]) -> Vec<f64> {
    p.get(key)
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
        .unwrap_or_else(|| default.to_vec())
}

fn trio() -> (Network, Network, Network) {
    (
        zoo::resnet50(32, 100),
        zoo::vgg16(32, 100),
        zoo::mobilenetv2(32, 100),
    )
}

/// Re-run the named study sub-sweep under the worker's configuration.
/// Every sub-sweep the CLI can launch in process mode has an entry
/// here; the job *keys* double as the contract between both sides, so
/// a worker rebuilds exactly the job list the supervisor partitioned.
fn dispatch(task: &str, p: &Json, cfg: &SweepConfig, ectx: &EvalCtx) -> anyhow::Result<()> {
    use super::{
        ablation_study, executor, fault_study, input_study, mapping_study, search,
        sparsity_study,
    };
    use crate::cli::{load_arch, load_net};
    match task {
        "smoke" => {
            let points = p.get("points").and_then(|v| v.as_usize());
            let job_ms = p.opt_usize("job_ms", 0) as u64;
            executor::smoke_sweep_sized(cfg, points, job_ms)?;
        }
        "fig8" => {
            let net = load_net(p.opt_str("model", "resnet50"))?;
            let ratios = f64s(p, "ratios", &sparsity_study::RATIOS);
            sparsity_study::run_fig8_robust(&net, &ratios, ectx, cfg)?;
        }
        "fig9a" => {
            let net = load_net(p.opt_str("model", "resnet50"))?;
            sparsity_study::run_fig9a_robust(&net, ectx, cfg)?;
        }
        "fig9b" => {
            let (r50, v16, mb) = trio();
            sparsity_study::run_fig9b_robust(&[&r50, &v16, &mb], ectx, cfg)?;
        }
        "fig10-dense" => {
            let (r50, v16, mb) = trio();
            let zero_frac = p.opt_f64("zero_frac", 0.55);
            input_study::run_dense_models_robust(&[&r50, &v16, &mb], zero_frac, ectx, cfg)?;
        }
        "fig10-pattern" => {
            let net = load_net(p.opt_str("model", "resnet50"))?;
            input_study::run_weight_patterns_robust(&net, ectx, cfg)?;
        }
        "fig10-ratio" => {
            let net = load_net(p.opt_str("model", "resnet50"))?;
            let ratios = f64s(p, "ratios", &[0.5, 0.6, 0.7, 0.8, 0.9]);
            input_study::run_ratio_sweep_robust(&net, &ratios, ectx, cfg)?;
        }
        "fig11" => {
            let r50 = zoo::resnet50(32, 100);
            let v16 = zoo::vgg16(32, 100);
            mapping_study::run_fig11_robust(&[&r50, &v16], ectx, cfg)?;
        }
        "fig12" => {
            let net = load_net(p.opt_str("model", "resnet50"))?;
            mapping_study::run_fig12_robust(&net, ectx, cfg)?;
        }
        "ablation" => {
            let net = load_net(p.opt_str("model", "resnet_mini"))?;
            ablation_study::run_all_robust(&net, ectx, cfg)?;
        }
        "faults" => {
            let arch = load_arch(p.req_str("arch")?)?;
            let net = load_net(p.opt_str("model", "resnet_mini"))?;
            let fb = crate::cli::pattern::parse_pattern(
                p.opt_str("pattern", "dense"),
                p.opt_f64("ratio", 0.8),
            )?;
            let rates = f64s(p, "rates", &fault_study::DEFAULT_RATES);
            let spatial =
                crate::hw::faults::FaultSpatial::parse(p.opt_str("spatial", "uniform"))?;
            let seed = p.opt_usize("seed", 0xC1A0) as u64;
            let fb_opt = (!fb.is_dense()).then_some(&fb);
            fault_study::run_resilience_robust(
                &arch,
                &net,
                fb_opt,
                &rates,
                spatial,
                seed,
                ectx,
                cfg,
            )?;
        }
        "search" => {
            let net = load_net(p.opt_str("model", "resnet50"))?;
            let n_macros = p.opt_usize("macros", 16);
            let cons = search::Constraints {
                max_sparsity: p.get("max_sparsity").and_then(|v| v.as_f64()),
                min_utilization: p.get("min_util").and_then(|v| v.as_f64()),
            };
            let ratios = f64s(p, "ratios", &[0.5, 0.7, 0.8, 0.9]);
            search::search_robust(&net, n_macros, &ratios, cons, ectx, cfg)?;
        }
        other => anyhow::bail!("unknown worker task `{other}`"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut frame = Json::obj();
        frame.set("ev", Json::Str("ok".into()));
        frame.set("key", Json::Str("smoke-0".into()));
        frame.set("val", Json::Num(42.0));
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let mut second = Json::obj();
        second.set("ev", Json::Str("done".into()));
        write_frame(&mut buf, &second).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let a = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(a.opt_str("ev", ""), "ok");
        assert_eq!(a.get("val").and_then(|v| v.as_f64()), Some(42.0));
        let b = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(b.opt_str("ev", ""), "done");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn torn_frame_is_an_error() {
        let mut frame = Json::obj();
        frame.set("ev", Json::Str("ok".into()));
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        buf.truncate(buf.len() - 3); // killed mid-write
        let mut r = std::io::Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn oversized_frame_is_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut r = std::io::Cursor::new(buf);
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn shard_count_bounds() {
        assert_eq!(shard_count(3, 100), 3);
        assert_eq!(shard_count(8, 2), 2, "never more shards than jobs");
        assert_eq!(shard_count(0, 0), 1);
        assert!(shard_count(0, 100) >= 1);
    }

    #[test]
    fn header_carries_task_identity() {
        let task = TaskSpec::new("smoke", Json::obj());
        let cfg = SweepConfig::default();
        let h = header_for(&task, &cfg, 3, Path::new("/tmp/x.jsonl.shard-3"));
        assert_eq!(h.opt_str("task", ""), "smoke");
        assert_eq!(h.opt_usize("shard", 99), 3);
        assert_eq!(h.opt_str("journal", ""), "/tmp/x.jsonl.shard-3");
    }
}
