//! Design-space exploration (Sec. VII): the resilient sweep executor
//! and the study drivers behind Fig. 8–12.
//!
//! Unhandled `.unwrap()` in sweep code means one bad design point can
//! abort an hours-long exploration, so it is linted against here
//! (tests are exempt).
#![warn(clippy::unwrap_used)]

pub mod ablation_study;
pub mod executor;
pub mod fault_study;
pub mod input_study;
pub mod mapping_study;
pub mod search;
pub mod sparsity_study;
pub mod sweep;
pub mod worker;

pub use executor::{
    run_sweep, Codec, IsolationMode, Job, JobError, JobOutcome, ProgressEvent, ProgressHook,
    Sweep, SweepConfig, SweepFailure, SweepReport, TaskSpec,
};
pub use sweep::{parallel_map, try_parallel_map};
