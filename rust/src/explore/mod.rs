//! Design-space exploration (Sec. VII): the parallel sweep executor and
//! the study drivers behind Fig. 8–12.

pub mod ablation_study;
pub mod fault_study;
pub mod input_study;
pub mod mapping_study;
pub mod search;
pub mod sparsity_study;
pub mod sweep;

pub use sweep::parallel_map;
