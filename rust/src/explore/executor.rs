//! Resilient job-execution engine for design-space sweeps.
//!
//! `parallel_map` (explore/sweep.rs) fans work out but dies with its
//! worst job: one panicking design point aborts the whole study, a hung
//! PJRT call blocks it forever, and hours of sweep work cannot be
//! resumed. This module is the production replacement:
//!
//! * **panic isolation** — every job runs under `catch_unwind`; a panic
//!   becomes a structured [`JobError::Panic`] for that point only;
//! * **watchdog timeouts** — a configurable per-job soft timeout marks
//!   stuck jobs [`JobError::Timeout`] and the sweep continues (the
//!   stuck worker thread is replaced; it is reclaimed when it wakes);
//! * **bounded retries** — transient `Err` results are retried up to
//!   `max_retries` times with exponential, capped backoff;
//! * **circuit breaker** — `max_failures` aborts the remaining queue
//!   ([`JobError::Aborted`]) once too many points have failed;
//! * **checkpointed resume** — each completed point is appended to a
//!   crash-safe JSONL journal; a re-run with `resume` replays finished
//!   points from the journal instead of recomputing them;
//! * **process isolation** — with [`IsolationMode::Process`] the job
//!   queue is partitioned into shards and each shard runs in a forked
//!   worker *process* (see `explore::worker`): aborts, OOM kills and
//!   segfaults become structured [`JobError::Crashed`] failures, and
//!   `job_timeout` turns into a **hard** kill-and-respawn timeout.
//!   Workers checkpoint to per-shard journals that are merged into the
//!   canonical journal (last-writer-wins), so even a SIGKILL'd sweep
//!   resumes cleanly.
//!
//! Results are always reported in input order, independent of thread
//! count, so sweeps are deterministic under `--threads` variation.

use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt;
use std::panic::{self, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Worker threads carry this name prefix so the quiet panic hook can
/// suppress the default "thread panicked" noise for captured panics.
const WORKER_PREFIX: &str = "ciminus-job-";

/// Coordinator poll granularity for the watchdog.
const WATCHDOG_TICK: Duration = Duration::from_millis(25);

// ---------------------------------------------------------------------
// error taxonomy
// ---------------------------------------------------------------------

/// Why a single sweep job produced no point.
#[derive(Debug, Clone)]
pub enum JobError {
    /// The job panicked; payload is the captured panic message.
    Panic(String),
    /// The job returned an error (after exhausting retries).
    Failed(String),
    /// The job blew its per-job soft timeout.
    Timeout(Duration),
    /// The sweep's failure budget was exhausted before this job ran.
    Aborted(String),
    /// The worker process executing the job died — abort, OOM kill,
    /// segfault. Only produced under `--isolation=process`, which is
    /// the point: `catch_unwind` cannot see any of these.
    Crashed { signal: i32, shard: usize },
}

impl JobError {
    /// Short machine-friendly class label for summaries.
    pub fn kind(&self) -> &'static str {
        match self {
            JobError::Panic(_) => "panic",
            JobError::Failed(_) => "error",
            JobError::Timeout(_) => "timeout",
            JobError::Aborted(_) => "aborted",
            JobError::Crashed { .. } => "crashed",
        }
    }

    /// The bare payload, without the kind prefix `Display` adds. Used
    /// by the worker protocol so messages survive a round trip through
    /// frames without accumulating `panic: panic: …` prefixes.
    pub fn message(&self) -> String {
        match self {
            JobError::Panic(m) | JobError::Failed(m) | JobError::Aborted(m) => m.clone(),
            JobError::Timeout(d) => format!("after {:.2}s", d.as_secs_f64()),
            JobError::Crashed { .. } => self.to_string(),
        }
    }
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::Panic(m) => write!(f, "panic: {m}"),
            JobError::Failed(m) => write!(f, "error: {m}"),
            JobError::Timeout(d) => write!(f, "timeout after {:.2}s", d.as_secs_f64()),
            JobError::Aborted(m) => write!(f, "aborted: {m}"),
            JobError::Crashed { signal, shard } => {
                if *signal > 0 {
                    write!(f, "crashed: worker for shard {shard} killed by signal {signal}")
                } else {
                    write!(f, "crashed: worker for shard {shard} exited abnormally")
                }
            }
        }
    }
}

impl std::error::Error for JobError {}

/// Extract a printable message from a caught panic payload.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

// ---------------------------------------------------------------------
// configuration
// ---------------------------------------------------------------------

/// How sweep jobs are isolated from each other.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsolationMode {
    /// In-process worker threads with `catch_unwind` (the default).
    Thread,
    /// One worker *process* per shard: survives aborts, OOM kills and
    /// segfaults, and enforces hard kill-on-timeout.
    Process,
}

impl IsolationMode {
    pub fn parse(s: &str) -> anyhow::Result<IsolationMode> {
        match s {
            "thread" => Ok(IsolationMode::Thread),
            "process" => Ok(IsolationMode::Process),
            other => anyhow::bail!("unknown isolation mode `{other}` (thread|process)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            IsolationMode::Thread => "thread",
            IsolationMode::Process => "process",
        }
    }
}

/// Names a study sub-sweep so a `__worker` process can rebuild the same
/// job list from scratch. The CLI stamps one on the `SweepConfig` before
/// each sub-sweep it launches; `explore::worker` keeps the registry that
/// maps `name` back to the study runner.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: String,
    /// Task parameters (model/arch specs, ratios, seeds, …) as JSON so
    /// they serialize straight into the worker header frame.
    pub params: Json,
}

impl TaskSpec {
    pub fn new(name: &str, params: Json) -> TaskSpec {
        TaskSpec {
            name: name.to_string(),
            params,
        }
    }
}

/// Per-job lifecycle events emitted by the in-thread engine when a
/// `progress` hook is configured. Process-mode workers use the hook to
/// stream results to their supervisor as they complete.
#[derive(Debug, Clone)]
pub enum ProgressEvent {
    Started { key: String },
    Ok { key: String, value: Json },
    Failed { key: String, kind: &'static str, message: String },
}

/// Cloneable progress callback; wrapped so `SweepConfig` keeps deriving
/// `Debug`/`Clone`.
#[derive(Clone)]
pub struct ProgressHook(pub Arc<dyn Fn(&ProgressEvent) + Send + Sync>);

impl fmt::Debug for ProgressHook {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("ProgressHook(..)")
    }
}

/// Receives the aggregated artifact-cache counters that process-mode
/// workers report on their `done` frames, so the supervisor's summary
/// reflects the whole sweep instead of losing them when workers exit.
/// Wrapped like [`ProgressHook`] so `SweepConfig` keeps deriving
/// `Debug`/`Clone`.
#[derive(Clone)]
pub struct StatsHook(pub Arc<dyn Fn(&crate::eval::EvalStats) + Send + Sync>);

impl fmt::Debug for StatsHook {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("StatsHook(..)")
    }
}

/// Execution policy for a sweep. `Default` reproduces the historical
/// behavior (all cores, no timeout, no retries, no checkpoint) except
/// that panics are captured instead of aborting the process.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Worker threads; 0 = available parallelism.
    pub threads: usize,
    /// Per-job (per-attempt) timeout. `None` disables it. Soft (the
    /// stuck thread is shed) in thread mode; **hard** (the worker
    /// process is killed and respawned) in process mode.
    pub job_timeout: Option<Duration>,
    /// Extra attempts after a transient `Err` (panics are not retried).
    pub max_retries: u32,
    /// Base backoff before the first retry; doubles per attempt.
    pub retry_backoff: Duration,
    /// Upper bound on the exponential backoff.
    pub backoff_cap: Duration,
    /// Abort the remaining queue once this many jobs have failed.
    pub max_failures: Option<usize>,
    /// JSONL checkpoint journal path (appended as points complete).
    pub checkpoint: Option<PathBuf>,
    /// Replay completed points from the journal instead of recomputing.
    pub resume: bool,
    /// Job isolation: in-thread `catch_unwind` or per-shard worker
    /// processes (requires a `task` so workers can rebuild the jobs).
    pub isolation: IsolationMode,
    /// Worker processes in process mode; 0 = auto.
    pub shards: usize,
    /// Process-mode task identity; set by the CLI per sub-sweep.
    pub task: Option<TaskSpec>,
    /// Restrict execution to these job keys; everything else resolves
    /// as `Aborted` without running. Set for process-mode workers.
    pub key_filter: Option<BTreeSet<String>>,
    /// Per-job progress callback (process-mode workers stream frames).
    pub progress: Option<ProgressHook>,
    /// Shared disk-backed artifact store (`--cache-dir`). Forwarded to
    /// process-mode workers over the header frame so every shard hits
    /// one store.
    pub cache_dir: Option<PathBuf>,
    /// Byte bound for the disk store; 0 = the store's default.
    pub cache_bytes: u64,
    /// Callback invoked by the process-mode supervisor with the merged
    /// worker cache counters after the shards drain.
    pub worker_stats: Option<StatsHook>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            threads: 0,
            job_timeout: None,
            max_retries: 0,
            retry_backoff: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
            max_failures: None,
            checkpoint: None,
            resume: false,
            isolation: IsolationMode::Thread,
            shards: 0,
            task: None,
            key_filter: None,
            progress: None,
            cache_dir: None,
            cache_bytes: 0,
            worker_stats: None,
        }
    }
}

impl SweepConfig {
    /// The legacy `threads`-only configuration used by the strict
    /// study wrappers.
    pub fn with_threads(threads: usize) -> Self {
        SweepConfig {
            threads,
            ..SweepConfig::default()
        }
    }

    /// This configuration with a process-mode task identity attached.
    pub fn tasked(&self, task: TaskSpec) -> SweepConfig {
        SweepConfig {
            task: Some(task),
            ..self.clone()
        }
    }
}

// ---------------------------------------------------------------------
// jobs, outcomes, reports
// ---------------------------------------------------------------------

/// One unit of sweep work. `key` must be stable across runs — it is the
/// checkpoint-journal identity used by `--resume`.
#[derive(Debug, Clone)]
pub struct Job<T> {
    pub key: String,
    pub input: T,
}

/// What happened to one job.
#[derive(Debug)]
pub struct JobOutcome<R> {
    pub key: String,
    pub index: usize,
    /// Attempts actually executed (0 when replayed from a checkpoint).
    pub attempts: u32,
    /// True when the result was replayed from the journal.
    pub from_checkpoint: bool,
    pub result: Result<R, JobError>,
}

/// Raw per-job outcomes of a sweep, in input order.
#[derive(Debug)]
pub struct SweepReport<R> {
    pub outcomes: Vec<JobOutcome<R>>,
}

impl<R> SweepReport<R> {
    pub fn n_ok(&self) -> usize {
        self.outcomes.iter().filter(|o| o.result.is_ok()).count()
    }

    pub fn n_failed(&self) -> usize {
        self.outcomes.len() - self.n_ok()
    }

    pub fn n_resumed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.from_checkpoint).count()
    }
}

/// One failed point of a sweep, keyed for reporting.
#[derive(Debug, Clone)]
pub struct SweepFailure {
    pub key: String,
    pub error: JobError,
}

/// Partial-results view of a sweep: the points that succeeded (input
/// order), plus a structured account of everything that did not.
#[derive(Debug)]
pub struct Sweep<P> {
    pub points: Vec<P>,
    pub failures: Vec<SweepFailure>,
    /// Points replayed from the checkpoint journal.
    pub resumed: usize,
    /// Total jobs in the sweep (ok + failed).
    pub total: usize,
}

impl<P> Sweep<P> {
    pub fn from_report(report: SweepReport<P>) -> Self {
        let total = report.outcomes.len();
        let mut points = Vec::new();
        let mut failures = Vec::new();
        let mut resumed = 0;
        for o in report.outcomes {
            if o.from_checkpoint {
                resumed += 1;
            }
            match o.result {
                Ok(p) => points.push(p),
                Err(e) => failures.push(SweepFailure {
                    key: o.key,
                    error: e,
                }),
            }
        }
        Sweep {
            points,
            failures,
            resumed,
            total,
        }
    }

    /// `"N ok / M failed (reasons) / K resumed"`.
    pub fn summary(&self) -> String {
        summary_line(self.total - self.failures.len(), &self.failures, self.resumed)
    }

    /// Legacy all-or-nothing view: error out if any point failed.
    pub fn strict(self) -> anyhow::Result<Vec<P>> {
        if let Some(first) = self.failures.first() {
            anyhow::bail!(
                "{} of {} sweep jobs failed; first: {}: {}",
                self.failures.len(),
                self.total,
                first.key,
                first.error
            );
        }
        Ok(self.points)
    }
}

/// Shared formatter for sweep summaries (also used by the CLI when it
/// aggregates several sub-sweeps of one study).
pub fn summary_line(ok: usize, failures: &[SweepFailure], resumed: usize) -> String {
    let mut s = format!("{ok} ok / {} failed", failures.len());
    if !failures.is_empty() {
        let mut kinds: BTreeMap<&'static str, usize> = BTreeMap::new();
        for f in failures {
            *kinds.entry(f.error.kind()).or_insert(0) += 1;
        }
        let parts: Vec<String> = kinds.iter().map(|(k, n)| format!("{n} {k}")).collect();
        s.push_str(&format!(" ({})", parts.join(", ")));
    }
    if resumed > 0 {
        s.push_str(&format!(" / {resumed} resumed"));
    }
    s
}

// ---------------------------------------------------------------------
// checkpoint journal
// ---------------------------------------------------------------------

/// Point serializer pair for the checkpoint journal.
pub struct Codec<R> {
    encode: Box<dyn Fn(&R) -> Json>,
    decode: Box<dyn Fn(&Json) -> anyhow::Result<R>>,
}

impl<R> Codec<R> {
    pub fn new(
        encode: impl Fn(&R) -> Json + 'static,
        decode: impl Fn(&Json) -> anyhow::Result<R> + 'static,
    ) -> Self {
        Codec {
            encode: Box::new(encode),
            decode: Box::new(decode),
        }
    }

    pub fn encode(&self, r: &R) -> Json {
        (self.encode)(r)
    }

    pub fn decode(&self, j: &Json) -> anyhow::Result<R> {
        (self.decode)(j)
    }
}

/// Append-only JSONL checkpoint journal. One line per completed point:
/// `{"key": "...", "ok": <encoded point>}`. Lines are flushed as they
/// are written; a torn final line from a crash is skipped on load, so a
/// resumed run simply recomputes that point.
pub struct Journal {
    file: std::fs::File,
}

impl Journal {
    /// Load `key -> encoded point` from an existing journal. A missing
    /// file is an empty journal. Torn or corrupt lines — a crash
    /// mid-append can truncate anywhere, including inside a multi-byte
    /// UTF-8 sequence — are skipped with a warning so they cost one
    /// recomputed point instead of the whole resume. Duplicate keys are
    /// last-writer-wins, which is what makes shard-journal merging and
    /// re-runs over the same journal safe.
    pub fn load_map(path: &Path) -> anyhow::Result<BTreeMap<String, Json>> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(BTreeMap::new()),
            Err(e) => anyhow::bail!("reading checkpoint journal {}: {e}", path.display()),
        };
        let text = String::from_utf8_lossy(&bytes);
        let mut map = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            match Json::parse(line) {
                Ok(j) => match (j.get("key").and_then(Json::as_str), j.get("ok")) {
                    (Some(k), Some(v)) => {
                        map.insert(k.to_string(), v.clone());
                    }
                    _ => eprintln!(
                        "warning: {}:{}: journal line lacks key/ok fields, skipped",
                        path.display(),
                        lineno + 1
                    ),
                },
                Err(_) => eprintln!(
                    "warning: {}:{}: torn or corrupt journal line skipped",
                    path.display(),
                    lineno + 1
                ),
            }
        }
        Ok(map)
    }

    /// Merge shard journals into a canonical journal: every (key,
    /// value) pair whose *effective* value (after last-writer-wins
    /// collapse) differs from the canonical journal's is appended to
    /// it. Later shard files win over earlier ones. Returns the number
    /// of entries appended. Used by the supervisor at end of run, by
    /// `--resume` to absorb shard journals orphaned by a killed
    /// supervisor, and by the `journal merge` CLI subcommand for
    /// independently-run (distributed) shards.
    pub fn merge_files(canonical: &Path, shards: &[PathBuf]) -> anyhow::Result<usize> {
        let have = Journal::load_map(canonical)?;
        // collapse across ALL shards first (later shards win), then
        // diff once against the canonical map — appending per shard
        // instead would re-append both sides of a cross-shard conflict
        // on every re-merge, breaking idempotency
        let mut incoming = BTreeMap::new();
        for shard in shards {
            for (key, val) in Journal::load_map(shard)? {
                incoming.insert(key, val);
            }
        }
        let mut out = Journal::open_append(canonical)?;
        let mut appended = 0usize;
        for (key, val) in incoming {
            if have.get(&key) == Some(&val) {
                continue;
            }
            out.append(&key, &val)
                .map_err(|e| anyhow::anyhow!("appending to {}: {e}", canonical.display()))?;
            appended += 1;
        }
        Ok(appended)
    }

    /// Fold `<canonical>.shard-N` journals left behind by a killed
    /// process-mode supervisor into the canonical journal, then delete
    /// them. Returns the number of merged entries; quietly a no-op when
    /// there is nothing to fold.
    pub fn merge_orphan_shards(canonical: &Path) -> anyhow::Result<usize> {
        let parent = match canonical.parent() {
            Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
            _ => PathBuf::from("."),
        };
        let prefix = match canonical.file_name().and_then(|s| s.to_str()) {
            Some(name) => format!("{name}.shard-"),
            None => return Ok(0),
        };
        let entries = match std::fs::read_dir(&parent) {
            Ok(rd) => rd,
            Err(_) => return Ok(0),
        };
        let mut shards: Vec<PathBuf> = entries
            .flatten()
            .filter(|e| {
                e.file_name()
                    .to_str()
                    .is_some_and(|n| n.starts_with(&prefix))
            })
            .map(|e| e.path())
            .collect();
        if shards.is_empty() {
            return Ok(0);
        }
        shards.sort();
        let merged = Journal::merge_files(canonical, &shards)?;
        for s in &shards {
            let _ = std::fs::remove_file(s);
        }
        Ok(merged)
    }

    pub fn open_append(path: &Path) -> anyhow::Result<Journal> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| anyhow::anyhow!("creating {}: {e}", parent.display()))?;
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| anyhow::anyhow!("opening checkpoint journal {}: {e}", path.display()))?;
        Ok(Journal { file })
    }

    pub fn append(&mut self, key: &str, result: &Json) -> std::io::Result<()> {
        use std::io::Write;
        let line = Json::from_pairs(vec![
            ("key", Json::Str(key.to_string())),
            ("ok", result.clone()),
        ])
        .to_string();
        writeln!(self.file, "{line}")?;
        self.file.flush()
    }
}

// ---------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------

struct Shared<T, F> {
    items: Vec<Job<T>>,
    queue: Mutex<VecDeque<usize>>,
    aborted: AtomicBool,
    f: F,
    max_retries: u32,
    retry_backoff: Duration,
    backoff_cap: Duration,
}

enum Event<R> {
    Started {
        idx: usize,
        attempt: u32,
        at: Instant,
    },
    Finished {
        idx: usize,
        attempts: u32,
        result: Result<R, JobError>,
    },
}

pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // a worker panic can never poison these locks (jobs run outside the
    // critical sections and under catch_unwind), but never abort a
    // sweep over a poisoned mutex either way
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn effective_threads(requested: usize, n_jobs: usize) -> usize {
    let t = if requested == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        requested
    };
    t.clamp(1, n_jobs.max(1))
}

/// Suppress the default "thread '…' panicked" stderr noise for panics
/// that the executor captures; every other thread keeps the previous
/// hook behavior.
pub(crate) fn install_quiet_panic_hook() {
    static HOOK: std::sync::Once = std::sync::Once::new();
    HOOK.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            let captured = std::thread::current()
                .name()
                .is_some_and(|n| n.starts_with(WORKER_PREFIX));
            if !captured {
                prev(info);
            }
        }));
    });
}

fn worker_loop<T, R, F>(shared: Arc<Shared<T, F>>, tx: Sender<Event<R>>)
where
    F: Fn(&T) -> anyhow::Result<R>,
{
    loop {
        if shared.aborted.load(Ordering::Relaxed) {
            return;
        }
        let idx = match lock(&shared.queue).pop_front() {
            Some(i) => i,
            None => return,
        };
        let job = &shared.items[idx];
        let mut attempt: u32 = 0;
        let result = loop {
            attempt += 1;
            let _ = tx.send(Event::Started {
                idx,
                attempt,
                at: Instant::now(),
            });
            match panic::catch_unwind(AssertUnwindSafe(|| (shared.f)(&job.input))) {
                Ok(Ok(v)) => break Ok(v),
                Ok(Err(e)) => {
                    if attempt <= shared.max_retries && !shared.aborted.load(Ordering::Relaxed) {
                        let exp = attempt.saturating_sub(1).min(16);
                        let backoff = shared
                            .retry_backoff
                            .saturating_mul(1u32 << exp)
                            .min(shared.backoff_cap);
                        std::thread::sleep(backoff);
                        continue;
                    }
                    break Err(JobError::Failed(format!("{e:#}")));
                }
                Err(payload) => break Err(JobError::Panic(panic_message(payload.as_ref()))),
            }
        };
        // the coordinator may already be gone (late result of a
        // timed-out job after the sweep finished) — ignore send errors
        let _ = tx.send(Event::Finished {
            idx,
            attempts: attempt,
            result,
        });
    }
}

fn spawn_worker<T, R, F>(shared: &Arc<Shared<T, F>>, tx: &Sender<Event<R>>, id: usize)
where
    T: Send + Sync + 'static,
    R: Send + 'static,
    F: Fn(&T) -> anyhow::Result<R> + Send + Sync + 'static,
{
    let shared = Arc::clone(shared);
    let tx = tx.clone();
    // detached on purpose: a worker stuck on a hung job must not block
    // sweep completion; it exits on its own when the job wakes
    std::thread::Builder::new()
        .name(format!("{WORKER_PREFIX}{id}"))
        .spawn(move || worker_loop(shared, tx))
        .expect("spawn sweep worker");
}

/// Run `f` over `jobs` under the configured policy and return per-job
/// outcomes in input order.
///
/// `Err` is reserved for engine-level failures (unreadable or unwritable
/// checkpoint journal); per-job failures are reported in the outcomes.
/// Without a `codec` the checkpoint options are inert.
///
/// Caveat: a job that hangs forever with no `job_timeout` configured
/// blocks the sweep exactly like the old `parallel_map` — configure a
/// timeout for untrusted design points.
pub fn run_sweep<T, R, F>(
    jobs: Vec<Job<T>>,
    cfg: &SweepConfig,
    codec: Option<Codec<R>>,
    f: F,
) -> anyhow::Result<SweepReport<R>>
where
    T: Send + Sync + 'static,
    R: Send + 'static,
    F: Fn(&T) -> anyhow::Result<R> + Send + Sync + 'static,
{
    install_quiet_panic_hook();
    let n = jobs.len();
    let mut outcomes: Vec<Option<JobOutcome<R>>> = Vec::with_capacity(n);
    outcomes.resize_with(n, || None);

    // resume: replay completed points recorded by a previous run
    if cfg.resume {
        if let (Some(path), Some(codec)) = (cfg.checkpoint.as_ref(), codec.as_ref()) {
            // a SIGKILL'd process-mode supervisor leaves its progress in
            // per-shard journals; fold them in before loading
            if cfg.isolation == IsolationMode::Process && cfg.key_filter.is_none() {
                match Journal::merge_orphan_shards(path) {
                    Ok(0) => {}
                    Ok(m) => eprintln!(
                        "sweep: recovered {m} completed points from orphaned shard journals"
                    ),
                    Err(e) => eprintln!("warning: shard journal recovery failed: {e}"),
                }
            }
            let seen = Journal::load_map(path)?;
            for (i, job) in jobs.iter().enumerate() {
                if let Some(saved) = seen.get(&job.key) {
                    if let Ok(r) = codec.decode(saved) {
                        outcomes[i] = Some(JobOutcome {
                            key: job.key.clone(),
                            index: i,
                            attempts: 0,
                            from_checkpoint: true,
                            result: Ok(r),
                        });
                    }
                }
            }
        }
    }
    // shard filter (process-mode workers): jobs outside the assigned
    // key set resolve immediately without running
    if let Some(filter) = cfg.key_filter.as_ref() {
        for (i, job) in jobs.iter().enumerate() {
            if outcomes[i].is_none() && !filter.contains(&job.key) {
                outcomes[i] = Some(JobOutcome {
                    key: job.key.clone(),
                    index: i,
                    attempts: 0,
                    from_checkpoint: false,
                    result: Err(JobError::Aborted("outside this worker's shard".into())),
                });
            }
        }
    }

    // process isolation: delegate the remaining queue to the shard
    // supervisor, which forks one worker process per shard
    if cfg.isolation == IsolationMode::Process && cfg.key_filter.is_none() {
        match (cfg.task.as_ref(), codec.as_ref()) {
            (Some(task), Some(c)) => {
                let keys: Vec<String> = jobs.iter().map(|j| j.key.clone()).collect();
                return super::worker::supervise(keys, outcomes, cfg, c, task);
            }
            (None, _) => eprintln!(
                "warning: this sweep has no process-mode task registered; \
                 falling back to --isolation=thread"
            ),
            (Some(_), None) => eprintln!(
                "warning: --isolation=process needs a checkpoint codec; \
                 falling back to --isolation=thread"
            ),
        }
    }

    let mut journal = match (&cfg.checkpoint, &codec) {
        (Some(path), Some(_)) => Some(Journal::open_append(path)?),
        _ => None,
    };

    let pending: VecDeque<usize> = (0..n).filter(|&i| outcomes[i].is_none()).collect();
    let mut done = n - pending.len();
    let mut failures = 0usize;

    if done < n {
        let threads = effective_threads(cfg.threads, n - done);
        let shared = Arc::new(Shared {
            items: jobs,
            queue: Mutex::new(pending),
            aborted: AtomicBool::new(false),
            f,
            max_retries: cfg.max_retries,
            retry_backoff: cfg.retry_backoff,
            backoff_cap: cfg.backoff_cap,
        });
        let (tx, rx) = mpsc::channel::<Event<R>>();
        for id in 0..threads {
            spawn_worker(&shared, &tx, id);
        }
        let mut next_worker_id = threads;
        // idx -> (attempt, watchdog deadline)
        let mut running: BTreeMap<usize, (u32, Instant)> = BTreeMap::new();

        let emit = |ev: ProgressEvent| {
            if let Some(h) = cfg.progress.as_ref() {
                (h.0)(&ev);
            }
        };

        while done < n {
            match rx.recv_timeout(WATCHDOG_TICK) {
                Ok(Event::Started { idx, attempt, at }) => {
                    if attempt == 1 {
                        emit(ProgressEvent::Started {
                            key: shared.items[idx].key.clone(),
                        });
                    }
                    if let Some(t) = cfg.job_timeout {
                        running.insert(idx, (attempt, at + t));
                    }
                }
                Ok(Event::Finished {
                    idx,
                    attempts,
                    result,
                }) => {
                    running.remove(&idx);
                    if outcomes[idx].is_some() {
                        continue; // late result of a job already timed out
                    }
                    match (&result, codec.as_ref()) {
                        (Ok(r), Some(c)) => {
                            let encoded = c.encode(r);
                            if let Some(j) = journal.as_mut() {
                                if let Err(e) = j.append(&shared.items[idx].key, &encoded) {
                                    eprintln!(
                                        "warning: checkpoint append failed for `{}`: {e}",
                                        shared.items[idx].key
                                    );
                                }
                            }
                            emit(ProgressEvent::Ok {
                                key: shared.items[idx].key.clone(),
                                value: encoded,
                            });
                        }
                        (Ok(_), None) => {}
                        (Err(e), _) => emit(ProgressEvent::Failed {
                            key: shared.items[idx].key.clone(),
                            kind: e.kind(),
                            message: e.message(),
                        }),
                    }
                    if result.is_err() {
                        failures += 1;
                    }
                    outcomes[idx] = Some(JobOutcome {
                        key: shared.items[idx].key.clone(),
                        index: idx,
                        attempts,
                        from_checkpoint: false,
                        result,
                    });
                    done += 1;
                }
                Err(mpsc::RecvTimeoutError::Timeout) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                }
            }

            // watchdog: expire attempts that blew the soft timeout
            if !running.is_empty() {
                let now = Instant::now();
                let expired: Vec<(usize, u32)> = running
                    .iter()
                    .filter(|(_, (_, deadline))| *deadline <= now)
                    .map(|(&i, &(attempt, _))| (i, attempt))
                    .collect();
                for (idx, attempt) in expired {
                    running.remove(&idx);
                    if outcomes[idx].is_some() {
                        continue;
                    }
                    let t = cfg.job_timeout.unwrap_or(WATCHDOG_TICK);
                    emit(ProgressEvent::Failed {
                        key: shared.items[idx].key.clone(),
                        kind: "timeout",
                        message: format!("after {:.2}s", t.as_secs_f64()),
                    });
                    outcomes[idx] = Some(JobOutcome {
                        key: shared.items[idx].key.clone(),
                        index: idx,
                        attempts: attempt,
                        from_checkpoint: false,
                        result: Err(JobError::Timeout(t)),
                    });
                    done += 1;
                    failures += 1;
                    // the worker stuck on this job is lost to the pool;
                    // replace it if there is still queued work
                    if !lock(&shared.queue).is_empty() {
                        spawn_worker(&shared, &tx, next_worker_id);
                        next_worker_id += 1;
                    }
                }
            }

            // circuit breaker: stop scheduling once the budget is spent
            if let Some(maxf) = cfg.max_failures {
                if failures >= maxf && !shared.aborted.load(Ordering::Relaxed) {
                    shared.aborted.store(true, Ordering::Relaxed);
                    let drained: Vec<usize> = {
                        let mut q = lock(&shared.queue);
                        q.drain(..).collect()
                    };
                    for idx in drained {
                        if outcomes[idx].is_some() {
                            continue;
                        }
                        let msg = format!(
                            "sweep aborted after {failures} failures (--max-failures {maxf})"
                        );
                        emit(ProgressEvent::Failed {
                            key: shared.items[idx].key.clone(),
                            kind: "aborted",
                            message: msg.clone(),
                        });
                        outcomes[idx] = Some(JobOutcome {
                            key: shared.items[idx].key.clone(),
                            index: idx,
                            attempts: 0,
                            from_checkpoint: false,
                            result: Err(JobError::Aborted(msg)),
                        });
                        done += 1;
                    }
                }
            }
        }
        // release any straggler threads when their jobs wake up
        shared.aborted.store(true, Ordering::Relaxed);
    }

    let outcomes = outcomes
        .into_iter()
        .map(|o| o.expect("every job has an outcome"))
        .collect();
    Ok(SweepReport { outcomes })
}

// ---------------------------------------------------------------------
// built-in smoke sweep (CLI `explore --study smoke`, CI)
// ---------------------------------------------------------------------

/// A tiny self-contained sweep with one deliberately panicking job and
/// one deliberately hanging job — and, under process isolation, one job
/// that aborts its whole worker process. Exercises the full engine —
/// panic capture, timeouts, crash recovery, checkpointing — without
/// touching the simulator, so CI can assert partial-failure exit
/// behavior cheaply. If no timeout is configured, a 500 ms default is
/// applied so the hang is always caught.
pub fn smoke_sweep(cfg: &SweepConfig) -> anyhow::Result<Sweep<f64>> {
    smoke_sweep_sized(cfg, None, 0)
}

/// The smoke sweep, optionally resized: with `points = Some(n)` the
/// sweep becomes `n` clean jobs that each sleep `job_ms` — the shape
/// the CI kill/resume smoke needs (a long, steadily-checkpointing run
/// with nothing else injected). `points = None` is the canonical
/// 8-point (thread) / 9-point (process) smoke with injected failures.
pub fn smoke_sweep_sized(
    cfg: &SweepConfig,
    points: Option<usize>,
    job_ms: u64,
) -> anyhow::Result<Sweep<f64>> {
    let mut cfg = cfg.clone();
    let timeout = cfg.job_timeout.unwrap_or(Duration::from_millis(500));
    let in_worker = cfg.key_filter.is_some();
    // inside a process-mode worker the supervisor owns the (hard)
    // timeout: leave the in-thread watchdog off so the hanging point is
    // killed, not shed
    cfg.job_timeout = if in_worker { None } else { Some(timeout) };
    // long enough to trip the watchdog, short enough that the detached
    // straggler thread dies quickly after the sweep completes
    let hang = (timeout * 10)
        .max(timeout + Duration::from_millis(250))
        .min(Duration::from_secs(5));
    let canonical = points.is_none();
    // the aborting point only exists where a worker process can die for
    // it: in a worker, or in a supervisor that will fork workers
    let with_abort = canonical
        && (in_worker || (cfg.isolation == IsolationMode::Process && cfg.task.is_some()));
    let n = points.unwrap_or(if with_abort { 9 } else { 8 });
    let jobs: Vec<Job<usize>> = (0..n)
        .map(|i| Job {
            key: format!("smoke-{i}"),
            input: i,
        })
        .collect();
    let report = run_sweep(jobs, &cfg, Some(smoke_codec()), move |&i: &usize| {
        if !canonical {
            if job_ms > 0 {
                std::thread::sleep(Duration::from_millis(job_ms));
            }
            return Ok((i * i) as f64);
        }
        match i {
            3 => panic!("injected panic (smoke study)"),
            5 => {
                std::thread::sleep(hang);
                Ok(i as f64)
            }
            7 if with_abort => {
                eprintln!("smoke: aborting worker process (injected)");
                std::process::abort()
            }
            _ => Ok((i * i) as f64),
        }
    })?;
    Ok(Sweep::from_report(report))
}

/// Journal codec for the smoke sweep's numeric points.
pub fn smoke_codec() -> Codec<f64> {
    Codec::new(
        |v: &f64| Json::Num(*v),
        |j: &Json| {
            j.as_f64()
                .ok_or_else(|| anyhow::anyhow!("smoke point must be a number"))
        },
    )
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn num_codec() -> Codec<f64> {
        smoke_codec()
    }

    fn jobs_of(n: usize) -> Vec<Job<usize>> {
        (0..n)
            .map(|i| Job {
                key: format!("j{i}"),
                input: i,
            })
            .collect()
    }

    #[test]
    fn empty_sweep() {
        let r = run_sweep(
            Vec::<Job<usize>>::new(),
            &SweepConfig::default(),
            None::<Codec<f64>>,
            |&i: &usize| Ok(i as f64),
        )
        .unwrap();
        assert!(r.outcomes.is_empty());
    }

    #[test]
    fn summary_formatting() {
        let failures = vec![
            SweepFailure {
                key: "a".into(),
                error: JobError::Panic("boom".into()),
            },
            SweepFailure {
                key: "b".into(),
                error: JobError::Timeout(Duration::from_secs(1)),
            },
        ];
        let s = summary_line(6, &failures, 3);
        assert_eq!(s, "6 ok / 2 failed (1 panic, 1 timeout) / 3 resumed");
        assert_eq!(summary_line(4, &[], 0), "4 ok / 0 failed");
    }

    #[test]
    fn journal_roundtrip_and_torn_line() {
        let dir = std::env::temp_dir().join(format!("ciminus_journal_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("j.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let mut j = Journal::open_append(&path).unwrap();
            j.append("a", &Json::Num(1.0)).unwrap();
            j.append("b", &Json::Num(2.0)).unwrap();
        }
        // simulate a crash mid-append: torn trailing line
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"key\":\"c\",\"ok\":3").unwrap();
        }
        let map = Journal::load_map(&path).unwrap();
        assert_eq!(map.len(), 2);
        assert_eq!(map.get("a").unwrap().as_f64(), Some(1.0));
        assert!(!map.contains_key("c"), "torn line skipped");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_journal_is_empty() {
        let map =
            Journal::load_map(Path::new("/definitely/not/here/ciminus.jsonl")).unwrap();
        assert!(map.is_empty());
    }

    #[test]
    fn truncated_mid_record_journal_still_resumes() {
        let dir = std::env::temp_dir().join(format!("ciminus_torn_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let mut j = Journal::open_append(&path).unwrap();
            j.append("a", &Json::Num(1.0)).unwrap();
            j.append("b", &Json::Str("héllo".into())).unwrap();
        }
        // SIGKILL mid-append: truncate the file at an arbitrary byte
        // inside the final record (here: inside a multi-byte char)
        let bytes = std::fs::read(&path).unwrap();
        let cut = bytes.len() - 7;
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let map = Journal::load_map(&path).unwrap();
        assert_eq!(map.len(), 1, "intact prefix survives");
        assert_eq!(map.get("a").unwrap().as_f64(), Some(1.0));
        assert!(!map.contains_key("b"), "torn record dropped");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_files_is_last_writer_wins_and_idempotent() {
        let dir = std::env::temp_dir().join(format!("ciminus_merge_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let canon = dir.join("canon.jsonl");
        let s0 = dir.join("canon.jsonl.shard-0");
        let s1 = dir.join("canon.jsonl.shard-1");
        for p in [&canon, &s0, &s1] {
            std::fs::remove_file(p).ok();
        }
        {
            let mut j = Journal::open_append(&canon).unwrap();
            j.append("a", &Json::Num(1.0)).unwrap();
        }
        {
            let mut j = Journal::open_append(&s0).unwrap();
            j.append("a", &Json::Num(1.0)).unwrap(); // duplicate: skipped
            j.append("b", &Json::Num(2.0)).unwrap();
        }
        {
            let mut j = Journal::open_append(&s1).unwrap();
            j.append("b", &Json::Num(3.0)).unwrap(); // later shard wins
        }
        let n = Journal::merge_files(&canon, &[s0.clone(), s1.clone()]).unwrap();
        assert_eq!(n, 1, "only the collapsed winner `b = 3` is new");
        let map = Journal::load_map(&canon).unwrap();
        assert_eq!(map.get("b").unwrap().as_f64(), Some(3.0));
        assert_eq!(map.len(), 2);
        // merging the same shards again adds nothing
        assert_eq!(Journal::merge_files(&canon, &[s0, s1]).unwrap(), 0);

        // orphan recovery sweeps up *.shard-N files and deletes them
        let s2 = dir.join("canon.jsonl.shard-2");
        {
            let mut j = Journal::open_append(&s2).unwrap();
            j.append("c", &Json::Num(9.0)).unwrap();
        }
        assert_eq!(Journal::merge_orphan_shards(&canon).unwrap(), 1);
        assert!(!s2.exists(), "orphan shard journal removed after merge");
        assert_eq!(Journal::load_map(&canon).unwrap().len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn key_filter_runs_only_assigned_jobs() {
        use std::sync::atomic::AtomicUsize;
        let mut cfg = SweepConfig::with_threads(2);
        cfg.key_filter = Some(["j0".to_string(), "j2".to_string()].into_iter().collect());
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let r = run_sweep(jobs_of(4), &cfg, None::<Codec<f64>>, move |&i: &usize| {
            c.fetch_add(1, Ordering::Relaxed);
            Ok(i as f64)
        })
        .unwrap();
        assert_eq!(calls.load(Ordering::Relaxed), 2);
        assert!(r.outcomes[0].result.is_ok());
        assert!(r.outcomes[2].result.is_ok());
        for idx in [1, 3] {
            match &r.outcomes[idx].result {
                Err(e) => assert_eq!(e.kind(), "aborted"),
                Ok(_) => panic!("job {idx} should have been filtered out"),
            }
        }
    }

    #[test]
    fn crashed_error_shape() {
        let e = JobError::Crashed { signal: 9, shard: 2 };
        assert_eq!(e.kind(), "crashed");
        assert_eq!(e.to_string(), "crashed: worker for shard 2 killed by signal 9");
        let e0 = JobError::Crashed { signal: 0, shard: 1 };
        assert!(e0.to_string().contains("exited abnormally"));
        let s = summary_line(
            1,
            &[SweepFailure {
                key: "x".into(),
                error: e,
            }],
            0,
        );
        assert_eq!(s, "1 ok / 1 failed (1 crashed)");
    }

    #[test]
    fn sized_smoke_is_clean() {
        let sweep = smoke_sweep_sized(&SweepConfig::with_threads(2), Some(5), 0).unwrap();
        assert_eq!(sweep.total, 5);
        assert_eq!(sweep.points.len(), 5);
        assert!(sweep.failures.is_empty());
    }

    #[test]
    fn progress_hook_sees_every_terminal_event() {
        use std::sync::atomic::AtomicUsize;
        let ok = Arc::new(AtomicUsize::new(0));
        let failed = Arc::new(AtomicUsize::new(0));
        let (ok2, failed2) = (Arc::clone(&ok), Arc::clone(&failed));
        let mut cfg = SweepConfig::with_threads(2);
        cfg.progress = Some(ProgressHook(Arc::new(move |ev: &ProgressEvent| match ev {
            ProgressEvent::Ok { .. } => {
                ok2.fetch_add(1, Ordering::Relaxed);
            }
            ProgressEvent::Failed { .. } => {
                failed2.fetch_add(1, Ordering::Relaxed);
            }
            ProgressEvent::Started { .. } => {}
        })));
        let r = run_sweep(jobs_of(4), &cfg, Some(num_codec()), |&i: &usize| {
            if i == 1 {
                anyhow::bail!("boom");
            }
            Ok(i as f64)
        })
        .unwrap();
        assert_eq!(r.n_ok(), 3);
        assert_eq!(ok.load(Ordering::Relaxed), 3);
        assert_eq!(failed.load(Ordering::Relaxed), 1);
    }
}
