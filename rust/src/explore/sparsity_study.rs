//! Sparsity-exploitation analysis (Sec. VII-B, Fig. 8): speedup, energy
//! saving and model accuracy across the Table II sparsity patterns and
//! ratios 0.5–0.9 on the 4-macro use-case architecture.

use super::executor::{run_sweep, Codec, Job, Sweep, SweepConfig};
use crate::eval::{EvalCtx, Scenario};
use crate::hw::arch::Architecture;
use crate::hw::presets;
use crate::sim::engine::SimOptions;
use crate::sim::report::SimReport;
use crate::sparsity::flexblock::FlexBlock;
use crate::util::json::Json;
use crate::workload::graph::Network;
use std::sync::Arc;

/// One point of the Fig. 8 sweep.
#[derive(Debug, Clone)]
pub struct SparsityPoint {
    pub pattern: String,
    pub ratio: f64,
    pub speedup: f64,
    pub energy_saving: f64,
    pub utilization: f64,
    /// Filled from PJRT accuracy evaluation when artifacts are present.
    pub accuracy: Option<f64>,
}

fn point_to_json(p: &SparsityPoint) -> Json {
    let mut j = Json::obj();
    j.set("pattern", Json::Str(p.pattern.clone()))
        .set("ratio", Json::Num(p.ratio))
        .set("speedup", Json::Num(p.speedup))
        .set("energy_saving", Json::Num(p.energy_saving))
        .set("utilization", Json::Num(p.utilization))
        .set(
            "accuracy",
            match p.accuracy {
                Some(a) => Json::Num(a),
                None => Json::Null,
            },
        );
    j
}

fn point_from_json(j: &Json) -> anyhow::Result<SparsityPoint> {
    Ok(SparsityPoint {
        pattern: j.req_str("pattern")?.to_string(),
        ratio: j.req_f64("ratio")?,
        speedup: j.req_f64("speedup")?,
        energy_saving: j.req_f64("energy_saving")?,
        utilization: j.req_f64("utilization")?,
        accuracy: j.get("accuracy").and_then(Json::as_f64),
    })
}

/// Checkpoint-journal codec for [`SparsityPoint`] sweeps.
pub fn sparsity_codec() -> Codec<SparsityPoint> {
    Codec::new(point_to_json, point_from_json)
}

fn model_point_codec() -> Codec<(String, SparsityPoint)> {
    Codec::new(
        |(model, p): &(String, SparsityPoint)| {
            let mut j = point_to_json(p);
            j.set("model", Json::Str(model.clone()));
            j
        },
        |j: &Json| Ok((j.req_str("model")?.to_string(), point_from_json(j)?)),
    )
}

/// The Fig. 8 / Table II pattern set at a given overall ratio.
pub fn fig8_patterns(ratio: f64) -> Vec<FlexBlock> {
    vec![
        FlexBlock::row_wise(ratio),
        FlexBlock::row_block(16, ratio),
        FlexBlock::column_wise(ratio),
        FlexBlock::channel_wise(ratio),
        FlexBlock::column_block(16, ratio),
        FlexBlock::hybrid(2, 16, ratio),
        FlexBlock::hybrid_row_wise(2, ratio),
        FlexBlock::hybrid(4, 16, ratio),
    ]
}

/// The standard ratio axis of the use-cases.
pub const RATIOS: [f64; 5] = [0.5, 0.6, 0.7, 0.8, 0.9];

fn sparsity_point(fb: &FlexBlock, ratio: f64, rep: &SimReport, dense: &SimReport) -> SparsityPoint {
    SparsityPoint {
        pattern: fb.name.clone(),
        ratio,
        speedup: rep.speedup_vs(dense),
        energy_saving: rep.energy_saving_vs(dense),
        utilization: rep.mean_utilization,
        accuracy: None,
    }
}

/// The default-pipeline scenario these studies sweep: uniform pruning,
/// synthetic activation profiles (the `simulate_network_default`
/// numbers, now routed through the shared evaluator).
fn usecase_scenario(
    arch: &Arc<Architecture>,
    net: &Arc<Network>,
    fb: Option<&FlexBlock>,
    sim: SimOptions,
) -> Scenario {
    let mut s = Scenario::new(arch.clone(), net.clone())
        .synthetic_profiles(arch.input_bits, 0.5, 0xC1A0)
        .with_sim(sim);
    if let Some(fb) = fb {
        s = s.prune_uniform(fb);
    }
    s
}

fn dense_baseline(
    ctx: &EvalCtx,
    net: &Arc<Network>,
) -> anyhow::Result<(Arc<SimReport>, Arc<Architecture>)> {
    let dense_arch = Arc::new(presets::usecase_dense_baseline(4, (2, 2)));
    let dense = ctx
        .evaluator
        .evaluate(&usecase_scenario(&dense_arch, net, None, ctx.sim))?;
    Ok((Arc::new(dense), Arc::new(presets::usecase_arch(4, (2, 2)))))
}

/// Run the cost side of Fig. 8 under the resilient executor; failed
/// points are reported in the returned [`Sweep`] instead of aborting
/// the study. (Accuracy is attached separately by the caller when a
/// PJRT session is available.) All points share `ctx`'s evaluator, so
/// the dense baseline's artifacts and repeated patterns are served
/// from cache.
pub fn run_fig8_robust(
    net: &Network,
    ratios: &[f64],
    ctx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<Sweep<SparsityPoint>> {
    let net = Arc::new(net.clone());
    let (dense, arch) = dense_baseline(ctx, &net)?;
    let ev = ctx.evaluator.clone();
    let sim = ctx.sim;
    let mut jobs = Vec::new();
    for &r in ratios {
        for fb in fig8_patterns(r) {
            jobs.push(Job {
                key: format!("fig8:{}:{r:.3}", fb.name),
                input: (fb, r),
            });
        }
    }
    let report = run_sweep(
        jobs,
        cfg,
        Some(sparsity_codec()),
        move |(fb, r): &(FlexBlock, f64)| {
            let rep = ev.evaluate(&usecase_scenario(&arch, &net, Some(fb), sim))?;
            Ok(sparsity_point(fb, *r, &rep, &dense))
        },
    )?;
    Ok(Sweep::from_report(report))
}

/// Strict legacy entry point: any failed point fails the whole study.
pub fn run_fig8(
    net: &Network,
    ratios: &[f64],
    threads: usize,
) -> anyhow::Result<Vec<SparsityPoint>> {
    run_fig8_robust(
        net,
        ratios,
        &EvalCtx::default(),
        &SweepConfig::with_threads(threads),
    )?
    .strict()
}

/// Fig. 9(a): block-size sweep at fixed 80% sparsity. Sizes chosen to
/// align (16 along broadcast rows, 32 along accumulation columns) or
/// misalign (8, 24, 48) with the array dimensions.
pub fn fig9a_patterns() -> Vec<FlexBlock> {
    let r = 0.8;
    let mut v = Vec::new();
    for w in [8usize, 16, 24, 32, 48] {
        v.push(FlexBlock::row_block(w, r));
    }
    for h in [8usize, 16, 24, 32, 48] {
        v.push(FlexBlock::column_block(h, r));
    }
    for m in [2usize, 4, 8] {
        v.push(FlexBlock::hybrid(m, 16, r));
    }
    v
}

/// Fig. 9(a) under the resilient executor.
pub fn run_fig9a_robust(
    net: &Network,
    ctx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<Sweep<SparsityPoint>> {
    let net = Arc::new(net.clone());
    let (dense, arch) = dense_baseline(ctx, &net)?;
    let ev = ctx.evaluator.clone();
    let sim = ctx.sim;
    let jobs: Vec<Job<FlexBlock>> = fig9a_patterns()
        .into_iter()
        .map(|fb| Job {
            key: format!("fig9a:{}", fb.name),
            input: fb,
        })
        .collect();
    let report = run_sweep(jobs, cfg, Some(sparsity_codec()), move |fb: &FlexBlock| {
        let rep = ev.evaluate(&usecase_scenario(&arch, &net, Some(fb), sim))?;
        Ok(sparsity_point(fb, 0.8, &rep, &dense))
    })?;
    Ok(Sweep::from_report(report))
}

pub fn run_fig9a(net: &Network, threads: usize) -> anyhow::Result<Vec<SparsityPoint>> {
    run_fig9a_robust(
        net,
        &EvalCtx::default(),
        &SweepConfig::with_threads(threads),
    )?
    .strict()
}

/// Fig. 9(b): the cross-model comparison at 80% sparsity, under the
/// resilient executor. Returns (model, point) rows; depthwise convs and
/// FC layers keep the default workflow exclusions (the paper restricts
/// pruning to standard convs for MobileNetV2/VGG16 after observing
/// accuracy collapse).
pub fn run_fig9b_robust(
    nets: &[&Network],
    ctx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<Sweep<(String, SparsityPoint)>> {
    let arch = Arc::new(presets::usecase_arch(4, (2, 2)));
    let dense_arch = Arc::new(presets::usecase_dense_baseline(4, (2, 2)));
    let ev = ctx.evaluator.clone();
    let sim = ctx.sim;
    let mut jobs: Vec<Job<(Arc<Network>, Arc<SimReport>, FlexBlock)>> = Vec::new();
    for net in nets {
        let netc = Arc::new((*net).clone());
        let dense = Arc::new(
            ctx.evaluator
                .evaluate(&usecase_scenario(&dense_arch, &netc, None, ctx.sim))?,
        );
        for fb in [
            FlexBlock::row_block(16, 0.8),
            FlexBlock::column_block(16, 0.8),
            FlexBlock::hybrid(2, 16, 0.8),
        ] {
            jobs.push(Job {
                key: format!("fig9b:{}:{}", net.name, fb.name),
                input: (netc.clone(), dense.clone(), fb),
            });
        }
    }
    let report = run_sweep(
        jobs,
        cfg,
        Some(model_point_codec()),
        move |(net, dense, fb): &(Arc<Network>, Arc<SimReport>, FlexBlock)| {
            let rep = ev.evaluate(&usecase_scenario(&arch, net, Some(fb), sim))?;
            Ok((net.name.clone(), sparsity_point(fb, 0.8, &rep, dense)))
        },
    )?;
    Ok(Sweep::from_report(report))
}

pub fn run_fig9b(
    nets: &[&Network],
    threads: usize,
) -> anyhow::Result<Vec<(String, SparsityPoint)>> {
    run_fig9b_robust(
        nets,
        &EvalCtx::default(),
        &SweepConfig::with_threads(threads),
    )?
    .strict()
}

/// Convenience: the use-case architectures of Sec. VII-A.
pub fn usecase_archs() -> (Architecture, Architecture) {
    (
        presets::usecase_arch(4, (2, 2)),
        presets::usecase_dense_baseline(4, (2, 2)),
    )
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn fig8_sweep_small() {
        let net = zoo::resnet_mini();
        let pts = run_fig8(&net, &[0.5, 0.9], 0).unwrap();
        assert_eq!(pts.len(), 2 * fig8_patterns(0.5).len());
        for p in &pts {
            assert!(p.speedup > 0.0, "{}: {}", p.pattern, p.speedup);
            assert!(p.energy_saving > 0.0);
        }
        // coarse row-wise at 0.9 beats hybrid at 0.5 in speedup
        let rw9 = pts
            .iter()
            .find(|p| p.pattern == "Row-wise" && p.ratio == 0.9)
            .unwrap();
        let hy5 = pts
            .iter()
            .find(|p| p.pattern.starts_with("1:2+Row-block") && p.ratio == 0.5)
            .unwrap();
        assert!(rw9.speedup > hy5.speedup);
    }

    #[test]
    fn fig8_speedup_monotone_in_ratio_for_row_wise() {
        let net = zoo::resnet_mini();
        let pts = run_fig8(&net, &RATIOS, 0).unwrap();
        let mut row_wise: Vec<(f64, f64)> = pts
            .iter()
            .filter(|p| p.pattern == "Row-wise")
            .map(|p| (p.ratio, p.speedup))
            .collect();
        row_wise.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in row_wise.windows(2) {
            assert!(
                w[1].1 >= w[0].1 * 0.95,
                "speedup roughly monotone: {row_wise:?}"
            );
        }
    }

    #[test]
    fn fig9a_runs() {
        let net = zoo::resnet_mini();
        let pts = run_fig9a(&net, 0).unwrap();
        assert_eq!(pts.len(), fig9a_patterns().len());
    }

    #[test]
    fn sparsity_point_codec_roundtrips() {
        let p = SparsityPoint {
            pattern: "Row-wise".into(),
            ratio: 0.8,
            speedup: 3.25,
            energy_saving: 2.5,
            utilization: 0.75,
            accuracy: None,
        };
        let c = sparsity_codec();
        let back = c.decode(&c.encode(&p)).unwrap();
        assert_eq!(back.pattern, p.pattern);
        assert_eq!(back.speedup, p.speedup);
        assert_eq!(back.accuracy, None);
        let with_acc = SparsityPoint {
            accuracy: Some(0.91),
            ..p
        };
        let back2 = c.decode(&c.encode(&with_acc)).unwrap();
        assert_eq!(back2.accuracy, Some(0.91));
    }

    #[test]
    fn fig8_robust_reports_sweep_shape() {
        let net = zoo::resnet_mini();
        let ctx = EvalCtx::default();
        let sw = run_fig8_robust(&net, &[0.8], &ctx, &SweepConfig::default()).unwrap();
        assert_eq!(sw.total, fig8_patterns(0.8).len());
        assert!(sw.failures.is_empty(), "{}", sw.summary());
        assert_eq!(sw.points.len(), sw.total);
        assert_eq!(sw.resumed, 0);
        // the shared evaluator reused artifacts across the pattern sweep
        // (all points share one net and one profile spec)
        assert!(ctx.evaluator.stats().total_hits() > 0);
    }
}
