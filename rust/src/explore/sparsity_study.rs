//! Sparsity-exploitation analysis (Sec. VII-B, Fig. 8): speedup, energy
//! saving and model accuracy across the Table II sparsity patterns and
//! ratios 0.5–0.9 on the 4-macro use-case architecture.

use super::sweep::parallel_map;
use crate::hw::arch::Architecture;
use crate::hw::presets;
use crate::sim::engine::simulate_network_default;
use crate::sim::report::SimReport;
use crate::sparsity::flexblock::FlexBlock;
use crate::workload::graph::Network;

/// One point of the Fig. 8 sweep.
#[derive(Debug, Clone)]
pub struct SparsityPoint {
    pub pattern: String,
    pub ratio: f64,
    pub speedup: f64,
    pub energy_saving: f64,
    pub utilization: f64,
    /// Filled from PJRT accuracy evaluation when artifacts are present.
    pub accuracy: Option<f64>,
}

/// The Fig. 8 / Table II pattern set at a given overall ratio.
pub fn fig8_patterns(ratio: f64) -> Vec<FlexBlock> {
    vec![
        FlexBlock::row_wise(ratio),
        FlexBlock::row_block(16, ratio),
        FlexBlock::column_wise(ratio),
        FlexBlock::channel_wise(ratio),
        FlexBlock::column_block(16, ratio),
        FlexBlock::hybrid(2, 16, ratio),
        FlexBlock::hybrid_row_wise(2, ratio),
        FlexBlock::hybrid(4, 16, ratio),
    ]
}

/// The standard ratio axis of the use-cases.
pub const RATIOS: [f64; 5] = [0.5, 0.6, 0.7, 0.8, 0.9];

/// Run the cost side of Fig. 8 (accuracy is attached separately by the
/// caller when a PJRT session is available).
pub fn run_fig8(net: &Network, ratios: &[f64], threads: usize) -> anyhow::Result<Vec<SparsityPoint>> {
    let dense_arch = presets::usecase_dense_baseline(4, (2, 2));
    let dense = simulate_network_default(&dense_arch, net, None)?;
    let arch = presets::usecase_arch(4, (2, 2));
    let mut jobs: Vec<(FlexBlock, f64)> = Vec::new();
    for &r in ratios {
        for fb in fig8_patterns(r) {
            jobs.push((fb, r));
        }
    }
    let results = parallel_map(jobs, threads, |(fb, r)| {
        let rep = simulate_network_default(&arch, net, Some(&fb));
        (fb, r, rep)
    });
    let mut out = Vec::new();
    for (fb, ratio, rep) in results {
        let rep: SimReport = rep?;
        out.push(SparsityPoint {
            pattern: fb.name.clone(),
            ratio,
            speedup: rep.speedup_vs(&dense),
            energy_saving: rep.energy_saving_vs(&dense),
            utilization: rep.mean_utilization,
            accuracy: None,
        });
    }
    Ok(out)
}

/// Fig. 9(a): block-size sweep at fixed 80% sparsity. Sizes chosen to
/// align (16 along broadcast rows, 32 along accumulation columns) or
/// misalign (8, 24, 48) with the array dimensions.
pub fn fig9a_patterns() -> Vec<FlexBlock> {
    let r = 0.8;
    let mut v = Vec::new();
    for w in [8usize, 16, 24, 32, 48] {
        v.push(FlexBlock::row_block(w, r));
    }
    for h in [8usize, 16, 24, 32, 48] {
        v.push(FlexBlock::column_block(h, r));
    }
    for m in [2usize, 4, 8] {
        v.push(FlexBlock::hybrid(m, 16, r));
    }
    v
}

pub fn run_fig9a(net: &Network, threads: usize) -> anyhow::Result<Vec<SparsityPoint>> {
    let dense_arch = presets::usecase_dense_baseline(4, (2, 2));
    let dense = simulate_network_default(&dense_arch, net, None)?;
    let arch = presets::usecase_arch(4, (2, 2));
    let results = parallel_map(fig9a_patterns(), threads, |fb| {
        let rep = simulate_network_default(&arch, net, Some(&fb));
        (fb, rep)
    });
    let mut out = Vec::new();
    for (fb, rep) in results {
        let rep = rep?;
        out.push(SparsityPoint {
            pattern: fb.name.clone(),
            ratio: 0.8,
            speedup: rep.speedup_vs(&dense),
            energy_saving: rep.energy_saving_vs(&dense),
            utilization: rep.mean_utilization,
            accuracy: None,
        });
    }
    Ok(out)
}

/// Fig. 9(b): the cross-model comparison at 80% sparsity. Returns
/// (model, pattern, point) rows; depthwise convs and FC layers keep the
/// default workflow exclusions (the paper restricts pruning to standard
/// convs for MobileNetV2/VGG16 after observing accuracy collapse).
pub fn run_fig9b(
    nets: &[&Network],
    threads: usize,
) -> anyhow::Result<Vec<(String, SparsityPoint)>> {
    let mut out = Vec::new();
    for net in nets {
        let dense_arch = presets::usecase_dense_baseline(4, (2, 2));
        let dense = simulate_network_default(&dense_arch, net, None)?;
        let arch = presets::usecase_arch(4, (2, 2));
        let patterns = vec![
            FlexBlock::row_block(16, 0.8),
            FlexBlock::column_block(16, 0.8),
            FlexBlock::hybrid(2, 16, 0.8),
        ];
        let results = parallel_map(patterns, threads, |fb| {
            let rep = simulate_network_default(&arch, net, Some(&fb));
            (fb, rep)
        });
        for (fb, rep) in results {
            let rep = rep?;
            out.push((
                net.name.clone(),
                SparsityPoint {
                    pattern: fb.name.clone(),
                    ratio: 0.8,
                    speedup: rep.speedup_vs(&dense),
                    energy_saving: rep.energy_saving_vs(&dense),
                    utilization: rep.mean_utilization,
                    accuracy: None,
                },
            ));
        }
    }
    Ok(out)
}

/// Convenience: the use-case architectures of Sec. VII-A.
pub fn usecase_archs() -> (Architecture, Architecture) {
    (
        presets::usecase_arch(4, (2, 2)),
        presets::usecase_dense_baseline(4, (2, 2)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn fig8_sweep_small() {
        let net = zoo::resnet_mini();
        let pts = run_fig8(&net, &[0.5, 0.9], 0).unwrap();
        assert_eq!(pts.len(), 2 * fig8_patterns(0.5).len());
        for p in &pts {
            assert!(p.speedup > 0.0, "{}: {}", p.pattern, p.speedup);
            assert!(p.energy_saving > 0.0);
        }
        // coarse row-wise at 0.9 beats hybrid at 0.5 in speedup
        let rw9 = pts
            .iter()
            .find(|p| p.pattern == "Row-wise" && p.ratio == 0.9)
            .unwrap();
        let hy5 = pts
            .iter()
            .find(|p| p.pattern.starts_with("1:2+Row-block") && p.ratio == 0.5)
            .unwrap();
        assert!(rw9.speedup > hy5.speedup);
    }

    #[test]
    fn fig8_speedup_monotone_in_ratio_for_row_wise() {
        let net = zoo::resnet_mini();
        let pts = run_fig8(&net, &RATIOS, 0).unwrap();
        let mut row_wise: Vec<(f64, f64)> = pts
            .iter()
            .filter(|p| p.pattern == "Row-wise")
            .map(|p| (p.ratio, p.speedup))
            .collect();
        row_wise.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in row_wise.windows(2) {
            assert!(
                w[1].1 >= w[0].1 * 0.95,
                "speedup roughly monotone: {row_wise:?}"
            );
        }
    }

    #[test]
    fn fig9a_runs() {
        let net = zoo::resnet_mini();
        let pts = run_fig9a(&net, 0).unwrap();
        assert_eq!(pts.len(), fig9a_patterns().len());
    }
}
