//! Automated design-space search — the "efficiently navigate the
//! expansive co-design space" use the paper motivates (Sec. I), packaged
//! as a first-class feature: enumerate (pattern, ratio, organization,
//! strategy) candidates, simulate each in parallel, and return the
//! Pareto frontier over (latency, energy) with optional constraints.

use super::executor::{run_sweep, Codec, Job, Sweep, SweepConfig};
use crate::eval::{EvalCtx, Scenario};
use crate::hw::presets;
use crate::mapping::duplication::{Strategy, StrategyPolicy};
use crate::mapping::planner::MappingOptions;
use crate::sparsity::flexblock::FlexBlock;
use crate::util::json::Json;
use crate::workload::graph::Network;
use std::sync::Arc;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub pattern: String,
    pub ratio: f64,
    pub org: (usize, usize),
    pub strategy: &'static str,
    pub cycles: u64,
    pub energy_pj: f64,
    pub utilization: f64,
}

impl DesignPoint {
    /// Pareto dominance on (cycles, energy): true if `self` is at least
    /// as good on both axes and better on one. NaN energy on either
    /// side never dominates and is never dominated (all comparisons
    /// with NaN are false), so a corrupt point cannot silently evict
    /// valid points from the frontier — [`pareto_frontier`] drops
    /// non-finite points up front instead.
    pub fn dominates(&self, other: &DesignPoint) -> bool {
        if self.energy_pj.is_nan() || other.energy_pj.is_nan() {
            return false;
        }
        (self.cycles <= other.cycles && self.energy_pj <= other.energy_pj)
            && (self.cycles < other.cycles || self.energy_pj < other.energy_pj)
    }
}

/// Search constraints.
#[derive(Debug, Clone, Copy, Default)]
pub struct Constraints {
    /// Skip candidates whose overall sparsity exceeds this (an accuracy
    /// budget proxy when no trained model is attached).
    pub max_sparsity: Option<f64>,
    /// Require at least this mean array utilization.
    pub min_utilization: Option<f64>,
}

/// The candidate space of a search over `n_macros` macros: every
/// (pattern, ratio, organization, strategy) combination. An empty
/// `ratios` slice yields an empty candidate list (a search over nothing
/// finds nothing — it is not an error).
pub fn candidates(n_macros: usize, ratios: &[f64]) -> Vec<(FlexBlock, (usize, usize), Strategy)> {
    let orgs: Vec<(usize, usize)> = (1..=n_macros)
        .filter(|d| n_macros % d == 0)
        .map(|d| (d, n_macros / d))
        .collect();
    let mut out = Vec::new();
    for &r in ratios {
        for fb in [
            FlexBlock::row_wise(r),
            FlexBlock::row_block(16, r),
            FlexBlock::channel_wise(r),
            FlexBlock::hybrid(2, 16, r),
        ] {
            for &org in &orgs {
                for strat in [Strategy::Spatial, Strategy::Duplicate] {
                    out.push((fb.clone(), org, strat));
                }
            }
        }
    }
    out
}

fn point_to_json(p: &DesignPoint) -> Json {
    let mut j = Json::obj();
    j.set("pattern", Json::Str(p.pattern.clone()))
        .set("ratio", Json::Num(p.ratio))
        .set("org_rows", Json::Num(p.org.0 as f64))
        .set("org_cols", Json::Num(p.org.1 as f64))
        .set("strategy", Json::Str(p.strategy.to_string()))
        .set("cycles", Json::Num(p.cycles as f64))
        .set("energy_pj", Json::Num(p.energy_pj))
        .set("utilization", Json::Num(p.utilization));
    j
}

fn point_from_json(j: &Json) -> anyhow::Result<DesignPoint> {
    Ok(DesignPoint {
        pattern: j.req_str("pattern")?.to_string(),
        ratio: j.req_f64("ratio")?,
        org: (j.req_usize("org_rows")?, j.req_usize("org_cols")?),
        // round-trip through parse() to recover the &'static label
        strategy: Strategy::parse(j.req_str("strategy")?)?.label(),
        cycles: j.req_f64("cycles")? as u64,
        energy_pj: j.req_f64("energy_pj")?,
        utilization: j.req_f64("utilization")?,
    })
}

/// Checkpoint-journal codec for search sweeps. Constraint-filtered
/// candidates evaluate to `None` and journal as JSON `null`.
pub fn design_codec() -> Codec<Option<DesignPoint>> {
    Codec::new(
        |p: &Option<DesignPoint>| match p {
            Some(p) => point_to_json(p),
            None => Json::Null,
        },
        |j: &Json| match j {
            Json::Null => Ok(None),
            other => point_from_json(other).map(Some),
        },
    )
}

/// Evaluate the candidate space under the resilient executor. Returns
/// the raw sweep (one `Option<DesignPoint>` per candidate; `None` =
/// filtered by constraints) plus the Pareto frontier over the surviving
/// points. Failed candidates are reported in the sweep's `failures` and
/// simply do not compete for the frontier.
pub fn search_robust(
    net: &Network,
    n_macros: usize,
    ratios: &[f64],
    cons: Constraints,
    ctx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<(Sweep<Option<DesignPoint>>, Vec<DesignPoint>)> {
    let net = Arc::new(net.clone());
    let ev = ctx.evaluator.clone();
    let sim = ctx.sim;
    let jobs: Vec<Job<(FlexBlock, (usize, usize), Strategy)>> = candidates(n_macros, ratios)
        .into_iter()
        .map(|(fb, org, strat)| Job {
            key: format!(
                "search:{}:{:.3}:{}x{}:{}",
                fb.name,
                fb.overall_sparsity(),
                org.0,
                org.1,
                strat.label()
            ),
            input: (fb, org, strat),
        })
        .collect();
    let report = run_sweep(
        jobs,
        cfg,
        Some(design_codec()),
        move |(fb, org, strat): &(FlexBlock, (usize, usize), Strategy)| {
            if let Some(maxs) = cons.max_sparsity {
                if fb.overall_sparsity() > maxs + 1e-9 {
                    return Ok(None);
                }
            }
            let arch = presets::usecase_arch(n_macros, *org);
            let bits = arch.input_bits;
            let opts = MappingOptions {
                policy: StrategyPolicy::Fixed(*strat),
                ..Default::default()
            };
            let s = Scenario::new(arch, net.clone())
                .prune_uniform(fb)
                .with_mapping(opts)
                .synthetic_profiles(bits, 0.55, 0x5EA)
                .with_sim(sim);
            let rep = ev.evaluate(&s)?;
            if let Some(minu) = cons.min_utilization {
                if rep.mean_utilization < minu {
                    return Ok(None);
                }
            }
            Ok(Some(DesignPoint {
                pattern: fb.name.clone(),
                ratio: fb.overall_sparsity(),
                org: *org,
                strategy: strat.label(),
                cycles: rep.total_cycles,
                energy_pj: rep.energy.total_pj,
                utilization: rep.mean_utilization,
            }))
        },
    )?;
    let sweep = Sweep::from_report(report);
    let all: Vec<DesignPoint> = sweep.points.iter().filter_map(|p| p.clone()).collect();
    let pareto = pareto_frontier(&all);
    Ok((sweep, pareto))
}

/// Historical strict signature: evaluate the space and return
/// (all surviving points, pareto frontier). Any executor-level failure
/// aborts the search.
pub fn search(
    net: &Network,
    n_macros: usize,
    ratios: &[f64],
    cons: Constraints,
    threads: usize,
) -> anyhow::Result<(Vec<DesignPoint>, Vec<DesignPoint>)> {
    let (sweep, pareto) = search_robust(
        net,
        n_macros,
        ratios,
        cons,
        &EvalCtx::default(),
        &SweepConfig::with_threads(threads),
    )?;
    let all: Vec<DesignPoint> = sweep.strict()?.into_iter().flatten().collect();
    Ok((all, pareto))
}

/// Extract the Pareto-optimal subset. Points with non-finite energy
/// (NaN/∞ from a degenerate model) are excluded up front: they can
/// neither sit on a finite frontier nor be meaningfully compared.
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<DesignPoint> {
    let finite: Vec<&DesignPoint> = points.iter().filter(|p| p.energy_pj.is_finite()).collect();
    finite
        .iter()
        .filter(|p| !finite.iter().any(|q| q.dominates(p)))
        .map(|p| (*p).clone())
        .collect()
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn candidate_space_shape() {
        let c = candidates(4, &[0.5, 0.8]);
        // 2 ratios × 4 patterns × 3 orgs (1x4, 2x2, 4x1) × 2 strategies
        assert_eq!(c.len(), 2 * 4 * 3 * 2);
    }

    #[test]
    fn empty_ratio_list_yields_empty_space() {
        assert!(candidates(4, &[]).is_empty());
        let net = zoo::resnet_mini();
        let (all, pareto) = search(&net, 4, &[], Constraints::default(), 0).unwrap();
        assert!(all.is_empty());
        assert!(pareto.is_empty());
    }

    #[test]
    fn search_returns_nonempty_pareto() {
        let net = zoo::resnet_mini();
        let (all, pareto) = search(&net, 4, &[0.8], Constraints::default(), 0).unwrap();
        assert!(!all.is_empty());
        assert!(!pareto.is_empty());
        assert!(pareto.len() <= all.len());
        // no pareto point dominated by any other point
        for p in &pareto {
            assert!(!all.iter().any(|q| q.dominates(p)));
        }
    }

    #[test]
    fn constraints_filter() {
        let net = zoo::resnet_mini();
        let cons = Constraints {
            max_sparsity: Some(0.6),
            min_utilization: None,
        };
        let (all, _) = search(&net, 4, &[0.5, 0.9], cons, 0).unwrap();
        assert!(all.iter().all(|p| p.ratio <= 0.6 + 0.05), "sparsity cap respected");
        assert!(!all.is_empty(), "0.5 candidates survive");
    }

    #[test]
    fn dominance_logic() {
        let a = DesignPoint {
            pattern: "a".into(), ratio: 0.5, org: (2, 2), strategy: "sp",
            cycles: 100, energy_pj: 100.0, utilization: 0.5,
        };
        let mut b = a.clone();
        b.cycles = 200;
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        assert!(!a.dominates(&a.clone()));
    }

    #[test]
    fn nan_energy_never_dominates_or_poisons_the_frontier() {
        let good = DesignPoint {
            pattern: "good".into(), ratio: 0.5, org: (2, 2), strategy: "sp",
            cycles: 100, energy_pj: 100.0, utilization: 0.5,
        };
        let mut nan = good.clone();
        nan.pattern = "nan".into();
        nan.cycles = 1;
        nan.energy_pj = f64::NAN;
        assert!(!nan.dominates(&good), "NaN cannot dominate");
        assert!(!good.dominates(&nan), "NaN cannot be dominated");
        let mut inf = good.clone();
        inf.pattern = "inf".into();
        inf.energy_pj = f64::INFINITY;
        let frontier = pareto_frontier(&[good.clone(), nan, inf]);
        assert_eq!(frontier.len(), 1, "only the finite point survives");
        assert_eq!(frontier[0].pattern, "good");
    }

    #[test]
    fn design_codec_roundtrips_including_filtered() {
        let p = DesignPoint {
            pattern: "Hybrid".into(), ratio: 0.8, org: (4, 1), strategy: "duplicate",
            cycles: 5000, energy_pj: 2.5e6, utilization: 0.7,
        };
        let c = design_codec();
        let back = c.decode(&c.encode(&Some(p.clone()))).unwrap().unwrap();
        assert_eq!(back.pattern, p.pattern);
        assert_eq!(back.org, p.org);
        assert_eq!(back.strategy, "duplicate");
        let none = c.decode(&c.encode(&None)).unwrap();
        assert!(none.is_none(), "filtered candidates journal as null");
    }
}
