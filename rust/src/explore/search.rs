//! Automated design-space search — the "efficiently navigate the
//! expansive co-design space" use the paper motivates (Sec. I), packaged
//! as a first-class feature: enumerate (pattern, ratio, organization,
//! strategy) candidates, simulate each in parallel, and return the
//! Pareto frontier over (latency, energy) with optional constraints.

use super::sweep::parallel_map;
use crate::hw::presets;
use crate::mapping::duplication::{Strategy, StrategyPolicy};
use crate::mapping::planner::{plan, MappingOptions};
use crate::pruning::workflow::PruningWorkflow;
use crate::sim::engine::{simulate, SimOptions};
use crate::sim::input_sparsity::InputProfiles;
use crate::sparsity::flexblock::FlexBlock;
use crate::workload::graph::Network;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub pattern: String,
    pub ratio: f64,
    pub org: (usize, usize),
    pub strategy: &'static str,
    pub cycles: u64,
    pub energy_pj: f64,
    pub utilization: f64,
}

impl DesignPoint {
    /// Pareto dominance on (cycles, energy): true if `self` is at least
    /// as good on both axes and better on one.
    pub fn dominates(&self, other: &DesignPoint) -> bool {
        (self.cycles <= other.cycles && self.energy_pj <= other.energy_pj)
            && (self.cycles < other.cycles || self.energy_pj < other.energy_pj)
    }
}

/// Search constraints.
#[derive(Debug, Clone, Copy, Default)]
pub struct Constraints {
    /// Skip candidates whose overall sparsity exceeds this (an accuracy
    /// budget proxy when no trained model is attached).
    pub max_sparsity: Option<f64>,
    /// Require at least this mean array utilization.
    pub min_utilization: Option<f64>,
}

/// The candidate space of a search over `n_macros` macros.
pub fn candidates(n_macros: usize, ratios: &[f64]) -> Vec<(FlexBlock, (usize, usize), Strategy)> {
    let orgs: Vec<(usize, usize)> = (1..=n_macros)
        .filter(|d| n_macros % d == 0)
        .map(|d| (d, n_macros / d))
        .collect();
    let mut out = Vec::new();
    for &r in ratios {
        for fb in [
            FlexBlock::row_wise(r),
            FlexBlock::row_block(16, r),
            FlexBlock::channel_wise(r),
            FlexBlock::hybrid(2, 16, r),
        ] {
            for &org in &orgs {
                for strat in [Strategy::Spatial, Strategy::Duplicate] {
                    out.push((fb.clone(), org, strat));
                }
            }
        }
    }
    out
}

/// Evaluate the space and return (all points, pareto frontier).
pub fn search(
    net: &Network,
    n_macros: usize,
    ratios: &[f64],
    cons: Constraints,
    threads: usize,
) -> anyhow::Result<(Vec<DesignPoint>, Vec<DesignPoint>)> {
    let cands = candidates(n_macros, ratios);
    let results = parallel_map(cands, threads, |(fb, org, strat)| -> anyhow::Result<Option<DesignPoint>> {
        if let Some(maxs) = cons.max_sparsity {
            if fb.overall_sparsity() > maxs + 1e-9 {
                return Ok(None);
            }
        }
        let arch = presets::usecase_arch(n_macros, org);
        let prune = PruningWorkflow::default().run_uniform(net, &fb, None)?;
        let opts = MappingOptions {
            policy: StrategyPolicy::Fixed(strat),
            ..Default::default()
        };
        let mapping = plan(&arch, net, Some(&prune), opts)?;
        let profiles = InputProfiles::synthetic(net, arch.input_bits, 0.55, 0x5EA);
        let rep = simulate(&arch, net, &mapping, Some(&profiles), SimOptions::default())?;
        if let Some(minu) = cons.min_utilization {
            if rep.mean_utilization < minu {
                return Ok(None);
            }
        }
        Ok(Some(DesignPoint {
            pattern: fb.name.clone(),
            ratio: fb.overall_sparsity(),
            org,
            strategy: strat.label(),
            cycles: rep.total_cycles,
            energy_pj: rep.energy.total_pj,
            utilization: rep.mean_utilization,
        }))
    });
    let mut all = Vec::new();
    for r in results {
        if let Some(p) = r? {
            all.push(p);
        }
    }
    let pareto = pareto_frontier(&all);
    Ok((all, pareto))
}

/// Extract the Pareto-optimal subset.
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<DesignPoint> {
    points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn candidate_space_shape() {
        let c = candidates(4, &[0.5, 0.8]);
        // 2 ratios × 4 patterns × 3 orgs (1x4, 2x2, 4x1) × 2 strategies
        assert_eq!(c.len(), 2 * 4 * 3 * 2);
    }

    #[test]
    fn search_returns_nonempty_pareto() {
        let net = zoo::resnet_mini();
        let (all, pareto) = search(&net, 4, &[0.8], Constraints::default(), 0).unwrap();
        assert!(!all.is_empty());
        assert!(!pareto.is_empty());
        assert!(pareto.len() <= all.len());
        // no pareto point dominated by any other point
        for p in &pareto {
            assert!(!all.iter().any(|q| q.dominates(p)));
        }
    }

    #[test]
    fn constraints_filter() {
        let net = zoo::resnet_mini();
        let cons = Constraints {
            max_sparsity: Some(0.6),
            min_utilization: None,
        };
        let (all, _) = search(&net, 4, &[0.5, 0.9], cons, 0).unwrap();
        assert!(all.iter().all(|p| p.ratio <= 0.6 + 0.05), "sparsity cap respected");
        assert!(!all.is_empty(), "0.5 candidates survive");
    }

    #[test]
    fn dominance_logic() {
        let a = DesignPoint {
            pattern: "a".into(), ratio: 0.5, org: (2, 2), strategy: "sp",
            cycles: 100, energy_pj: 100.0, utilization: 0.5,
        };
        let mut b = a.clone();
        b.cycles = 200;
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        assert!(!a.dominates(&a.clone()));
    }
}
