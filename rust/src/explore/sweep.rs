//! Parallel sweep executor: the L3 coordinator's work-distribution core.
//! Design-space exploration runs hundreds of independent (architecture,
//! workload, sparsity, mapping) simulations; this fans them out over a
//! std-thread pool (no rayon offline) with deterministic result order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` in parallel, preserving input order in the
/// output. Uses up to `threads` workers (0 = available parallelism).
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = if threads == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        threads
    }
    .min(n);
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }

    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i].lock().unwrap().take().expect("item taken once");
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("all results filled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(items, 8, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let out = parallel_map(vec![1, 2, 3], 1, |i| i + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(vec![5], 64, |i| i * i);
        assert_eq!(out, vec![25]);
    }

    #[test]
    fn actually_uses_multiple_threads() {
        use std::collections::BTreeSet;
        let ids = parallel_map((0..64).collect::<Vec<_>>(), 4, |_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            format!("{:?}", std::thread::current().id())
        });
        let distinct: BTreeSet<_> = ids.into_iter().collect();
        assert!(distinct.len() > 1, "expected multiple worker threads");
    }
}
