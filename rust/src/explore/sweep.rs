//! Ordered parallel fan-out: the minimal work-distribution primitive
//! underneath `explore::executor`. Design-space exploration runs
//! hundreds of independent (architecture, workload, sparsity, mapping)
//! simulations; this fans them out over a std-thread pool (no rayon
//! offline) with deterministic result order.
//!
//! Prefer [`super::executor::run_sweep`] for study-scale sweeps — it
//! adds timeouts, retries, checkpointing and partial results. The
//! functions here remain for small, trusted, infallible maps.

use super::executor::{panic_message, JobError};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // never let one worker's panic poison the sweep: take the guard
    // even from a poisoned mutex (slot state stays consistent because
    // jobs run outside the critical sections)
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn catch<R>(f: impl FnOnce() -> R) -> Result<R, JobError> {
    panic::catch_unwind(AssertUnwindSafe(f))
        .map_err(|payload| JobError::Panic(panic_message(payload.as_ref())))
}

/// Map `f` over `items` in parallel with per-job panic isolation,
/// preserving input order in the output. A panicking job yields
/// `Err(JobError::Panic)` for its slot; every other job still runs to
/// completion and its result survives. Uses up to `threads` workers
/// (0 = available parallelism).
pub fn try_parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<Result<R, JobError>>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    super::executor::install_quiet_panic_hook();
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = if threads == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        threads
    }
    .min(n);
    if threads <= 1 {
        return items.into_iter().map(|t| catch(|| f(t))).collect();
    }

    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<Result<R, JobError>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for w in 0..threads {
            std::thread::Builder::new()
                .name(format!("ciminus-job-map-{w}"))
                .spawn_scoped(scope, || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = lock(&work[i]).take().expect("item taken once");
                    let r = catch(|| f(item));
                    *lock(&results[i]) = Some(r);
                })
                .expect("spawn sweep worker");
        }
    });
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(PoisonError::into_inner)
                .expect("all results filled")
        })
        .collect()
}

/// Infallible variant with the historical signature. All jobs run to
/// completion even if some panic; if any did, the first captured panic
/// is re-raised (in the caller's thread) after the sweep finishes, so a
/// single bad item can no longer poison mutexes or abort sibling jobs
/// mid-flight.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let results = try_parallel_map(items, threads, f);
    let mut out = Vec::with_capacity(results.len());
    let mut first_panic: Option<String> = None;
    let mut n_panics = 0usize;
    for r in results {
        match r {
            Ok(v) => out.push(v),
            Err(e) => {
                n_panics += 1;
                if first_panic.is_none() {
                    first_panic = Some(e.to_string());
                }
            }
        }
    }
    if let Some(msg) = first_panic {
        panic!("parallel_map: {n_panics} job(s) panicked; first: {msg}");
    }
    out
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(items, 8, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let out = parallel_map(vec![1, 2, 3], 1, |i| i + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(vec![5], 64, |i| i * i);
        assert_eq!(out, vec![25]);
    }

    #[test]
    fn actually_uses_multiple_threads() {
        use std::collections::BTreeSet;
        let ids = parallel_map((0..64).collect::<Vec<_>>(), 4, |_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            format!("{:?}", std::thread::current().id())
        });
        let distinct: BTreeSet<_> = ids.into_iter().collect();
        assert!(distinct.len() > 1, "expected multiple worker threads");
    }

    /// Regression for the poisoned-mutex abort path: a panicking job
    /// must not take down its siblings' results.
    #[test]
    fn panicking_job_does_not_poison_siblings() {
        let items: Vec<usize> = (0..32).collect();
        let out = try_parallel_map(items, 4, |i| {
            if i == 7 {
                panic!("injected failure at {i}");
            }
            i * 10
        });
        assert_eq!(out.len(), 32);
        for (i, r) in out.iter().enumerate() {
            if i == 7 {
                let e = r.as_ref().unwrap_err();
                assert_eq!(e.kind(), "panic");
                assert!(e.to_string().contains("injected failure"), "{e}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 10, "sibling {i} survived");
            }
        }
    }

    #[test]
    fn panicking_job_isolated_on_single_thread_path() {
        let out = try_parallel_map(vec![0usize, 1, 2], 1, |i| {
            if i == 1 {
                panic!("solo");
            }
            i
        });
        assert!(out[0].is_ok() && out[2].is_ok());
        assert!(out[1].is_err());
    }

    #[test]
    fn parallel_map_repanics_after_completing_siblings() {
        let completed = std::sync::atomic::AtomicUsize::new(0);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_map((0..16).collect::<Vec<usize>>(), 4, |i| {
                if i == 3 {
                    panic!("boom");
                }
                completed.fetch_add(1, Ordering::Relaxed);
                i
            })
        }));
        assert!(caught.is_err(), "panic propagates to caller");
        let msg = panic_message(caught.unwrap_err().as_ref());
        assert!(msg.contains("1 job(s) panicked"), "{msg}");
        assert_eq!(
            completed.load(Ordering::Relaxed),
            15,
            "all sibling jobs ran to completion before the re-panic"
        );
    }
}
