//! Fault-resilience exploration: sweep fault density × sparsity pattern
//! on a preset architecture and report the graceful-degradation curve —
//! latency/energy overhead, surviving capacity and extra rounds vs. the
//! fault-free chip. The scenario axis no ideal-hardware framework
//! covers: how much performance a mapped sparse workload loses as the
//! silicon degrades, and at what fault density the chip stops being
//! usable at all.

use super::executor::{run_sweep, Codec, Job, Sweep, SweepConfig};
use crate::eval::{EvalCtx, Evaluator, Scenario};
use crate::hw::arch::Architecture;
use crate::hw::faults::{FaultModel, FaultSpatial};
use crate::sim::engine::SimOptions;
use crate::sim::report::SimReport;
use crate::sparsity::flexblock::FlexBlock;
use crate::util::json::Json;
use crate::workload::graph::Network;
use std::sync::Arc;

/// Default fault-rate axis for resilience curves (0 anchors the
/// fault-free baseline point).
pub const DEFAULT_RATES: [f64; 6] = [0.0, 0.001, 0.005, 0.02, 0.05, 0.1];

/// One point of a resilience curve.
#[derive(Debug, Clone)]
pub struct ResiliencePoint {
    pub arch: String,
    pub pattern: String,
    pub spatial: String,
    pub fault_rate: f64,
    pub usable_macros: usize,
    pub total_macros: usize,
    /// Fraction of weight capacity lost to faults.
    pub capacity_loss: f64,
    /// Extra temporal rounds forced by the degradation.
    pub extra_rounds: u64,
    pub cycles: u64,
    pub energy_pj: f64,
    /// Latency relative to the fault-free chip (1.0 at rate 0).
    pub latency_overhead: f64,
    /// Energy relative to the fault-free chip (1.0 at rate 0).
    pub energy_overhead: f64,
    /// `false` when the chip was unusable at this fault density (the
    /// cliff edge of the curve; overheads are meaningless there).
    pub usable: bool,
}

fn point_to_json(p: &ResiliencePoint) -> Json {
    let mut j = Json::obj();
    j.set("arch", Json::Str(p.arch.clone()))
        .set("pattern", Json::Str(p.pattern.clone()))
        .set("spatial", Json::Str(p.spatial.clone()))
        .set("fault_rate", Json::Num(p.fault_rate))
        .set("usable_macros", Json::Num(p.usable_macros as f64))
        .set("total_macros", Json::Num(p.total_macros as f64))
        .set("capacity_loss", Json::Num(p.capacity_loss))
        .set("extra_rounds", Json::Num(p.extra_rounds as f64))
        .set("cycles", Json::Num(p.cycles as f64))
        .set("energy_pj", Json::Num(p.energy_pj))
        .set(
            "latency_overhead",
            if p.usable {
                Json::Num(p.latency_overhead)
            } else {
                Json::Null
            },
        )
        .set(
            "energy_overhead",
            if p.usable {
                Json::Num(p.energy_overhead)
            } else {
                Json::Null
            },
        )
        .set("usable", Json::Bool(p.usable));
    j
}

fn point_from_json(j: &Json) -> anyhow::Result<ResiliencePoint> {
    let usable = j
        .get("usable")
        .and_then(Json::as_bool)
        .ok_or_else(|| anyhow::anyhow!("resilience point missing 'usable'"))?;
    // cliff points serialize their (infinite) overheads as null; restore
    // the in-memory INFINITY convention on decode
    let overhead = |key: &str| -> anyhow::Result<f64> {
        if usable {
            j.req_f64(key)
        } else {
            Ok(f64::INFINITY)
        }
    };
    Ok(ResiliencePoint {
        arch: j.req_str("arch")?.to_string(),
        pattern: j.req_str("pattern")?.to_string(),
        spatial: j.req_str("spatial")?.to_string(),
        fault_rate: j.req_f64("fault_rate")?,
        usable_macros: j.req_usize("usable_macros")?,
        total_macros: j.req_usize("total_macros")?,
        capacity_loss: j.req_f64("capacity_loss")?,
        extra_rounds: j.req_f64("extra_rounds")? as u64,
        cycles: j.req_f64("cycles")? as u64,
        energy_pj: j.req_f64("energy_pj")?,
        latency_overhead: overhead("latency_overhead")?,
        energy_overhead: overhead("energy_overhead")?,
        usable,
    })
}

/// Checkpoint-journal codec for [`ResiliencePoint`] sweeps.
pub fn resilience_codec() -> Codec<ResiliencePoint> {
    Codec::new(point_to_json, point_from_json)
}

/// Everything a single resilience point needs besides its fault rate;
/// shared across workers via one `Arc`. The prune plan and activation
/// profiles are not materialized here — every point's scenario carries
/// the same prune/profile specs, so the shared evaluator computes each
/// artifact once and serves the rest from cache.
struct FaultCtx {
    ev: Arc<Evaluator>,
    sim: SimOptions,
    arch: Architecture,
    net: Arc<Network>,
    fb: Option<FlexBlock>,
    baseline: SimReport,
    pattern: String,
    spatial: FaultSpatial,
    seed: u64,
}

fn base_scenario(
    net: &Arc<Network>,
    fb: Option<&FlexBlock>,
    arch: Architecture,
    sim: SimOptions,
) -> Scenario {
    let bits = arch.input_bits;
    let mut s = Scenario::new(arch, net.clone())
        .synthetic_profiles(bits, 0.55, 0xFA17)
        .with_sim(sim);
    if let Some(fb) = fb {
        s = s.prune_uniform(fb);
    }
    s
}

fn fault_scenario(ctx: &FaultCtx, arch: Architecture) -> Scenario {
    base_scenario(&ctx.net, ctx.fb.as_ref(), arch, ctx.sim)
}

fn resilience_point(ctx: &FaultCtx, rate: f64) -> ResiliencePoint {
    let mut a = ctx.arch.clone();
    a.faults = FaultModel::scaled(rate, ctx.spatial, ctx.seed);
    match ctx.ev.evaluate(&fault_scenario(ctx, a)) {
        Ok(rep) => {
            let (usable_macros, capacity_loss, extra_rounds) = match &rep.faults {
                Some(f) => (f.usable_macros, f.capacity_loss, f.extra_rounds()),
                None => (ctx.arch.org.n_macros(), 0.0, 0),
            };
            ResiliencePoint {
                arch: ctx.arch.name.clone(),
                pattern: ctx.pattern.clone(),
                spatial: ctx.spatial.label().into(),
                fault_rate: rate,
                usable_macros,
                total_macros: ctx.arch.org.n_macros(),
                capacity_loss,
                extra_rounds,
                cycles: rep.total_cycles,
                energy_pj: rep.energy.total_pj,
                latency_overhead: rep.total_cycles as f64
                    / ctx.baseline.total_cycles.max(1) as f64,
                energy_overhead: rep.energy.total_pj / ctx.baseline.energy.total_pj.max(1e-12),
                usable: true,
            }
        }
        // the cliff edge: chip unusable at this density. Deliberately a
        // *point*, not a sweep failure — the cliff is the result.
        Err(_) => ResiliencePoint {
            arch: ctx.arch.name.clone(),
            pattern: ctx.pattern.clone(),
            spatial: ctx.spatial.label().into(),
            fault_rate: rate,
            usable_macros: 0,
            total_macros: ctx.arch.org.n_macros(),
            capacity_loss: 1.0,
            extra_rounds: 0,
            cycles: 0,
            energy_pj: 0.0,
            latency_overhead: f64::INFINITY,
            energy_overhead: f64::INFINITY,
            usable: false,
        },
    }
}

/// Resilience curve under the resilient executor. The same pruning
/// masks and activation profiles are reused across all points (served
/// from the shared evaluator's cache), so differences are purely
/// fault-induced. Rates at which the chip is unusable yield points with
/// `usable: false` instead of failing the sweep; a panic or hang in the
/// simulator itself surfaces as a [`super::executor::SweepFailure`].
pub fn run_resilience_robust(
    arch: &Architecture,
    net: &Network,
    fb: Option<&FlexBlock>,
    rates: &[f64],
    spatial: FaultSpatial,
    seed: u64,
    ectx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<Sweep<ResiliencePoint>> {
    let net = Arc::new(net.clone());
    let pattern = fb.map(|f| f.name.clone()).unwrap_or_else(|| "Dense".into());
    let mut clean = arch.clone();
    clean.faults = FaultModel::none();
    let baseline = ectx
        .evaluator
        .evaluate(&base_scenario(&net, fb, clean, ectx.sim))?;
    let ctx = Arc::new(FaultCtx {
        ev: ectx.evaluator.clone(),
        sim: ectx.sim,
        arch: arch.clone(),
        net,
        fb: fb.cloned(),
        baseline,
        pattern,
        spatial,
        seed,
    });
    let jobs: Vec<Job<f64>> = rates
        .iter()
        .map(|&r| Job {
            key: format!("faults:{}:{}:{r:.6}", arch.name, spatial.label()),
            input: r,
        })
        .collect();
    let report = run_sweep(jobs, cfg, Some(resilience_codec()), move |&rate: &f64| {
        Ok(resilience_point(&ctx, rate))
    })?;
    Ok(Sweep::from_report(report))
}

/// Historical strict signature: any executor-level failure aborts.
pub fn run_resilience(
    arch: &Architecture,
    net: &Network,
    fb: Option<&FlexBlock>,
    rates: &[f64],
    spatial: FaultSpatial,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Vec<ResiliencePoint>> {
    run_resilience_robust(
        arch,
        net,
        fb,
        rates,
        spatial,
        seed,
        &EvalCtx::default(),
        &SweepConfig::with_threads(threads),
    )?
    .strict()
}

/// Serialize a resilience curve as a JSON array (the `faults --json`
/// output format).
pub fn points_to_json(points: &[ResiliencePoint]) -> Json {
    Json::Arr(points.iter().map(point_to_json).collect())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::hw::presets;
    use crate::workload::zoo;

    #[test]
    fn curve_is_monotone_and_anchored() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let pts = run_resilience(
            &arch,
            &net,
            None,
            &[0.0, 0.02, 0.1],
            FaultSpatial::Uniform,
            0xBEEF,
            0,
        )
        .unwrap();
        assert_eq!(pts.len(), 3);
        assert!((pts[0].latency_overhead - 1.0).abs() < 1e-12, "rate 0 = baseline");
        assert!((pts[0].energy_overhead - 1.0).abs() < 1e-12);
        for w in pts.windows(2) {
            assert!(
                w[1].latency_overhead >= w[0].latency_overhead,
                "latency overhead monotone: {} -> {}",
                w[0].latency_overhead,
                w[1].latency_overhead
            );
            assert!(w[1].capacity_loss >= w[0].capacity_loss);
        }
        assert!(pts[2].latency_overhead > 1.0, "10% faults cost something");
    }

    #[test]
    fn unusable_rates_survive_as_cliff_points() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        // rate 1.0 with Row spatial quarantines every row of every macro
        // (next_f64() < 1.0 always), so the chip is provably unusable.
        let pts = run_resilience(&arch, &net, None, &[0.0, 1.0], FaultSpatial::Row, 1, 0).unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts[0].usable);
        assert!(!pts[1].usable, "fully-faulted chip is a cliff point");
        assert!(!pts[1].latency_overhead.is_finite());
        assert_eq!(pts[1].usable_macros, 0);
    }

    #[test]
    fn json_serialization_roundtrips() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let pts =
            run_resilience(&arch, &net, None, &[0.0], FaultSpatial::Cluster, 2, 0).unwrap();
        let j = points_to_json(&pts);
        let text = j.pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 1);
        assert_eq!(
            back.as_arr().unwrap()[0].get("arch").unwrap().as_str(),
            Some(arch.name.as_str())
        );
    }

    #[test]
    fn resilience_codec_roundtrips_cliff_points() {
        let p = ResiliencePoint {
            arch: "usecase-4".into(),
            pattern: "Dense".into(),
            spatial: "row".into(),
            fault_rate: 0.5,
            usable_macros: 0,
            total_macros: 4,
            capacity_loss: 1.0,
            extra_rounds: 0,
            cycles: 0,
            energy_pj: 0.0,
            latency_overhead: f64::INFINITY,
            energy_overhead: f64::INFINITY,
            usable: false,
        };
        let c = resilience_codec();
        let back = c.decode(&c.encode(&p)).unwrap();
        assert!(!back.usable);
        assert!(back.latency_overhead.is_infinite(), "null decodes to INFINITY");
        assert_eq!(back.total_macros, 4);
    }
}
