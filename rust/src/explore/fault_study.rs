//! Fault-resilience exploration: sweep fault density × sparsity pattern
//! on a preset architecture and report the graceful-degradation curve —
//! latency/energy overhead, surviving capacity and extra rounds vs. the
//! fault-free chip. The scenario axis no ideal-hardware framework
//! covers: how much performance a mapped sparse workload loses as the
//! silicon degrades, and at what fault density the chip stops being
//! usable at all.

use super::sweep::parallel_map;
use crate::hw::arch::Architecture;
use crate::hw::faults::{FaultModel, FaultSpatial};
use crate::mapping::planner::{plan, MappingOptions};
use crate::pruning::workflow::{PrunePlan, PruningWorkflow};
use crate::sim::engine::{simulate, SimOptions};
use crate::sim::input_sparsity::InputProfiles;
use crate::sim::report::SimReport;
use crate::sparsity::flexblock::FlexBlock;
use crate::util::json::Json;
use crate::workload::graph::Network;

/// Default fault-rate axis for resilience curves (0 anchors the
/// fault-free baseline point).
pub const DEFAULT_RATES: [f64; 6] = [0.0, 0.001, 0.005, 0.02, 0.05, 0.1];

/// One point of a resilience curve.
#[derive(Debug, Clone)]
pub struct ResiliencePoint {
    pub arch: String,
    pub pattern: String,
    pub spatial: String,
    pub fault_rate: f64,
    pub usable_macros: usize,
    pub total_macros: usize,
    /// Fraction of weight capacity lost to faults.
    pub capacity_loss: f64,
    /// Extra temporal rounds forced by the degradation.
    pub extra_rounds: u64,
    pub cycles: u64,
    pub energy_pj: f64,
    /// Latency relative to the fault-free chip (1.0 at rate 0).
    pub latency_overhead: f64,
    /// Energy relative to the fault-free chip (1.0 at rate 0).
    pub energy_overhead: f64,
    /// `false` when the chip was unusable at this fault density (the
    /// cliff edge of the curve; overheads are meaningless there).
    pub usable: bool,
}

fn simulate_arch(
    arch: &Architecture,
    net: &Network,
    prune: Option<&PrunePlan>,
    profiles: &InputProfiles,
) -> anyhow::Result<SimReport> {
    let mapping = plan(arch, net, prune, MappingOptions::default())?;
    simulate(arch, net, &mapping, Some(profiles), SimOptions::default())
}

/// Sweep `rates` on `arch` (one spatial distribution, one sparsity
/// pattern) and return the resilience curve. The same pruning masks and
/// activation profiles are reused across all points, so differences are
/// purely fault-induced. Rates at which the chip is unusable yield
/// points with `usable: false` instead of failing the whole sweep.
pub fn run_resilience(
    arch: &Architecture,
    net: &Network,
    fb: Option<&FlexBlock>,
    rates: &[f64],
    spatial: FaultSpatial,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Vec<ResiliencePoint>> {
    let prune = match fb {
        Some(fb) if !fb.is_dense() => {
            Some(PruningWorkflow::default().run_uniform(net, fb, None)?)
        }
        _ => None,
    };
    let profiles = InputProfiles::synthetic(net, arch.input_bits, 0.55, 0xFA17);
    let mut clean = arch.clone();
    clean.faults = FaultModel::none();
    let baseline = simulate_arch(&clean, net, prune.as_ref(), &profiles)?;
    let pattern = fb.map(|f| f.name.clone()).unwrap_or_else(|| "Dense".into());

    let results = parallel_map(rates.to_vec(), threads, |rate| {
        let mut a = arch.clone();
        a.faults = FaultModel::scaled(rate, spatial, seed);
        let rep = simulate_arch(&a, net, prune.as_ref(), &profiles);
        (rate, rep)
    });

    let mut out = Vec::with_capacity(results.len());
    for (rate, rep) in results {
        let point = match rep {
            Ok(rep) => {
                let (usable_macros, capacity_loss, extra_rounds) = match &rep.faults {
                    Some(f) => (f.usable_macros, f.capacity_loss, f.extra_rounds()),
                    None => (arch.org.n_macros(), 0.0, 0),
                };
                ResiliencePoint {
                    arch: arch.name.clone(),
                    pattern: pattern.clone(),
                    spatial: spatial.label().into(),
                    fault_rate: rate,
                    usable_macros,
                    total_macros: arch.org.n_macros(),
                    capacity_loss,
                    extra_rounds,
                    cycles: rep.total_cycles,
                    energy_pj: rep.energy.total_pj,
                    latency_overhead: rep.total_cycles as f64
                        / baseline.total_cycles.max(1) as f64,
                    energy_overhead: rep.energy.total_pj / baseline.energy.total_pj.max(1e-12),
                    usable: true,
                }
            }
            // the cliff edge: chip unusable at this density
            Err(_) => ResiliencePoint {
                arch: arch.name.clone(),
                pattern: pattern.clone(),
                spatial: spatial.label().into(),
                fault_rate: rate,
                usable_macros: 0,
                total_macros: arch.org.n_macros(),
                capacity_loss: 1.0,
                extra_rounds: 0,
                cycles: 0,
                energy_pj: 0.0,
                latency_overhead: f64::INFINITY,
                energy_overhead: f64::INFINITY,
                usable: false,
            },
        };
        out.push(point);
    }
    Ok(out)
}

/// Serialize a resilience curve as a JSON array (the `faults --json`
/// output format).
pub fn points_to_json(points: &[ResiliencePoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                let mut j = Json::obj();
                j.set("arch", Json::Str(p.arch.clone()))
                    .set("pattern", Json::Str(p.pattern.clone()))
                    .set("spatial", Json::Str(p.spatial.clone()))
                    .set("fault_rate", Json::Num(p.fault_rate))
                    .set("usable_macros", Json::Num(p.usable_macros as f64))
                    .set("total_macros", Json::Num(p.total_macros as f64))
                    .set("capacity_loss", Json::Num(p.capacity_loss))
                    .set("extra_rounds", Json::Num(p.extra_rounds as f64))
                    .set("cycles", Json::Num(p.cycles as f64))
                    .set("energy_pj", Json::Num(p.energy_pj))
                    .set(
                        "latency_overhead",
                        if p.usable {
                            Json::Num(p.latency_overhead)
                        } else {
                            Json::Null
                        },
                    )
                    .set(
                        "energy_overhead",
                        if p.usable {
                            Json::Num(p.energy_overhead)
                        } else {
                            Json::Null
                        },
                    )
                    .set("usable", Json::Bool(p.usable));
                j
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::workload::zoo;

    #[test]
    fn curve_is_monotone_and_anchored() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let pts = run_resilience(
            &arch,
            &net,
            None,
            &[0.0, 0.02, 0.1],
            FaultSpatial::Uniform,
            0xBEEF,
            0,
        )
        .unwrap();
        assert_eq!(pts.len(), 3);
        assert!((pts[0].latency_overhead - 1.0).abs() < 1e-12, "rate 0 = baseline");
        assert!((pts[0].energy_overhead - 1.0).abs() < 1e-12);
        for w in pts.windows(2) {
            assert!(
                w[1].latency_overhead >= w[0].latency_overhead,
                "latency overhead monotone: {} -> {}",
                w[0].latency_overhead,
                w[1].latency_overhead
            );
            assert!(w[1].capacity_loss >= w[0].capacity_loss);
        }
        assert!(pts[2].latency_overhead > 1.0, "10% faults cost something");
    }

    #[test]
    fn unusable_rates_survive_as_cliff_points() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        // rate 1.0 with Row spatial quarantines every row of every macro
        // (next_f64() < 1.0 always), so the chip is provably unusable.
        let pts = run_resilience(&arch, &net, None, &[0.0, 1.0], FaultSpatial::Row, 1, 0).unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts[0].usable);
        assert!(!pts[1].usable, "fully-faulted chip is a cliff point");
        assert!(!pts[1].latency_overhead.is_finite());
        assert_eq!(pts[1].usable_macros, 0);
    }

    #[test]
    fn json_serialization_roundtrips() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let pts =
            run_resilience(&arch, &net, None, &[0.0], FaultSpatial::Cluster, 2, 0).unwrap();
        let j = points_to_json(&pts);
        let text = j.pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 1);
        assert_eq!(
            back.as_arr().unwrap()[0].get("arch").unwrap().as_str(),
            Some(arch.name.as_str())
        );
    }
}
