//! Mapping-strategy exploration (Sec. VII-C, Fig. 11/12): spatial
//! mapping vs. weight duplication across 16-macro organizations, and the
//! effect of ragged-matrix rearrangement.

use super::sweep::parallel_map;
use crate::hw::presets;
use crate::mapping::duplication::{Strategy, StrategyPolicy};
use crate::mapping::planner::{plan, MappingOptions};
use crate::pruning::workflow::PruningWorkflow;
use crate::sim::engine::{simulate, SimOptions};
use crate::sim::input_sparsity::InputProfiles;
use crate::sim::report::SimReport;
use crate::sparsity::flexblock::FlexBlock;
use crate::workload::graph::Network;

/// One Fig. 11 cell: (model, organization, strategy) → cost triple.
#[derive(Debug, Clone)]
pub struct MappingPoint {
    pub model: String,
    pub org: String,
    pub strategy: String,
    pub energy_pj: f64,
    pub latency_cycles: u64,
    pub utilization: f64,
}

/// The Fig. 11 organizations of the 16-macro architecture.
pub const ORGS: [(usize, usize); 3] = [(8, 2), (4, 4), (2, 8)];

fn run_one(
    net: &Network,
    org: (usize, usize),
    strategy: Strategy,
    fb: &FlexBlock,
    rearrange: bool,
) -> anyhow::Result<SimReport> {
    let arch = presets::usecase_arch(16, org);
    let prune = PruningWorkflow::default().run_uniform(net, fb, None)?;
    let opts = MappingOptions {
        policy: StrategyPolicy::Fixed(strategy),
        rearrange,
        ..Default::default()
    };
    let mapping = plan(&arch, net, Some(&prune), opts)?;
    let profiles = InputProfiles::synthetic(net, arch.input_bits, 0.6, 0xF16_11);
    simulate(&arch, net, &mapping, Some(&profiles), SimOptions::default())
}

/// Fig. 11: sweep organizations × strategies for the given networks at
/// the hybrid 80% pattern.
pub fn run_fig11(nets: &[&Network], threads: usize) -> anyhow::Result<Vec<MappingPoint>> {
    let fb = FlexBlock::hybrid(2, 16, 0.8);
    let mut jobs = Vec::new();
    for net in nets {
        for org in ORGS {
            for strat in [Strategy::Spatial, Strategy::Duplicate] {
                jobs.push((*net, org, strat));
            }
        }
    }
    let results = parallel_map(jobs, threads, |(net, org, strat)| {
        run_one(net, org, strat, &fb, false).map(|rep| MappingPoint {
            model: net.name.clone(),
            org: format!("{}x{}", org.0, org.1),
            strategy: strat.label().to_string(),
            energy_pj: rep.energy.total_pj,
            latency_cycles: rep.total_cycles,
            utilization: rep.mean_utilization,
        })
    });
    results.into_iter().collect()
}

/// One Fig. 12 row: rearrangement off/on for a strategy.
#[derive(Debug, Clone)]
pub struct RearrangePoint {
    pub strategy: String,
    pub rearranged: bool,
    pub energy_pj: f64,
    pub latency_cycles: u64,
    pub utilization: f64,
    pub report: SimReport,
}

/// Fig. 12: hybrid Intra(2,1)+Full(2,16) on the 4×4 organization, with
/// and without weight-data rearrangement, for both strategies.
pub fn run_fig12(net: &Network, threads: usize) -> anyhow::Result<Vec<RearrangePoint>> {
    let fb = FlexBlock::hybrid(2, 16, 0.8);
    let mut jobs = Vec::new();
    for strat in [Strategy::Spatial, Strategy::Duplicate] {
        for rearr in [false, true] {
            jobs.push((strat, rearr));
        }
    }
    let results = parallel_map(jobs, threads, |(strat, rearr)| {
        run_one(net, (4, 4), strat, &fb, rearr).map(|rep| RearrangePoint {
            strategy: strat.label().to_string(),
            rearranged: rearr,
            energy_pj: rep.energy.total_pj,
            latency_cycles: rep.total_cycles,
            utilization: rep.mean_utilization,
            report: rep,
        })
    });
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn fig11_grid_complete() {
        let net = zoo::resnet_mini();
        let pts = run_fig11(&[&net], 0).unwrap();
        assert_eq!(pts.len(), ORGS.len() * 2);
        for p in &pts {
            assert!(p.energy_pj > 0.0);
            assert!(p.latency_cycles > 0);
        }
    }

    #[test]
    fn duplication_raises_utilization_for_conv_models() {
        let net = zoo::resnet_mini();
        let pts = run_fig11(&[&net], 0).unwrap();
        for org in ORGS {
            let label = format!("{}x{}", org.0, org.1);
            let sp = pts
                .iter()
                .find(|p| p.org == label && p.strategy == "spatial")
                .unwrap();
            let dp = pts
                .iter()
                .find(|p| p.org == label && p.strategy == "duplicate")
                .unwrap();
            assert!(
                dp.utilization > sp.utilization,
                "{label}: dup {} <= sp {}",
                dp.utilization,
                sp.utilization
            );
        }
    }

    #[test]
    fn fig12_rearrangement_improves_utilization() {
        let net = zoo::resnet_mini();
        let pts = run_fig12(&net, 0).unwrap();
        for strat in ["spatial", "duplicate"] {
            let base = pts
                .iter()
                .find(|p| p.strategy == strat && !p.rearranged)
                .unwrap();
            let rearr = pts
                .iter()
                .find(|p| p.strategy == strat && p.rearranged)
                .unwrap();
            assert!(
                rearr.utilization >= base.utilization - 1e-9,
                "{strat}: {} < {}",
                rearr.utilization,
                base.utilization
            );
        }
    }
}
