//! Mapping-strategy exploration (Sec. VII-C, Fig. 11/12): spatial
//! mapping vs. weight duplication across 16-macro organizations, and the
//! effect of ragged-matrix rearrangement.

use super::executor::{run_sweep, Codec, Job, Sweep, SweepConfig};
use crate::eval::{EvalCtx, Evaluator, Scenario};
use crate::hw::presets;
use crate::hw::units::UnitKind;
use crate::mapping::duplication::{Strategy, StrategyPolicy};
use crate::mapping::planner::MappingOptions;
use crate::sim::engine::SimOptions;
use crate::sim::report::SimReport;
use crate::sparsity::flexblock::FlexBlock;
use crate::util::json::Json;
use crate::workload::graph::Network;
use std::sync::Arc;

/// One Fig. 11 cell: (model, organization, strategy) → cost triple.
#[derive(Debug, Clone)]
pub struct MappingPoint {
    pub model: String,
    pub org: String,
    pub strategy: String,
    pub energy_pj: f64,
    pub latency_cycles: u64,
    pub utilization: f64,
}

fn point_to_json(p: &MappingPoint) -> Json {
    let mut j = Json::obj();
    j.set("model", Json::Str(p.model.clone()))
        .set("org", Json::Str(p.org.clone()))
        .set("strategy", Json::Str(p.strategy.clone()))
        .set("energy_pj", Json::Num(p.energy_pj))
        .set("latency_cycles", Json::Num(p.latency_cycles as f64))
        .set("utilization", Json::Num(p.utilization));
    j
}

fn point_from_json(j: &Json) -> anyhow::Result<MappingPoint> {
    Ok(MappingPoint {
        model: j.req_str("model")?.to_string(),
        org: j.req_str("org")?.to_string(),
        strategy: j.req_str("strategy")?.to_string(),
        energy_pj: j.req_f64("energy_pj")?,
        latency_cycles: j.req_f64("latency_cycles")? as u64,
        utilization: j.req_f64("utilization")?,
    })
}

/// Checkpoint-journal codec for [`MappingPoint`] sweeps.
pub fn mapping_codec() -> Codec<MappingPoint> {
    Codec::new(point_to_json, point_from_json)
}

/// The Fig. 11 organizations of the 16-macro architecture.
pub const ORGS: [(usize, usize); 3] = [(8, 2), (4, 4), (2, 8)];

fn run_one(
    ev: &Evaluator,
    net: &Arc<Network>,
    org: (usize, usize),
    strategy: Strategy,
    fb: &FlexBlock,
    rearrange: bool,
    sim: SimOptions,
) -> anyhow::Result<SimReport> {
    let arch = Arc::new(presets::usecase_arch(16, org));
    let bits = arch.input_bits;
    let opts = MappingOptions {
        policy: StrategyPolicy::Fixed(strategy),
        rearrange,
        ..Default::default()
    };
    let s = Scenario::new(arch, net.clone())
        .prune_uniform(fb)
        .with_mapping(opts)
        .synthetic_profiles(bits, 0.6, 0xF16_11)
        .with_sim(sim);
    ev.evaluate(&s)
}

/// Fig. 11 under the resilient executor: sweep organizations ×
/// strategies for the given networks at the hybrid 80% pattern. The
/// shared evaluator serves the prune plan and profiles from cache
/// across the strategy column of each (model, org) pair.
pub fn run_fig11_robust(
    nets: &[&Network],
    ctx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<Sweep<MappingPoint>> {
    let fb = FlexBlock::hybrid(2, 16, 0.8);
    let mut jobs: Vec<Job<(Arc<Network>, (usize, usize), Strategy)>> = Vec::new();
    for net in nets {
        let netc = Arc::new((*net).clone());
        for org in ORGS {
            for strat in [Strategy::Spatial, Strategy::Duplicate] {
                jobs.push(Job {
                    key: format!(
                        "fig11:{}:{}x{}:{}",
                        net.name,
                        org.0,
                        org.1,
                        strat.label()
                    ),
                    input: (netc.clone(), org, strat),
                });
            }
        }
    }
    let ev = ctx.evaluator.clone();
    let sim = ctx.sim;
    let report = run_sweep(
        jobs,
        cfg,
        Some(mapping_codec()),
        move |(net, org, strat): &(Arc<Network>, (usize, usize), Strategy)| {
            let rep = run_one(&ev, net, *org, *strat, &fb, false, sim)?;
            Ok(MappingPoint {
                model: net.name.clone(),
                org: format!("{}x{}", org.0, org.1),
                strategy: strat.label().to_string(),
                energy_pj: rep.energy.total_pj,
                latency_cycles: rep.total_cycles,
                utilization: rep.mean_utilization,
            })
        },
    )?;
    Ok(Sweep::from_report(report))
}

pub fn run_fig11(nets: &[&Network], threads: usize) -> anyhow::Result<Vec<MappingPoint>> {
    run_fig11_robust(
        nets,
        &EvalCtx::default(),
        &SweepConfig::with_threads(threads),
    )?
    .strict()
}

/// One Fig. 12 row: rearrangement off/on for a strategy. Carries the
/// derived metrics only (not the full `SimReport`), so the sweep
/// journals and resumes like every other study.
#[derive(Debug, Clone)]
pub struct RearrangePoint {
    pub strategy: String,
    pub rearranged: bool,
    pub energy_pj: f64,
    pub latency_cycles: u64,
    pub utilization: f64,
    /// Weight-buffer reads + writes — the buffer-traffic cost the
    /// rearrangement trades against utilization.
    pub weight_buf_accesses: u64,
    /// Energy in the weight/global-in/global-out buffers.
    pub buffer_energy_pj: f64,
}

fn rearrange_to_json(p: &RearrangePoint) -> Json {
    let mut j = Json::obj();
    j.set("strategy", Json::Str(p.strategy.clone()))
        .set("rearranged", Json::Bool(p.rearranged))
        .set("energy_pj", Json::Num(p.energy_pj))
        .set("latency_cycles", Json::Num(p.latency_cycles as f64))
        .set("utilization", Json::Num(p.utilization))
        .set(
            "weight_buf_accesses",
            Json::Num(p.weight_buf_accesses as f64),
        )
        .set("buffer_energy_pj", Json::Num(p.buffer_energy_pj));
    j
}

fn rearrange_from_json(j: &Json) -> anyhow::Result<RearrangePoint> {
    Ok(RearrangePoint {
        strategy: j.req_str("strategy")?.to_string(),
        rearranged: j
            .get("rearranged")
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow::anyhow!("missing bool field `rearranged`"))?,
        energy_pj: j.req_f64("energy_pj")?,
        latency_cycles: j.req_f64("latency_cycles")? as u64,
        utilization: j.req_f64("utilization")?,
        weight_buf_accesses: j.req_f64("weight_buf_accesses")? as u64,
        buffer_energy_pj: j.req_f64("buffer_energy_pj")?,
    })
}

/// Checkpoint-journal codec for [`RearrangePoint`] sweeps — fig12 is
/// checkpointable/resumable like every other study now that points
/// journal derived metrics instead of an embedded report.
pub fn rearrange_codec() -> Codec<RearrangePoint> {
    Codec::new(rearrange_to_json, rearrange_from_json)
}

/// Fig. 12 under the resilient executor: hybrid Intra(2,1)+Full(2,16)
/// on the 4×4 organization, with and without weight-data rearrangement,
/// for both strategies.
pub fn run_fig12_robust(
    net: &Network,
    ctx: &EvalCtx,
    cfg: &SweepConfig,
) -> anyhow::Result<Sweep<RearrangePoint>> {
    let fb = FlexBlock::hybrid(2, 16, 0.8);
    let net = Arc::new(net.clone());
    let mut jobs: Vec<Job<(Strategy, bool)>> = Vec::new();
    for strat in [Strategy::Spatial, Strategy::Duplicate] {
        for rearr in [false, true] {
            jobs.push(Job {
                key: format!("fig12:{}:{}", strat.label(), rearr),
                input: (strat, rearr),
            });
        }
    }
    let ev = ctx.evaluator.clone();
    let sim = ctx.sim;
    let report = run_sweep(
        jobs,
        cfg,
        Some(rearrange_codec()),
        move |(strat, rearr): &(Strategy, bool)| {
            let rep = run_one(&ev, &net, (4, 4), *strat, &fb, *rearr, sim)?;
            Ok(RearrangePoint {
                strategy: strat.label().to_string(),
                rearranged: *rearr,
                energy_pj: rep.energy.total_pj,
                latency_cycles: rep.total_cycles,
                utilization: rep.mean_utilization,
                weight_buf_accesses: rep.counters.reads_of(UnitKind::WeightBuf)
                    + rep.counters.writes_of(UnitKind::WeightBuf),
                buffer_energy_pj: rep.energy.of(UnitKind::WeightBuf)
                    + rep.energy.of(UnitKind::GlobalInBuf)
                    + rep.energy.of(UnitKind::GlobalOutBuf),
            })
        },
    )?;
    Ok(Sweep::from_report(report))
}

pub fn run_fig12(net: &Network, threads: usize) -> anyhow::Result<Vec<RearrangePoint>> {
    run_fig12_robust(
        net,
        &EvalCtx::default(),
        &SweepConfig::with_threads(threads),
    )?
    .strict()
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn fig11_grid_complete() {
        let net = zoo::resnet_mini();
        let pts = run_fig11(&[&net], 0).unwrap();
        assert_eq!(pts.len(), ORGS.len() * 2);
        for p in &pts {
            assert!(p.energy_pj > 0.0);
            assert!(p.latency_cycles > 0);
        }
    }

    #[test]
    fn duplication_raises_utilization_for_conv_models() {
        let net = zoo::resnet_mini();
        let pts = run_fig11(&[&net], 0).unwrap();
        for org in ORGS {
            let label = format!("{}x{}", org.0, org.1);
            let sp = pts
                .iter()
                .find(|p| p.org == label && p.strategy == "spatial")
                .unwrap();
            let dp = pts
                .iter()
                .find(|p| p.org == label && p.strategy == "duplicate")
                .unwrap();
            assert!(
                dp.utilization > sp.utilization,
                "{label}: dup {} <= sp {}",
                dp.utilization,
                sp.utilization
            );
        }
    }

    #[test]
    fn fig12_rearrangement_improves_utilization() {
        let net = zoo::resnet_mini();
        let pts = run_fig12(&net, 0).unwrap();
        for strat in ["spatial", "duplicate"] {
            let base = pts
                .iter()
                .find(|p| p.strategy == strat && !p.rearranged)
                .unwrap();
            let rearr = pts
                .iter()
                .find(|p| p.strategy == strat && p.rearranged)
                .unwrap();
            assert!(
                rearr.utilization >= base.utilization - 1e-9,
                "{strat}: {} < {}",
                rearr.utilization,
                base.utilization
            );
        }
    }

    #[test]
    fn mapping_point_codec_roundtrips() {
        let p = MappingPoint {
            model: "resnet50".into(),
            org: "4x4".into(),
            strategy: "duplicate".into(),
            energy_pj: 1.5e9,
            latency_cycles: 123_456,
            utilization: 0.8,
        };
        let c = mapping_codec();
        let back = c.decode(&c.encode(&p)).unwrap();
        assert_eq!(back.model, p.model);
        assert_eq!(back.latency_cycles, p.latency_cycles);
    }

    #[test]
    fn rearrange_codec_roundtrips() {
        let p = RearrangePoint {
            strategy: "spatial".into(),
            rearranged: true,
            energy_pj: 2.5e8,
            latency_cycles: 42_000,
            utilization: 0.66,
            weight_buf_accesses: 9_876_543,
            buffer_energy_pj: 1.2e7,
        };
        let c = rearrange_codec();
        let back = c.decode(&c.encode(&p)).unwrap();
        assert_eq!(back.strategy, p.strategy);
        assert!(back.rearranged);
        assert_eq!(back.latency_cycles, p.latency_cycles);
        assert_eq!(back.weight_buf_accesses, p.weight_buf_accesses);
        assert_eq!(back.buffer_energy_pj, p.buffer_energy_pj);
    }
}
