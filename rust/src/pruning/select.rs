//! Importance-driven block and pattern selection (Sec. IV-D): the
//! "pruning strategies" component. FullBlock selection keeps the
//! highest-loss Φ blocks globally within a layer (Eq. 1); IntraBlock
//! selection picks, per surviving block, the pattern with the lowest
//! pruned-away loss (Eq. 2).

use super::criterion::{Criterion, WeightMatrix};
use crate::sparsity::mask::{
    bind, fullblock_mask_from_selection, intrablock_apply, pattern_set_for, LayerCtx,
};
use crate::sparsity::flexblock::FlexBlock;
use crate::util::bits::BitMatrix;

/// Keep-selection for a FullBlock pattern: retain the Φ blocks with the
/// highest aggregate importance (equivalently prune the lowest-loss
/// blocks). Ties break on grid order for determinism.
pub fn fullblock_importance_selection(
    w: &WeightMatrix,
    crit: Criterion,
    bp: &crate::sparsity::pattern::BoundPattern,
) -> Vec<bool> {
    let (gr, gc) = bp.grid(w.rows, w.cols);
    let keep_n = bp.nonzero_blocks(w.rows, w.cols);
    let mut losses: Vec<(f64, usize)> = Vec::with_capacity(gr * gc);
    for bi in 0..gr {
        for bj in 0..gc {
            let loss = w.block_loss(crit, bi * bp.m, bj * bp.n, bp.m, bp.n);
            losses.push((loss, bi * gc + bj));
        }
    }
    // descending by loss, ascending by index for ties
    losses.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let mut keep = vec![false; gr * gc];
    for &(_, idx) in losses.iter().take(keep_n) {
        keep[idx] = true;
    }
    keep
}

/// Generate a pruning mask for one layer's weights under `fb`, using
/// importance-based selection (the pruning-workflow path, vs. the random
/// path in `sparsity::mask::random_mask`).
pub fn importance_mask(
    fb: &FlexBlock,
    w: &WeightMatrix,
    crit: Criterion,
    ctx: LayerCtx,
) -> BitMatrix {
    if fb.is_dense() {
        return BitMatrix::ones(w.rows, w.cols);
    }
    let (intra, full) = bind(fb, w.rows, w.cols, ctx);
    let mut mask = match &full {
        Some(bp) => {
            let keep = fullblock_importance_selection(w, crit, bp);
            fullblock_mask_from_selection(w.rows, w.cols, bp, &keep)
        }
        None => BitMatrix::ones(w.rows, w.cols),
    };
    if let Some(bp) = &intra {
        let patterns = pattern_set_for(fb, bp);
        intrablock_apply(&mut mask, bp, &patterns, |bi, bj, set| {
            // lowest pruned-away loss wins (Eq. 2)
            let (r0, c0) = (bi * bp.m, bj * bp.n);
            let mut best = 0usize;
            let mut best_loss = f64::INFINITY;
            for (k, p) in set.iter().enumerate() {
                let loss = w.pattern_loss(crit, r0, c0, p);
                if loss < best_loss {
                    best_loss = loss;
                    best = k;
                }
            }
            best
        });
    }
    mask
}

/// Apply a mask to weights, zeroing pruned elements (in place).
pub fn apply_mask(w: &mut WeightMatrix, mask: &BitMatrix) {
    assert_eq!((w.rows, w.cols), (mask.rows(), mask.cols()));
    for r in 0..w.rows {
        for c in 0..w.cols {
            if !mask.get(r, c) {
                w.data[r * w.cols + c] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_weights(rows: usize, cols: usize, seed: u64) -> WeightMatrix {
        let mut rng = Pcg32::new(seed);
        WeightMatrix::new(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| rng.next_normal() as f32)
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn fullblock_keeps_highest_magnitude_rows() {
        // rows 0..4 have magnitude proportional to row index
        let mut data = vec![0f32; 4 * 8];
        for r in 0..4 {
            for c in 0..8 {
                data[r * 8 + c] = (r as f32 + 1.0) * 0.1;
            }
        }
        let w = WeightMatrix::new(4, 8, data).unwrap();
        let fb = FlexBlock::row_wise(0.5);
        let mask = importance_mask(&fb, &w, Criterion::L1, LayerCtx::fc());
        // rows 2 and 3 (largest) survive
        assert_eq!(mask.row_count(0), 0);
        assert_eq!(mask.row_count(1), 0);
        assert_eq!(mask.row_count(2), 8);
        assert_eq!(mask.row_count(3), 8);
    }

    #[test]
    fn intra_keeps_largest_element_per_block() {
        let w = WeightMatrix::new(4, 1, vec![0.1, 0.9, -0.8, 0.2]).unwrap();
        let fb = FlexBlock::intra(2, 0.5);
        let mask = importance_mask(&fb, &w, Criterion::L1, LayerCtx::fc());
        assert!(!mask.get(0, 0) && mask.get(1, 0), "keeps 0.9 of (0.1,0.9)");
        assert!(mask.get(2, 0) && !mask.get(3, 0), "keeps -0.8 of (-0.8,0.2)");
    }

    #[test]
    fn importance_beats_random_in_retained_norm() {
        let w = random_weights(64, 64, 7);
        let fb = FlexBlock::row_block(16, 0.75);
        let imask = importance_mask(&fb, &w, Criterion::L2, LayerCtx::fc());
        let mut rng = Pcg32::new(8);
        let rmask = crate::sparsity::mask::random_mask(&fb, 64, 64, LayerCtx::fc(), &mut rng);
        let norm = |m: &BitMatrix| -> f64 {
            let mut s = 0.0;
            for r in 0..64 {
                for c in 0..64 {
                    if m.get(r, c) {
                        s += (w.get(r, c) as f64).powi(2);
                    }
                }
            }
            s
        };
        assert!(
            norm(&imask) > norm(&rmask),
            "importance selection retains more weight norm"
        );
        // identical sparsity level
        assert_eq!(imask.count_ones(), rmask.count_ones());
    }

    #[test]
    fn apply_mask_zeroes_pruned() {
        let mut w = random_weights(8, 8, 9);
        let fb = FlexBlock::row_wise(0.5);
        let mask = importance_mask(&fb, &w, Criterion::L1, LayerCtx::fc());
        apply_mask(&mut w, &mask);
        for r in 0..8 {
            for c in 0..8 {
                if !mask.get(r, c) {
                    assert_eq!(w.get(r, c), 0.0);
                }
            }
        }
    }

    #[test]
    fn l1_vs_l2_can_differ() {
        // L2 favors blocks with one huge value; L1 favors many mediums.
        let w = WeightMatrix::new(2, 2, vec![10.0, 0.0, 4.0, 4.0]).unwrap();
        let fb = FlexBlock::row_wise(0.5);
        let m1 = importance_mask(&fb, &w, Criterion::L1, LayerCtx::fc());
        let m2 = importance_mask(&fb, &w, Criterion::L2, LayerCtx::fc());
        // L1: row1 loss 8 < row0 loss 10 → keep row0. L2: 100 vs 32 → row0.
        assert_eq!(m1.row_count(0), 2);
        assert_eq!(m2.row_count(0), 2);
        // L1: row0=6 vs row1=8 → keep row1. L2: row0=36 vs row1=32 → keep row0.
        let w2 = WeightMatrix::new(2, 2, vec![6.0, 0.0, 4.0, 4.0]).unwrap();
        let m1b = importance_mask(&fb, &w2, Criterion::L1, LayerCtx::fc());
        let m2b = importance_mask(&fb, &w2, Criterion::L2, LayerCtx::fc());
        assert_eq!(m1b.row_count(1), 2, "L1 keeps 4+4=8 over 6");
        assert_eq!(m2b.row_count(0), 2, "L2 keeps 36 over 32");
    }
}
