//! Pruning workflow (Sec. IV-D): criteria ρ(·), block-loss (Eq. 1) and
//! pattern-loss (Eq. 2) selection, and the per-network workflow that
//! emits FlexBlock-conformant masks.

pub mod criterion;
pub mod select;
pub mod workflow;

pub use criterion::{Criterion, WeightMatrix};
pub use select::{apply_mask, importance_mask};
pub use workflow::{LayerPrune, PrunePlan, PruningWorkflow};
