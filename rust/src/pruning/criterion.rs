//! Pruning criteria ρ(·) (Sec. IV-D): per-element importance measures
//! aggregated over blocks (Eq. 1) or over pattern-pruned positions
//! (Eq. 2). L1 (magnitude) and L2 (squared magnitude, summing to the
//! squared Euclidean norm over a block) are the paper's named criteria.

/// Pruning criterion selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Criterion {
    /// ρ(w) = |w| — magnitude pruning.
    L1,
    /// ρ(w) = w² — Euclidean-norm pruning (block loss = ‖W_block‖₂²).
    L2,
}

impl Criterion {
    #[inline]
    pub fn rho(&self, w: f32) -> f64 {
        match self {
            Criterion::L1 => w.abs() as f64,
            Criterion::L2 => (w as f64) * (w as f64),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Criterion> {
        match s.to_ascii_lowercase().as_str() {
            "l1" => Ok(Criterion::L1),
            "l2" => Ok(Criterion::L2),
            other => anyhow::bail!("unknown pruning criterion `{other}` (expected l1|l2)"),
        }
    }
}

/// A weight matrix in row-major order with its dims; the unit the
/// pruning workflow operates on (reshaped 2-D view of a layer).
#[derive(Debug, Clone)]
pub struct WeightMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl WeightMatrix {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> anyhow::Result<Self> {
        if data.len() != rows * cols {
            anyhow::bail!(
                "weight data length {} != {rows}x{cols}",
                data.len()
            );
        }
        Ok(Self { rows, cols, data })
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Block loss L_FB (Eq. 1): Σ ρ(W[x,y]) over the block rectangle,
    /// clipped at matrix edges.
    pub fn block_loss(
        &self,
        crit: Criterion,
        r0: usize,
        c0: usize,
        m: usize,
        n: usize,
    ) -> f64 {
        let mut s = 0.0;
        for r in r0..(r0 + m).min(self.rows) {
            for c in c0..(c0 + n).min(self.cols) {
                s += crit.rho(self.get(r, c));
            }
        }
        s
    }

    /// Pattern loss L_IB (Eq. 2): Σ ρ over positions the pattern prunes
    /// (Ω_k = zeros of the pattern mask).
    pub fn pattern_loss(
        &self,
        crit: Criterion,
        r0: usize,
        c0: usize,
        pattern: &crate::util::bits::BitMatrix,
    ) -> f64 {
        let mut s = 0.0;
        for pr in 0..pattern.rows() {
            for pc in 0..pattern.cols() {
                if !pattern.get(pr, pc) {
                    let (r, c) = (r0 + pr, c0 + pc);
                    if r < self.rows && c < self.cols {
                        s += crit.rho(self.get(r, c));
                    }
                }
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bits::BitMatrix;

    #[test]
    fn rho_values() {
        assert_eq!(Criterion::L1.rho(-2.0), 2.0);
        assert_eq!(Criterion::L2.rho(-2.0), 4.0);
        assert_eq!(Criterion::L1.rho(0.5), 0.5);
        assert_eq!(Criterion::L2.rho(0.5), 0.25);
    }

    #[test]
    fn parse_criteria() {
        assert_eq!(Criterion::parse("L1").unwrap(), Criterion::L1);
        assert_eq!(Criterion::parse("l2").unwrap(), Criterion::L2);
        assert!(Criterion::parse("l3").is_err());
    }

    #[test]
    fn block_loss_sums_rectangle() {
        let w = WeightMatrix::new(2, 3, vec![1.0, -2.0, 3.0, 0.5, 0.0, -1.0]).unwrap();
        assert_eq!(w.block_loss(Criterion::L1, 0, 0, 2, 2), 1.0 + 2.0 + 0.5 + 0.0);
        assert_eq!(w.block_loss(Criterion::L2, 0, 2, 2, 1), 9.0 + 1.0);
        // edge clipping
        assert_eq!(w.block_loss(Criterion::L1, 1, 2, 5, 5), 1.0);
    }

    #[test]
    fn pattern_loss_counts_pruned_positions() {
        let w = WeightMatrix::new(2, 1, vec![3.0, -1.0]).unwrap();
        // pattern keeping row 0 → prunes row 1 → loss = ρ(-1)
        let mut keep_top = BitMatrix::zeros(2, 1);
        keep_top.set(0, 0, true);
        assert_eq!(w.pattern_loss(Criterion::L1, 0, 0, &keep_top), 1.0);
        let mut keep_bot = BitMatrix::zeros(2, 1);
        keep_bot.set(1, 0, true);
        assert_eq!(w.pattern_loss(Criterion::L1, 0, 0, &keep_bot), 3.0);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(WeightMatrix::new(2, 2, vec![0.0; 3]).is_err());
    }
}
