//! The pruning workflow (Sec. IV-D): iterates over a network's MVM
//! layers, selects blocks/patterns per the configured criterion, and
//! produces per-layer masks forming a [`PrunePlan`] consumed by the
//! mapping and simulation layers (and, via `runtime::infer`, applied to
//! trained artifact weights for accuracy evaluation).

use super::criterion::{Criterion, WeightMatrix};
use super::select::importance_mask;
use crate::sparsity::flexblock::FlexBlock;
use crate::sparsity::mask::{random_mask, LayerCtx};
use crate::util::bits::BitMatrix;
use crate::util::rng::Pcg32;
use crate::workload::graph::Network;
use crate::workload::op::{OpId, OpKind};
use std::collections::BTreeMap;

/// Pruning configuration.
#[derive(Debug, Clone)]
pub struct PruningWorkflow {
    pub criterion: Criterion,
    /// Seed for randomized masks when no weights are available.
    pub seed: u64,
    /// Skip depthwise convolutions (paper: pruning them destroys
    /// MobileNetV2 accuracy — Fig. 9(b); default true).
    pub skip_depthwise: bool,
    /// Skip fully-connected layers (paper: pruning VGG16 FC layers causes
    /// significant accuracy drop; default false).
    pub skip_fc: bool,
    /// Skip the stem/first convolution (common pruning practice).
    pub skip_first_conv: bool,
}

impl Default for PruningWorkflow {
    fn default() -> Self {
        Self {
            criterion: Criterion::L2,
            seed: 0xC1A0_5EED,
            skip_depthwise: true,
            skip_fc: false,
            skip_first_conv: true,
        }
    }
}

/// One layer's pruning outcome.
#[derive(Debug, Clone)]
pub struct LayerPrune {
    pub fb: FlexBlock,
    pub mask: BitMatrix,
    pub ctx: LayerCtx,
}

/// Masks for all pruned layers of a network.
#[derive(Debug, Clone, Default)]
pub struct PrunePlan {
    pub layers: BTreeMap<OpId, LayerPrune>,
}

impl PrunePlan {
    /// Overall weight sparsity across pruned layers (element-weighted).
    pub fn overall_sparsity(&self) -> f64 {
        let (mut nz, mut total) = (0u64, 0u64);
        for lp in self.layers.values() {
            nz += lp.mask.count_ones() as u64;
            total += (lp.mask.rows() * lp.mask.cols()) as u64;
        }
        if total == 0 {
            0.0
        } else {
            1.0 - nz as f64 / total as f64
        }
    }

    pub fn mask_for(&self, id: OpId) -> Option<&LayerPrune> {
        self.layers.get(&id)
    }
}

impl PruningWorkflow {
    /// Is this op eligible for pruning under the workflow's policy?
    pub fn eligible(&self, net: &Network, id: OpId) -> bool {
        let op = &net.ops[id];
        match &op.kind {
            OpKind::Conv2d { groups, .. } => {
                if *groups > 1 && self.skip_depthwise {
                    return false;
                }
                if self.skip_first_conv {
                    // first MVM op in topological order is the stem
                    if net.mvm_ops().first() == Some(&id) {
                        return false;
                    }
                }
                true
            }
            OpKind::Fc { .. } => !self.skip_fc,
            _ => false,
        }
    }

    /// Layer context for symbolic dim binding (kh·kw rows per channel).
    pub fn layer_ctx(net: &Network, id: OpId) -> LayerCtx {
        match &net.ops[id].kind {
            OpKind::Conv2d { kh, kw, .. } => LayerCtx {
                per_channel: kh * kw,
            },
            _ => LayerCtx::fc(),
        }
    }

    /// Apply the same FlexBlock description to every eligible layer.
    /// With `weights` (keyed by op id, reshaped row-major M×N), selection
    /// is importance-based (Eq. 1/2); otherwise randomized per seed.
    pub fn run_uniform(
        &self,
        net: &Network,
        fb: &FlexBlock,
        weights: Option<&BTreeMap<OpId, WeightMatrix>>,
    ) -> anyhow::Result<PrunePlan> {
        fb.validate()?;
        let mut assignment = BTreeMap::new();
        for id in net.mvm_ops() {
            if self.eligible(net, id) {
                assignment.insert(id, fb.clone());
            }
        }
        self.run(net, &assignment, weights)
    }

    /// Apply per-layer FlexBlock assignments.
    pub fn run(
        &self,
        net: &Network,
        assignment: &BTreeMap<OpId, FlexBlock>,
        weights: Option<&BTreeMap<OpId, WeightMatrix>>,
    ) -> anyhow::Result<PrunePlan> {
        let mut rng = Pcg32::new(self.seed);
        let mut plan = PrunePlan::default();
        for (&id, fb) in assignment {
            if fb.is_dense() {
                continue;
            }
            fb.validate()?;
            let dims = net
                .mvm_dims(id)
                .ok_or_else(|| anyhow::anyhow!("op {id} is not an MVM op"))?;
            let ctx = Self::layer_ctx(net, id);
            let mask = match weights.and_then(|w| w.get(&id)) {
                Some(w) => {
                    if (w.rows, w.cols) != (dims.rows, dims.cols) {
                        anyhow::bail!(
                            "op {id} (`{}`): weights {}x{} != reshaped dims {}x{}",
                            net.ops[id].name,
                            w.rows,
                            w.cols,
                            dims.rows,
                            dims.cols
                        );
                    }
                    importance_mask(fb, w, self.criterion, ctx)
                }
                None => {
                    let mut layer_rng = rng.fork(id as u64);
                    random_mask(fb, dims.rows, dims.cols, ctx, &mut layer_rng)
                }
            };
            plan.layers.insert(
                id,
                LayerPrune {
                    fb: fb.clone(),
                    mask,
                    ctx,
                },
            );
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn uniform_prunes_eligible_layers_only() {
        let net = zoo::mobilenet_mini();
        let wf = PruningWorkflow::default();
        let fb = FlexBlock::row_wise(0.5);
        let plan = wf.run_uniform(&net, &fb, None).unwrap();
        // depthwise + stem excluded
        for (&id, _) in &plan.layers {
            match net.ops[id].kind {
                OpKind::Conv2d { groups, .. } => assert_eq!(groups, 1),
                OpKind::Fc { .. } => {}
                _ => panic!("non-MVM op pruned"),
            }
        }
        let first_mvm = net.mvm_ops()[0];
        assert!(!plan.layers.contains_key(&first_mvm), "stem skipped");
        assert!(!plan.layers.is_empty());
    }

    #[test]
    fn plan_sparsity_close_to_target() {
        let net = zoo::resnet_mini();
        let wf = PruningWorkflow::default();
        let fb = FlexBlock::row_wise(0.8);
        let plan = wf.run_uniform(&net, &fb, None).unwrap();
        let s = plan.overall_sparsity();
        assert!((s - 0.8).abs() < 0.05, "sparsity {s}");
    }

    #[test]
    fn deterministic_given_seed() {
        let net = zoo::resnet_mini();
        let wf = PruningWorkflow::default();
        let fb = FlexBlock::hybrid(2, 16, 0.7);
        let a = wf.run_uniform(&net, &fb, None).unwrap();
        let b = wf.run_uniform(&net, &fb, None).unwrap();
        for (id, la) in &a.layers {
            assert_eq!(la.mask, b.layers[id].mask);
        }
    }

    #[test]
    fn mask_dims_match_layer_dims() {
        let net = zoo::vgg_mini();
        let wf = PruningWorkflow {
            skip_fc: false,
            ..Default::default()
        };
        let fb = FlexBlock::row_block(16, 0.6);
        let plan = wf.run_uniform(&net, &fb, None).unwrap();
        for (&id, lp) in &plan.layers {
            let d = net.mvm_dims(id).unwrap();
            assert_eq!((lp.mask.rows(), lp.mask.cols()), (d.rows, d.cols));
        }
    }

    #[test]
    fn skip_fc_flag() {
        let net = zoo::vgg_mini();
        let wf = PruningWorkflow {
            skip_fc: true,
            ..Default::default()
        };
        let plan = wf
            .run_uniform(&net, &FlexBlock::row_wise(0.5), None)
            .unwrap();
        for (&id, _) in &plan.layers {
            assert!(!matches!(net.ops[id].kind, OpKind::Fc { .. }));
        }
    }

    #[test]
    fn weight_shape_mismatch_rejected() {
        let net = zoo::resnet_mini();
        let wf = PruningWorkflow::default();
        let mut weights = BTreeMap::new();
        let id = net.mvm_ops()[1];
        weights.insert(id, WeightMatrix::new(2, 2, vec![0.0; 4]).unwrap());
        let mut assignment = BTreeMap::new();
        assignment.insert(id, FlexBlock::row_wise(0.5));
        assert!(wf.run(&net, &assignment, Some(&weights)).is_err());
    }
}
