//! # CIMinus
//!
//! A cost-modeling and design-space-exploration framework for **sparse DNN
//! workloads on SRAM-based digital compute-in-memory (CIM) architectures**,
//! reproducing *CIMinus: Empowering Sparse DNN Workloads Modeling and
//! Exploration on SRAM-based CIM Architectures* (IEEE TC 2025).
//!
//! The crate is the L3 coordinator of a three-layer stack:
//!
//! - **L3 (this crate)** — workload DAGs, the FlexBlock sparsity
//!   abstraction, the pruning workflow, hardware and mapping descriptions,
//!   a cycle-level simulation engine with per-unit energy accounting, and
//!   exploration/validation harnesses.
//! - **L2 (python/compile)** — JAX models trained at build time and lowered
//!   to HLO text artifacts.
//! - **L1 (python/compile/kernels)** — Pallas kernels (FlexBlock masked
//!   matmul, activation bit-plane profiling) embedded in the L2 graphs.
//!
//! Python never runs at evaluation time: the [`runtime`] module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) for the
//! pre-simulation analyses (pruned-model accuracy, input-sparsity
//! profiling) that the paper describes in Sec. IV-B.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use ciminus::prelude::*;
//! let arch = ciminus::hw::presets::usecase_arch(4, (2, 2));
//! let net = ciminus::workload::zoo::resnet18(32, 100);
//! let sparsity = FlexBlock::full_block(1, 16, 0.8);
//! let report = ciminus::sim::simulate_network_default(&arch, &net, Some(&sparsity)).unwrap();
//! println!("{}", report.summary());
//! ```

pub mod cli;
pub mod eval;
pub mod explore;
pub mod hw;
pub mod mapping;
pub mod pruning;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod sparsity;
pub mod util;
pub mod validate;
pub mod workload;

/// Convenience re-exports for the common API surface.
pub mod prelude {
    pub use crate::eval::{EvalCtx, Evaluator, Scenario};
    pub use crate::hw::arch::Architecture;
    pub use crate::mapping::planner::MappingPlan;
    pub use crate::pruning::workflow::PruningWorkflow;
    pub use crate::sim::report::SimReport;
    pub use crate::sparsity::flexblock::FlexBlock;
    pub use crate::sparsity::pattern::{BlockPattern, PatternKind};
    pub use crate::workload::graph::Network;
    pub use crate::workload::op::{Op, OpKind};
}
