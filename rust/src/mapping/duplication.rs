//! Mapping strategies (Sec. VII-C): spatial mapping vs. weight
//! duplication across the organization's second dimension, plus the
//! auto-selection heuristic the mapping-strategy exploration evaluates.

use crate::workload::op::MvmDims;

/// How the organization's column dimension is used.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Unroll weight column tiles spatially (more of the matrix resident).
    Spatial,
    /// Duplicate weight tiles and split input vectors among copies
    /// (higher utilization for compressed Conv layers, Fig. 11).
    Duplicate,
}

impl Strategy {
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Spatial => "spatial",
            Strategy::Duplicate => "duplicate",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "spatial" | "sp" => Ok(Strategy::Spatial),
            "duplicate" | "dp" | "dup" => Ok(Strategy::Duplicate),
            other => anyhow::bail!("unknown mapping strategy `{other}` (spatial|duplicate)"),
        }
    }
}

/// Per-op strategy policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyPolicy {
    /// Force one strategy for every MVM op.
    Fixed(Strategy),
    /// Heuristic: duplicate when the op has many vectors to share and its
    /// weights underfill the spatially-available arrays (Conv layers);
    /// spatial otherwise (FC layers — little reuse, duplication wastes
    /// loads, Fig. 11's VGG finding).
    Auto,
}

impl StrategyPolicy {
    /// Resolve the strategy for an op: `fit` is the fraction of the
    /// spatial capacity the op's compressed weights occupy (>1 = does not
    /// fit at once).
    pub fn resolve(&self, dims: &MvmDims, fit: f64) -> Strategy {
        match self {
            StrategyPolicy::Fixed(s) => *s,
            StrategyPolicy::Auto => {
                let reuse = dims.n_vectors; // vectors sharing the weights
                if reuse >= 8 && fit < 0.5 {
                    Strategy::Duplicate
                } else {
                    Strategy::Spatial
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(vecs: usize) -> MvmDims {
        MvmDims {
            rows: 512,
            cols: 32,
            n_vectors: vecs,
            groups: 1,
        }
    }

    #[test]
    fn parse_labels() {
        assert_eq!(Strategy::parse("spatial").unwrap(), Strategy::Spatial);
        assert_eq!(Strategy::parse("DP").unwrap(), Strategy::Duplicate);
        assert!(Strategy::parse("x").is_err());
    }

    #[test]
    fn auto_duplicates_conv_like_ops() {
        // many vectors, small footprint → duplicate
        assert_eq!(
            StrategyPolicy::Auto.resolve(&d(256), 0.1),
            Strategy::Duplicate
        );
    }

    #[test]
    fn auto_keeps_fc_spatial() {
        // FC: one vector → no reuse to split
        assert_eq!(StrategyPolicy::Auto.resolve(&d(1), 0.1), Strategy::Spatial);
        // big op that fills the arrays → spatial
        assert_eq!(
            StrategyPolicy::Auto.resolve(&d(256), 0.9),
            Strategy::Spatial
        );
    }

    #[test]
    fn fixed_overrides() {
        assert_eq!(
            StrategyPolicy::Fixed(Strategy::Duplicate).resolve(&d(1), 2.0),
            Strategy::Duplicate
        );
    }
}
