//! Tile partitioning and round construction: how a compressed weight
//! matrix is cut into array-sized tiles and scheduled onto the macro
//! grid over temporal rounds (the executable form of the loopnest).

use super::duplication::Strategy;
use crate::hw::arch::Architecture;
use crate::sparsity::compress::CompressedLayout;
use crate::workload::op::MvmDims;

/// One macro's tile occupancy in a round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacroTile {
    /// Array rows with at least one occupied cell.
    pub rows_used: usize,
    /// Maximum occupied column extent.
    pub cols_used: usize,
    /// Total occupied weight cells.
    pub occupied: u64,
}

/// One temporal round: a set of macros computing concurrently.
#[derive(Debug, Clone, PartialEq)]
pub struct Round {
    /// Occupied tiles, one entry per *active* macro this round.
    pub tiles: Vec<MacroTile>,
    /// Input vectors each active macro processes this round.
    pub vectors_per_macro: usize,
    /// Compressed weight bytes pulled from the weight buffer this round.
    /// Duplicated copies receive the same tile over a broadcast bus, so
    /// they count once here.
    pub weight_bytes: u64,
    /// Final output values leaving the macros this round (after on-chip
    /// accumulation across row tiles), used for write-back sizing.
    pub outputs: u64,
    /// Distinct input rows that must be fetched this round, per vector:
    /// macros sharing a row tile (spatial column unrolling) share inputs;
    /// duplicates process different vectors so each copy counts.
    pub input_rows: u64,
}

impl Round {
    pub fn occupied_cells(&self) -> u64 {
        self.tiles.iter().map(|t| t.occupied).sum()
    }
}

/// A fully tiled + scheduled MVM op.
#[derive(Debug, Clone, PartialEq)]
pub struct OpTiling {
    pub tiles_r: usize,
    pub tiles_c: usize,
    pub rounds: Vec<Round>,
    /// Mean array utilization across rounds, counting idle macros
    /// (occupied cells / (n_macros · R · C)).
    pub utilization: f64,
    /// groups packed block-diagonally per tile (1 for standard layers).
    pub groups_per_tile: usize,
}

/// Build the tiling/schedule for one MVM op.
///
/// `layout` is the (possibly rearranged) compressed layout of the
/// *per-group* weight matrix; `dims` carries groups and vector counts.
pub fn tile_op(
    arch: &Architecture,
    dims: &MvmDims,
    layout: &CompressedLayout,
    strategy: Strategy,
) -> OpTiling {
    let (r_arr, c_arr) = (arch.cim.rows, arch.cim.cols);
    let d0 = arch.org.row_dim();
    let d1 = arch.org.col_dim();
    let n_macros = arch.org.n_macros();

    if dims.groups > 1 {
        return tile_grouped(arch, dims, layout, strategy);
    }

    let tiles_r = layout.comp_rows.div_ceil(r_arr).max(1);
    let tiles_c = layout.comp_cols.div_ceil(c_arr).max(1);

    // occupancy of tile (tr, tc)
    let tile_at = |tr: usize, tc: usize| -> MacroTile {
        let r0 = tr * r_arr;
        let r1 = ((tr + 1) * r_arr).min(layout.comp_rows);
        let c0 = tc * c_arr;
        let mut rows_used = 0usize;
        let mut cols_used = 0usize;
        let mut occupied = 0u64;
        for r in r0..r1 {
            let len = layout.row_lengths.get(r).copied().unwrap_or(0);
            let active = len.saturating_sub(c0).min(c_arr);
            if active > 0 {
                rows_used += 1;
                cols_used = cols_used.max(active);
                occupied += active as u64;
            }
        }
        MacroTile {
            rows_used,
            cols_used,
            occupied,
        }
    };

    let wb = arch.weight_bits as u64;
    let mut rounds = Vec::new();
    match strategy {
        Strategy::Spatial => {
            // row tiles across org dim0, col tiles across org dim1
            let rounds_r = tiles_r.div_ceil(d0);
            let rounds_c = tiles_c.div_ceil(d1);
            for rr in 0..rounds_r {
                for rc in 0..rounds_c {
                    let mut tiles = Vec::new();
                    let mut bytes = 0u64;
                    let mut outputs = 0u64;
                    let mut input_rows = 0u64;
                    // outputs: one value per (col position, vector) —
                    // partial sums accumulate across row tiles on-chip
                    let mut col_extent = vec![0usize; d1];
                    for i in 0..d0 {
                        let tr = rr * d0 + i;
                        if tr >= tiles_r {
                            continue;
                        }
                        let mut row_tile_max = 0usize;
                        for j in 0..d1 {
                            let tc = rc * d1 + j;
                            if tc >= tiles_c {
                                continue;
                            }
                            let t = tile_at(tr, tc);
                            if t.occupied == 0 {
                                continue;
                            }
                            bytes += t.occupied * wb / 8;
                            col_extent[j] = col_extent[j].max(t.cols_used);
                            row_tile_max = row_tile_max.max(t.rows_used);
                            tiles.push(t);
                        }
                        // column-unrolled macros share this row tile's inputs
                        input_rows += row_tile_max as u64;
                    }
                    outputs += col_extent.iter().map(|&c| c as u64).sum::<u64>()
                        * dims.n_vectors as u64;
                    if tiles.is_empty() {
                        continue;
                    }
                    rounds.push(Round {
                        tiles,
                        vectors_per_macro: dims.n_vectors,
                        weight_bytes: bytes,
                        outputs,
                        input_rows,
                    });
                }
            }
        }
        Strategy::Duplicate => {
            // row tiles across dim0; col tiles temporal; dim1 duplicates
            // the weights and splits the vectors
            let rounds_r = tiles_r.div_ceil(d0);
            let vec_share = dims.n_vectors.div_ceil(d1).max(1);
            for rr in 0..rounds_r {
                for tc in 0..tiles_c {
                    let mut tiles = Vec::new();
                    let mut bytes = 0u64;
                    let mut outputs = 0u64;
                    let mut input_rows = 0u64;
                    let mut col_max = 0usize;
                    for i in 0..d0 {
                        let tr = rr * d0 + i;
                        if tr >= tiles_r {
                            continue;
                        }
                        let t = tile_at(tr, tc);
                        if t.occupied == 0 {
                            continue;
                        }
                        // one broadcast read serves all d1 duplicates;
                        // each copy processes a different vector share and
                        // fetches its own inputs
                        bytes += t.occupied * wb / 8;
                        col_max = col_max.max(t.cols_used);
                        for _ in 0..d1 {
                            input_rows += t.rows_used as u64;
                            tiles.push(t);
                        }
                    }
                    // copies cover disjoint vectors; row tiles accumulate
                    outputs += col_max as u64 * (vec_share * d1) as u64;
                    if tiles.is_empty() {
                        continue;
                    }
                    rounds.push(Round {
                        tiles,
                        vectors_per_macro: vec_share,
                        weight_bytes: bytes,
                        outputs,
                        input_rows,
                    });
                }
            }
        }
    }

    let utilization = mean_utilization(&rounds, n_macros, r_arr, c_arr);
    OpTiling {
        tiles_r,
        tiles_c,
        rounds,
        utilization,
        groups_per_tile: 1,
    }
}

/// Depthwise/grouped layers: per-group matrices are tiny (kh·kw × 1), so
/// groups pack block-diagonally into one tile — disjoint rows *and*
/// columns per group keep row broadcast and column accumulation disjoint.
fn tile_grouped(
    arch: &Architecture,
    dims: &MvmDims,
    layout: &CompressedLayout,
    strategy: Strategy,
) -> OpTiling {
    let (r_arr, c_arr) = (arch.cim.rows, arch.cim.cols);
    let d0 = arch.org.row_dim();
    let d1 = arch.org.col_dim();
    let n_macros = arch.org.n_macros();
    let g_rows = layout.comp_rows.max(1);
    let g_cols = layout.comp_cols.max(1);
    let per_tile = (r_arr / g_rows).min(c_arr / g_cols).max(1);
    let tiles = dims.groups.div_ceil(per_tile);
    let occupied_per_group = layout.row_lengths.iter().map(|&l| l as u64).sum::<u64>();
    let wb = arch.weight_bits as u64;

    let tile_for = |groups_here: usize| MacroTile {
        rows_used: groups_here * g_rows,
        cols_used: groups_here * g_cols,
        occupied: occupied_per_group * groups_here as u64,
    };

    let (spatial_macros, vec_share) = match strategy {
        Strategy::Spatial => (d0 * d1, dims.n_vectors),
        Strategy::Duplicate => (d0, dims.n_vectors.div_ceil(d1).max(1)),
    };
    let dup = match strategy {
        Strategy::Spatial => 1,
        Strategy::Duplicate => d1,
    };

    let mut rounds = Vec::new();
    let mut remaining = dims.groups;
    while remaining > 0 {
        let mut tiles_vec = Vec::new();
        let mut bytes = 0u64;
        let mut outputs = 0u64;
        let mut input_rows = 0u64;
        for _ in 0..spatial_macros {
            if remaining == 0 {
                break;
            }
            let g_here = remaining.min(per_tile);
            remaining -= g_here;
            let t = tile_for(g_here);
            // broadcast one tile load to all duplicates; copies split
            // the vectors, so outputs cover the full vector range
            bytes += t.occupied * wb / 8;
            outputs += t.cols_used as u64 * (vec_share * dup) as u64;
            for _ in 0..dup {
                input_rows += t.rows_used as u64;
                tiles_vec.push(t);
            }
        }
        rounds.push(Round {
            tiles: tiles_vec,
            vectors_per_macro: vec_share,
            weight_bytes: bytes,
            outputs,
            input_rows,
        });
    }
    let utilization = mean_utilization(&rounds, n_macros, r_arr, c_arr);
    OpTiling {
        tiles_r: tiles,
        tiles_c: 1,
        rounds,
        utilization,
        groups_per_tile: per_tile,
    }
}

/// Mean occupancy of `rounds` against a grid of `n_macros` arrays of
/// `r`×`c` cells. Public so the planner can re-score degraded schedules
/// against the *full* (fault-free) geometry.
pub fn mean_utilization(rounds: &[Round], n_macros: usize, r: usize, c: usize) -> f64 {
    if rounds.is_empty() {
        return 0.0;
    }
    let cap = (n_macros * r * c) as f64;
    rounds
        .iter()
        .map(|rd| rd.occupied_cells() as f64 / cap)
        .sum::<f64>()
        / rounds.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::sparsity::compress::CompressedLayout;
    use crate::workload::op::MvmDims;

    fn dims(rows: usize, cols: usize, vecs: usize) -> MvmDims {
        MvmDims {
            rows,
            cols,
            n_vectors: vecs,
            groups: 1,
        }
    }

    #[test]
    fn dense_small_fits_one_round() {
        let arch = presets::usecase_arch(4, (2, 2)); // 1024x32 arrays
        let d = dims(512, 32, 100);
        let l = CompressedLayout::dense(512, 32);
        let t = tile_op(&arch, &d, &l, Strategy::Spatial);
        assert_eq!((t.tiles_r, t.tiles_c), (1, 1));
        assert_eq!(t.rounds.len(), 1);
        assert_eq!(t.rounds[0].tiles.len(), 1);
        assert_eq!(t.rounds[0].tiles[0].rows_used, 512);
        // utilization: 512*32 cells of 4 macros × 1024×32
        assert!((t.utilization - 512.0 * 32.0 / (4.0 * 1024.0 * 32.0)).abs() < 1e-9);
    }

    #[test]
    fn spatial_uses_grid() {
        let arch = presets::usecase_arch(4, (2, 2));
        // 2048 rows × 64 cols → 2×2 tiles → one round on 2×2 org
        let d = dims(2048, 64, 10);
        let l = CompressedLayout::dense(2048, 64);
        let t = tile_op(&arch, &d, &l, Strategy::Spatial);
        assert_eq!((t.tiles_r, t.tiles_c), (2, 2));
        assert_eq!(t.rounds.len(), 1);
        assert_eq!(t.rounds[0].tiles.len(), 4);
        assert!((t.utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spatial_overflow_goes_temporal() {
        let arch = presets::usecase_arch(4, (2, 2));
        let d = dims(4096, 64, 10);
        let l = CompressedLayout::dense(4096, 64);
        let t = tile_op(&arch, &d, &l, Strategy::Spatial);
        assert_eq!(t.tiles_r, 4);
        assert_eq!(t.rounds.len(), 2);
    }

    #[test]
    fn duplicate_splits_vectors_and_reloads_weights() {
        let arch = presets::usecase_arch(4, (2, 2));
        let d = dims(1024, 32, 100);
        let l = CompressedLayout::dense(1024, 32);
        let sp = tile_op(&arch, &d, &l, Strategy::Spatial);
        let dp = tile_op(&arch, &d, &l, Strategy::Duplicate);
        // duplication: 2 copies working on 50 vectors each
        assert_eq!(dp.rounds[0].vectors_per_macro, 50);
        assert_eq!(sp.rounds[0].vectors_per_macro, 100);
        // the duplicate copies receive the tile over a broadcast bus —
        // one weight-buffer read covers both
        assert_eq!(dp.rounds[0].weight_bytes, sp.rounds[0].weight_bytes);
        // outputs cover the same total work either way
        assert_eq!(dp.rounds[0].outputs, sp.rounds[0].outputs);
        // and duplication doubles utilization for this single-tile op
        assert!(dp.utilization > sp.utilization * 1.9);
    }

    #[test]
    fn ragged_rows_limit_cols_used() {
        let arch = presets::usecase_arch(4, (2, 2));
        let mut l = CompressedLayout::dense(64, 32);
        l.row_lengths = (0..64).map(|r| if r < 32 { 32 } else { 8 }).collect();
        l.comp_cols = 32;
        let d = dims(64, 32, 10);
        let t = tile_op(&arch, &d, &l, Strategy::Spatial);
        let tile = &t.rounds[0].tiles[0];
        assert_eq!(tile.rows_used, 64);
        assert_eq!(tile.cols_used, 32);
        assert_eq!(tile.occupied, 32 * 32 + 32 * 8);
    }

    #[test]
    fn grouped_depthwise_packs_block_diagonal() {
        let arch = presets::usecase_arch(4, (2, 2)); // 1024x32
        let d = MvmDims {
            rows: 9,
            cols: 1,
            n_vectors: 64,
            groups: 32,
        };
        let l = CompressedLayout::dense(9, 1);
        let t = tile_op(&arch, &d, &l, Strategy::Spatial);
        // per tile: min(1024/9, 32/1) = 32 groups → single tile
        assert_eq!(t.groups_per_tile, 32);
        assert_eq!(t.rounds.len(), 1);
        let tile = &t.rounds[0].tiles[0];
        assert_eq!(tile.rows_used, 32 * 9);
        assert_eq!(tile.cols_used, 32);
        // utilization is low: 288 cells of 32768 per macro
        assert!(t.utilization < 0.01);
    }

    #[test]
    fn compressed_layout_reduces_rounds() {
        let arch = presets::usecase_arch(4, (2, 2));
        let d = dims(8192, 32, 10);
        let dense = CompressedLayout::dense(8192, 32);
        let mut comp = CompressedLayout::dense(2048, 32);
        comp.orig_rows = 8192;
        let td = tile_op(&arch, &d, &dense, Strategy::Spatial);
        let tc = tile_op(&arch, &d, &comp, Strategy::Spatial);
        assert!(tc.rounds.len() < td.rounds.len());
    }
}
