//! Weight-data rearrangement (Sec. IV-C ①, Fig. 12): equalizing ragged
//! compressed matrices to improve spatial utilization.
//!
//! After FlexBlock compression, partial-width patterns (path D) leave
//! each physical row with a different occupied width. Mapping the ragged
//! matrix directly wastes array columns (the tile must span the longest
//! row). Rearrangement slices long rows into `slice` -wide chunks and
//! greedily repacks them into near-uniform rows — at the cost of extra
//! buffer traffic to shuffle the data (the overhead Fig. 12 exposes).

use crate::sparsity::compress::CompressedLayout;

/// Result of a rearrangement pass.
#[derive(Debug, Clone, PartialEq)]
pub struct Rearranged {
    /// The equalized layout (row_lengths repacked, comp dims updated).
    pub layout: CompressedLayout,
    /// Bytes moved through the weight buffer to realize the shuffle
    /// (read + write once per moved element byte).
    pub moved_bytes: u64,
    /// Raggedness before/after: (max−min)/max of row lengths.
    pub raggedness_before: f64,
    pub raggedness_after: f64,
}

fn raggedness(lengths: &[usize]) -> f64 {
    let max = lengths.iter().copied().max().unwrap_or(0);
    let min = lengths.iter().copied().min().unwrap_or(0);
    if max == 0 {
        0.0
    } else {
        (max - min) as f64 / max as f64
    }
}

/// Equalize `layout.row_lengths` by slicing rows at `slice` granularity
/// and repacking greedily (first-fit-decreasing) into rows of the target
/// width. `weight_bits` sizes the data movement cost.
pub fn rearrange(layout: &CompressedLayout, slice: usize, weight_bits: usize) -> Rearranged {
    assert!(slice > 0, "slice size must be positive");
    let before = raggedness(&layout.row_lengths);
    let total_occ: usize = layout.row_lengths.iter().sum();
    if total_occ == 0 || layout.comp_rows == 0 {
        return Rearranged {
            layout: layout.clone(),
            moved_bytes: 0,
            raggedness_before: before,
            raggedness_after: before,
        };
    }
    // target width: the smallest multiple of `slice` that fits the mean
    // occupancy — equalization cannot beat the mean.
    let mean = total_occ as f64 / layout.comp_rows as f64;
    let target = (mean / slice as f64).ceil() as usize * slice;
    let target = target.max(slice);

    // slice every row into `slice`-wide chunks (last chunk partial)
    let mut chunks: Vec<usize> = Vec::new();
    let mut moved: u64 = 0;
    let mut new_rows: Vec<usize> = Vec::new();
    for &len in &layout.row_lengths {
        if len == 0 {
            continue;
        }
        if len <= target {
            // row stays in place; only the overflow rows move
            new_rows.push(len);
        } else {
            // keep `target` in place, slice the remainder for repacking
            new_rows.push(target);
            let mut rem = len - target;
            while rem > 0 {
                let c = rem.min(slice);
                chunks.push(c);
                moved += c as u64 * weight_bits as u64 / 8;
                rem -= c;
            }
        }
    }
    // first-fit-decreasing pack of chunks into rows with spare capacity,
    // then into fresh rows
    chunks.sort_unstable_by(|a, b| b.cmp(a));
    for c in chunks {
        let mut placed = false;
        for r in new_rows.iter_mut() {
            if *r + c <= target {
                *r += c;
                placed = true;
                break;
            }
        }
        if !placed {
            new_rows.push(c);
        }
    }
    let comp_rows = new_rows.len();
    let comp_cols = new_rows.iter().copied().max().unwrap_or(0);
    let after = raggedness(&new_rows);
    let mut out = layout.clone();
    out.comp_rows = comp_rows;
    out.comp_cols = comp_cols;
    out.row_lengths = new_rows;
    // rearrangement scrambles block alignment → routing always required
    out.misaligned_cols = layout.misaligned_cols;
    out.routed_rows = true;
    Rearranged {
        layout: out,
        moved_bytes: moved,
        raggedness_before: before,
        raggedness_after: after,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::compress::compress;
    use crate::sparsity::flexblock::FlexBlock;
    use crate::sparsity::mask::{random_mask, LayerCtx};
    use crate::util::rng::Pcg32;

    fn ragged_layout(seed: u64) -> CompressedLayout {
        let fb = FlexBlock::row_block(16, 0.6);
        let ctx = LayerCtx::fc();
        let mut rng = Pcg32::new(seed);
        let mask = random_mask(&fb, 128, 128, ctx, &mut rng);
        compress(&fb, &mask, ctx)
    }

    #[test]
    fn rearrange_reduces_raggedness_and_width() {
        let l = ragged_layout(1);
        let r = rearrange(&l, 16, 8);
        assert!(r.raggedness_after <= r.raggedness_before + 1e-12);
        assert!(r.layout.comp_cols <= l.comp_cols);
        // occupancy preserved
        let before: usize = l.row_lengths.iter().sum();
        let after: usize = r.layout.row_lengths.iter().sum();
        assert_eq!(before, after);
    }

    #[test]
    fn rearrange_costs_buffer_traffic_when_ragged() {
        let l = ragged_layout(2);
        let r = rearrange(&l, 16, 8);
        if r.raggedness_before > 0.2 {
            assert!(r.moved_bytes > 0, "shuffling ragged rows moves data");
        }
    }

    #[test]
    fn uniform_layout_is_noop() {
        let l = CompressedLayout::dense(32, 64);
        let r = rearrange(&l, 16, 8);
        assert_eq!(r.moved_bytes, 0);
        assert_eq!(r.layout.comp_rows, 32);
        assert_eq!(r.layout.comp_cols, 64);
    }

    #[test]
    fn packing_utilization_improves() {
        let l = ragged_layout(3);
        let r = rearrange(&l, 16, 8);
        assert!(
            r.layout.packing_utilization() >= l.packing_utilization() - 1e-9,
            "after {} < before {}",
            r.layout.packing_utilization(),
            l.packing_utilization()
        );
    }

    #[test]
    fn rows_never_exceed_target_plus_slice() {
        let l = ragged_layout(4);
        let r = rearrange(&l, 8, 8);
        let max = r.layout.row_lengths.iter().copied().max().unwrap();
        assert_eq!(max, r.layout.comp_cols);
    }
}
