//! Loopnest mapping description (Sec. IV-C Mapping ②): the multi-level
//! loop representation of an MVM operation's execution, with each loop
//! bound either temporally (sequential) or spatially (to an organization
//! dimension of the macro grid).

use crate::hw::org::MacroOrg;

/// The loop axes of a tiled MVM on a multi-macro CIM.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LoopAxis {
    /// Weight-matrix row tiles (input-patch dimension).
    RowTile,
    /// Weight-matrix column tiles (output channels).
    ColTile,
    /// Input vectors (im2col columns / output pixels).
    Vector,
    /// Bit-serial input bits.
    Bit,
    /// Independent weight groups (depthwise).
    Group,
}

impl LoopAxis {
    pub fn label(&self) -> &'static str {
        match self {
            LoopAxis::RowTile => "row_tile",
            LoopAxis::ColTile => "col_tile",
            LoopAxis::Vector => "vector",
            LoopAxis::Bit => "bit",
            LoopAxis::Group => "group",
        }
    }
}

/// Binding of one loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Binding {
    /// Executed sequentially.
    Temporal,
    /// Unrolled across organization dimension `dim` (0 or 1). For weight
    /// axes this loads different tiles per macro; for the Vector axis it
    /// *duplicates* weights and splits vectors (Sec. IV-C: "duplicates it
    /// for feature loops").
    Spatial { dim: usize },
}

/// One loop level: axis, trip count, binding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Loop {
    pub axis: LoopAxis,
    pub trips: usize,
    pub binding: Binding,
}

/// An ordered loopnest (outermost first).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Loopnest {
    pub loops: Vec<Loop>,
}

impl Loopnest {
    /// Validate against an organization: each org dim bound at most once,
    /// spatial trip counts need not divide the org dim (partial use), and
    /// every axis appears at most once.
    pub fn validate(&self, org: &MacroOrg) -> anyhow::Result<()> {
        let mut seen_axes = std::collections::BTreeSet::new();
        let mut dim_users: Vec<Vec<LoopAxis>> = vec![Vec::new(); 2];
        for l in &self.loops {
            if !seen_axes.insert(l.axis) {
                anyhow::bail!("axis {:?} bound twice", l.axis);
            }
            if l.trips == 0 {
                anyhow::bail!("axis {:?} has zero trip count", l.axis);
            }
            if let Binding::Spatial { dim } = l.binding {
                if dim >= org.dims.len() {
                    anyhow::bail!(
                        "axis {:?} bound to org dim {dim}, but organization has {} dims",
                        l.axis,
                        org.dims.len()
                    );
                }
                dim_users[dim].push(l.axis);
            }
        }
        for (dim, users) in dim_users.iter().enumerate() {
            if users.len() > 1 {
                anyhow::bail!("org dim {dim} bound by multiple axes: {users:?}");
            }
        }
        // bit loop must be temporal (bit-serial by construction)
        if let Some(l) = self.loops.iter().find(|l| l.axis == LoopAxis::Bit) {
            if l.binding != Binding::Temporal {
                anyhow::bail!("bit-serial loop must be temporal");
            }
        }
        Ok(())
    }

    /// Temporal trip-count product (sequential rounds).
    pub fn temporal_rounds(&self) -> usize {
        self.loops
            .iter()
            .filter(|l| l.binding == Binding::Temporal && l.axis != LoopAxis::Bit && l.axis != LoopAxis::Vector)
            .map(|l| l.trips)
            .product()
    }

    /// Render like the paper's Fig. 5(c) mapping description.
    pub fn describe(&self) -> String {
        let mut out = String::new();
        for (depth, l) in self.loops.iter().enumerate() {
            let b = match l.binding {
                Binding::Temporal => "temporal".to_string(),
                Binding::Spatial { dim } => format!("spatial@org[{dim}]"),
            };
            out.push_str(&format!(
                "{}for {} in 0..{} ({b})\n",
                "  ".repeat(depth),
                l.axis.label(),
                l.trips
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn org() -> MacroOrg {
        MacroOrg::grid(4, 4)
    }

    fn nest(loops: Vec<Loop>) -> Loopnest {
        Loopnest { loops }
    }

    #[test]
    fn valid_spatial_nest() {
        let n = nest(vec![
            Loop { axis: LoopAxis::RowTile, trips: 8, binding: Binding::Spatial { dim: 0 } },
            Loop { axis: LoopAxis::ColTile, trips: 2, binding: Binding::Spatial { dim: 1 } },
            Loop { axis: LoopAxis::Vector, trips: 256, binding: Binding::Temporal },
            Loop { axis: LoopAxis::Bit, trips: 8, binding: Binding::Temporal },
        ]);
        n.validate(&org()).unwrap();
        assert_eq!(n.temporal_rounds(), 1);
    }

    #[test]
    fn duplication_nest_binds_vectors_spatially() {
        let n = nest(vec![
            Loop { axis: LoopAxis::RowTile, trips: 4, binding: Binding::Spatial { dim: 0 } },
            Loop { axis: LoopAxis::ColTile, trips: 3, binding: Binding::Temporal },
            Loop { axis: LoopAxis::Vector, trips: 4, binding: Binding::Spatial { dim: 1 } },
            Loop { axis: LoopAxis::Bit, trips: 8, binding: Binding::Temporal },
        ]);
        n.validate(&org()).unwrap();
        assert_eq!(n.temporal_rounds(), 3);
    }

    #[test]
    fn rejects_double_binding_of_org_dim() {
        let n = nest(vec![
            Loop { axis: LoopAxis::RowTile, trips: 4, binding: Binding::Spatial { dim: 0 } },
            Loop { axis: LoopAxis::ColTile, trips: 4, binding: Binding::Spatial { dim: 0 } },
        ]);
        assert!(n.validate(&org()).is_err());
    }

    #[test]
    fn rejects_spatial_bit_loop() {
        let n = nest(vec![Loop {
            axis: LoopAxis::Bit,
            trips: 8,
            binding: Binding::Spatial { dim: 0 },
        }]);
        assert!(n.validate(&org()).is_err());
    }

    #[test]
    fn rejects_duplicate_axis_and_bad_dim() {
        let n = nest(vec![
            Loop { axis: LoopAxis::Vector, trips: 8, binding: Binding::Temporal },
            Loop { axis: LoopAxis::Vector, trips: 8, binding: Binding::Temporal },
        ]);
        assert!(n.validate(&org()).is_err());
        let n2 = nest(vec![Loop {
            axis: LoopAxis::RowTile,
            trips: 2,
            binding: Binding::Spatial { dim: 5 },
        }]);
        assert!(n2.validate(&org()).is_err());
    }

    #[test]
    fn describe_is_indented() {
        let n = nest(vec![
            Loop { axis: LoopAxis::RowTile, trips: 2, binding: Binding::Spatial { dim: 0 } },
            Loop { axis: LoopAxis::Bit, trips: 8, binding: Binding::Temporal },
        ]);
        let d = n.describe();
        assert!(d.contains("for row_tile in 0..2 (spatial@org[0])"));
        assert!(d.contains("  for bit in 0..8 (temporal)"));
    }
}
