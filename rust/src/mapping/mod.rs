//! Mapping description layer (Sec. IV-C Mapping): data reshaping,
//! rearrangement, tiling onto the macro grid, loopnest binding, and the
//! per-network mapping planner with functional verification.

pub mod duplication;
pub mod loopnest;
pub mod planner;
pub mod rearrange;
pub mod reshape;
pub mod tiling;

pub use duplication::{Strategy, StrategyPolicy};
pub use loopnest::{Binding, Loop, LoopAxis, Loopnest};
pub use planner::{
    plan, plan_with_faults, FaultPlanSummary, MappingOptions, MappingPlan, OpMapping,
};
pub use rearrange::{rearrange, Rearranged};
pub use reshape::Flattening;
pub use tiling::{tile_op, MacroTile, OpTiling, Round};
