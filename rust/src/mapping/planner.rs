//! The mapping planner: binds workload + sparsity + architecture into a
//! per-op executable mapping (compressed layout → rearrangement → tiling
//! → loopnest), performing the functional verification of Sec. IV-B
//! (hardware/workload/mapping consistency) before simulation.

use super::duplication::{Strategy, StrategyPolicy};
use super::loopnest::{Binding, Loop, LoopAxis, Loopnest};
use super::rearrange::rearrange;
use super::reshape::Flattening;
use super::tiling::{tile_op, OpTiling};
use crate::hw::arch::Architecture;
use crate::pruning::workflow::PrunePlan;
use crate::sparsity::compress::{compress, CompressedLayout};
use crate::sparsity::flexblock::FlexBlock;
use crate::sparsity::index::{index_storage, IndexStorage};
use crate::workload::graph::Network;
use crate::workload::op::{MvmDims, OpId};
use std::collections::BTreeMap;

/// User-facing mapping options (the mapping description's knobs).
#[derive(Debug, Clone, Copy)]
pub struct MappingOptions {
    pub policy: StrategyPolicy,
    pub flattening: Flattening,
    /// Equalize ragged compressed matrices (Fig. 12).
    pub rearrange: bool,
    /// Slice width for rearrangement.
    pub rearrange_slice: usize,
}

impl Default for MappingOptions {
    fn default() -> Self {
        Self {
            policy: StrategyPolicy::Auto,
            flattening: Flattening::ChannelMajor,
            rearrange: false,
            rearrange_slice: 16,
        }
    }
}

/// One MVM op's complete mapping.
#[derive(Debug, Clone)]
pub struct OpMapping {
    pub op: OpId,
    pub name: String,
    pub dims: MvmDims,
    pub fb: FlexBlock,
    pub layout: CompressedLayout,
    pub tiling: OpTiling,
    pub strategy: Strategy,
    pub index: IndexStorage,
    pub rearrange_moved_bytes: u64,
    pub loopnest: Loopnest,
}

/// Whole-network mapping.
#[derive(Debug, Clone)]
pub struct MappingPlan {
    pub arch_name: String,
    pub ops: BTreeMap<OpId, OpMapping>,
}

impl MappingPlan {
    /// Mean array utilization across MVM ops (round-weighted).
    pub fn mean_utilization(&self) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for m in self.ops.values() {
            let w = m.tiling.rounds.len().max(1) as f64;
            num += m.tiling.utilization * w;
            den += w;
        }
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    }

    /// Total index-memory bytes required (sizes the index memories).
    pub fn total_index_bytes(&self) -> u64 {
        self.ops.values().map(|m| m.index.total_bytes()).sum()
    }
}

/// Build the mapping plan, verifying hardware support for every
/// sparsity feature the workload needs.
pub fn plan(
    arch: &Architecture,
    net: &Network,
    prune: Option<&PrunePlan>,
    opts: MappingOptions,
) -> anyhow::Result<MappingPlan> {
    arch.validate()?;
    let spatial_capacity_cells = (arch.org.n_macros() * arch.cim.capacity_words()) as f64;
    let mut ops = BTreeMap::new();
    for id in net.mvm_ops() {
        let dims = net
            .mvm_dims(id)
            .ok_or_else(|| anyhow::anyhow!("op {id} lost its MVM dims"))?;
        let op_name = net.ops[id].name.clone();
        let ctx = opts.flattening.layer_ctx(net, id);
        let lp = prune.and_then(|p| p.mask_for(id));
        let (fb, mut layout) = match lp {
            Some(lp) => {
                let layout = compress(&lp.fb, &lp.mask, ctx);
                (lp.fb.clone(), layout)
            }
            None => (
                FlexBlock::dense(),
                CompressedLayout::dense(dims.rows, dims.cols),
            ),
        };

        // ---- functional verification (Sec. IV-B) ----
        if !fb.is_dense() {
            if !arch.sparsity.weight_indexing {
                anyhow::bail!(
                    "op `{op_name}`: FlexBlock `{}` requires weight index support, \
                     but architecture `{}` has none",
                    fb.name,
                    arch.name
                );
            }
            if layout.routed_rows && !arch.sparsity.weight_routing {
                anyhow::bail!(
                    "op `{op_name}`: pattern `{}` needs mux-based input routing, \
                     but architecture `{}` lacks routing units",
                    fb.name,
                    arch.name
                );
            }
        }

        // ---- rearrangement ----
        let mut moved = 0u64;
        if opts.rearrange && !fb.is_dense() {
            let r = rearrange(&layout, opts.rearrange_slice, arch.weight_bits);
            moved = r.moved_bytes;
            layout = r.layout;
            if layout.routed_rows && !arch.sparsity.weight_routing {
                anyhow::bail!(
                    "op `{op_name}`: rearrangement requires input routing support"
                );
            }
        }

        // ---- strategy + tiling ----
        let fit = (layout.comp_rows * layout.comp_cols) as f64 * dims.groups as f64
            / spatial_capacity_cells;
        let strategy = opts.policy.resolve(&dims, fit);
        let tiling = tile_op(arch, &dims, &layout, strategy);
        let index = index_storage(&fb, &layout, ctx);

        // ---- loopnest description ----
        let mut loops = vec![Loop {
            axis: LoopAxis::RowTile,
            trips: tiling.tiles_r,
            binding: Binding::Spatial { dim: 0 },
        }];
        match strategy {
            Strategy::Spatial => {
                loops.push(Loop {
                    axis: LoopAxis::ColTile,
                    trips: tiling.tiles_c,
                    binding: Binding::Spatial { dim: 1 },
                });
                loops.push(Loop {
                    axis: LoopAxis::Vector,
                    trips: dims.n_vectors,
                    binding: Binding::Temporal,
                });
            }
            Strategy::Duplicate => {
                loops.push(Loop {
                    axis: LoopAxis::ColTile,
                    trips: tiling.tiles_c,
                    binding: Binding::Temporal,
                });
                loops.push(Loop {
                    axis: LoopAxis::Vector,
                    trips: arch.org.col_dim(),
                    binding: Binding::Spatial { dim: 1 },
                });
            }
        }
        if dims.groups > 1 {
            loops.push(Loop {
                axis: LoopAxis::Group,
                trips: dims.groups.div_ceil(tiling.groups_per_tile),
                binding: Binding::Temporal,
            });
        }
        loops.push(Loop {
            axis: LoopAxis::Bit,
            trips: arch.input_bits,
            binding: Binding::Temporal,
        });
        let loopnest = Loopnest { loops };
        loopnest.validate(&arch.org)?;

        ops.insert(
            id,
            OpMapping {
                op: id,
                name: op_name,
                dims,
                fb,
                layout,
                tiling,
                strategy,
                index,
                rearrange_moved_bytes: moved,
                loopnest,
            },
        );
    }
    Ok(MappingPlan {
        arch_name: arch.name.clone(),
        ops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::pruning::workflow::PruningWorkflow;
    use crate::workload::zoo;

    #[test]
    fn dense_plan_covers_all_mvm_ops() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let p = plan(&arch, &net, None, MappingOptions::default()).unwrap();
        assert_eq!(p.ops.len(), net.mvm_ops().len());
        for m in p.ops.values() {
            assert!(!m.tiling.rounds.is_empty(), "{}", m.name);
            assert!(m.index.total_bits() == 0);
        }
    }

    #[test]
    fn sparse_plan_requires_support() {
        let mut arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let wf = PruningWorkflow::default();
        let pp = wf
            .run_uniform(&net, &FlexBlock::intra(2, 0.5), None)
            .unwrap();
        // full support: ok
        assert!(plan(&arch, &net, Some(&pp), MappingOptions::default()).is_ok());
        // no routing: intra patterns must be rejected
        arch.sparsity.weight_routing = false;
        assert!(plan(&arch, &net, Some(&pp), MappingOptions::default()).is_err());
        // no indexing at all: any sparsity rejected
        arch.sparsity.weight_indexing = false;
        let pp2 = wf
            .run_uniform(&net, &FlexBlock::row_wise(0.5), None)
            .unwrap();
        assert!(plan(&arch, &net, Some(&pp2), MappingOptions::default()).is_err());
    }

    #[test]
    fn sparsity_reduces_rounds_vs_dense() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::vgg16(32, 100);
        let wf = PruningWorkflow::default();
        let pp = wf
            .run_uniform(&net, &FlexBlock::row_wise(0.8), None)
            .unwrap();
        let dense = plan(&arch, &net, None, MappingOptions::default()).unwrap();
        let sparse = plan(&arch, &net, Some(&pp), MappingOptions::default()).unwrap();
        let rounds = |p: &MappingPlan| -> usize {
            p.ops.values().map(|m| m.tiling.rounds.len()).sum()
        };
        assert!(
            rounds(&sparse) < rounds(&dense),
            "sparse {} vs dense {}",
            rounds(&sparse),
            rounds(&dense)
        );
    }

    #[test]
    fn rearrangement_improves_utilization() {
        let arch = presets::usecase_arch(16, (4, 4));
        let net = zoo::resnet50(32, 100);
        let wf = PruningWorkflow::default();
        let pp = wf
            .run_uniform(&net, &FlexBlock::hybrid(2, 16, 0.8), None)
            .unwrap();
        let base = plan(&arch, &net, Some(&pp), MappingOptions::default()).unwrap();
        let rearr = plan(
            &arch,
            &net,
            Some(&pp),
            MappingOptions {
                rearrange: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            rearr.mean_utilization() > base.mean_utilization(),
            "rearranged {} <= base {}",
            rearr.mean_utilization(),
            base.mean_utilization()
        );
        let moved: u64 = rearr.ops.values().map(|m| m.rearrange_moved_bytes).sum();
        assert!(moved > 0, "rearrangement moved data");
    }

    #[test]
    fn duplication_policy_affects_fc_and_conv_differently() {
        let arch = presets::usecase_arch(16, (4, 4));
        let net = zoo::vgg_mini();
        let wf = PruningWorkflow::default();
        let pp = wf
            .run_uniform(&net, &FlexBlock::row_wise(0.8), None)
            .unwrap();
        let p = plan(&arch, &net, Some(&pp), MappingOptions::default()).unwrap();
        let mut saw_conv_dup = false;
        for m in p.ops.values() {
            if matches!(
                net.ops[m.op].kind,
                crate::workload::op::OpKind::Fc { .. }
            ) {
                assert_eq!(m.strategy, Strategy::Spatial, "FC stays spatial");
            } else if m.strategy == Strategy::Duplicate {
                saw_conv_dup = true;
            }
        }
        assert!(saw_conv_dup, "some conv got duplicated");
    }

    #[test]
    fn index_bytes_grow_with_finer_patterns() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let wf = PruningWorkflow::default();
        let coarse = wf
            .run_uniform(&net, &FlexBlock::row_wise(0.8), None)
            .unwrap();
        let fine = wf
            .run_uniform(&net, &FlexBlock::hybrid(2, 16, 0.8), None)
            .unwrap();
        let pc = plan(&arch, &net, Some(&coarse), MappingOptions::default()).unwrap();
        let pf = plan(&arch, &net, Some(&fine), MappingOptions::default()).unwrap();
        assert!(pf.total_index_bytes() > pc.total_index_bytes());
    }
}
