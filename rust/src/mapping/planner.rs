//! The mapping planner: binds workload + sparsity + architecture into a
//! per-op executable mapping (compressed layout → rearrangement → tiling
//! → loopnest), performing the functional verification of Sec. IV-B
//! (hardware/workload/mapping consistency) before simulation.

use super::duplication::{Strategy, StrategyPolicy};
use super::loopnest::{Binding, Loop, LoopAxis, Loopnest};
use super::rearrange::rearrange;
use super::reshape::Flattening;
use super::tiling::{mean_utilization, tile_op, MacroTile, OpTiling, Round};
use crate::hw::arch::Architecture;
use crate::hw::cim_macro::CimMacro;
use crate::hw::faults::FaultMap;
use crate::pruning::workflow::PrunePlan;
use crate::sparsity::compress::{compress, CompressedLayout};
use crate::sparsity::flexblock::FlexBlock;
use crate::sparsity::index::{index_storage, IndexStorage};
use crate::workload::graph::Network;
use crate::workload::op::{MvmDims, OpId};
use std::collections::BTreeMap;

/// User-facing mapping options (the mapping description's knobs).
#[derive(Debug, Clone, Copy)]
pub struct MappingOptions {
    pub policy: StrategyPolicy,
    pub flattening: Flattening,
    /// Equalize ragged compressed matrices (Fig. 12).
    pub rearrange: bool,
    /// Slice width for rearrangement.
    pub rearrange_slice: usize,
}

impl Default for MappingOptions {
    fn default() -> Self {
        Self {
            policy: StrategyPolicy::Auto,
            flattening: Flattening::ChannelMajor,
            rearrange: false,
            rearrange_slice: 16,
        }
    }
}

/// One MVM op's complete mapping.
#[derive(Debug, Clone)]
pub struct OpMapping {
    pub op: OpId,
    pub name: String,
    pub dims: MvmDims,
    pub fb: FlexBlock,
    pub layout: CompressedLayout,
    pub tiling: OpTiling,
    pub strategy: Strategy,
    pub index: IndexStorage,
    pub rearrange_moved_bytes: u64,
    /// Weight bytes relocated off faulty rows/columns/macros (repair
    /// writes); 0 on the fault-free path.
    pub fault_moved_bytes: u64,
    pub loopnest: Loopnest,
}

/// Degradation bookkeeping attached to a plan built against a faulty
/// chip: what capacity was lost and what it cost the schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlanSummary {
    pub total_macros: usize,
    pub usable_macros: usize,
    /// Fault-free macro geometry (rows, cols).
    pub full_geometry: (usize, usize),
    /// Common usable geometry after quarantine, sub-array aligned.
    pub effective_geometry: (usize, usize),
    /// Exact fraction of weight capacity lost (before alignment).
    pub capacity_loss: f64,
    /// Fraction of capacity remapped onto spare rows/columns: costs
    /// repair writes but no capacity (0 without spare budgets).
    pub repair_fraction: f64,
    /// Total rounds the same (layout, strategy) choices would need on
    /// the fault-free chip.
    pub baseline_rounds: u64,
    /// Total rounds after degradation (shrunken tiles + spilled macros).
    pub degraded_rounds: u64,
    /// Total repair-write bytes across all ops.
    pub repair_bytes: u64,
}

impl FaultPlanSummary {
    /// Extra temporal rounds forced by the faults.
    pub fn extra_rounds(&self) -> u64 {
        self.degraded_rounds.saturating_sub(self.baseline_rounds)
    }
}

/// Whole-network mapping.
#[derive(Debug, Clone)]
pub struct MappingPlan {
    pub arch_name: String,
    pub ops: BTreeMap<OpId, OpMapping>,
    /// Present when the plan was built against a damaged chip.
    pub faults: Option<FaultPlanSummary>,
}

impl MappingPlan {
    /// Mean array utilization across MVM ops (round-weighted).
    pub fn mean_utilization(&self) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for m in self.ops.values() {
            let w = m.tiling.rounds.len().max(1) as f64;
            num += m.tiling.utilization * w;
            den += w;
        }
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    }

    /// Total index-memory bytes required (sizes the index memories).
    pub fn total_index_bytes(&self) -> u64 {
        self.ops.values().map(|m| m.index.total_bytes()).sum()
    }
}

/// Build the mapping plan, verifying hardware support for every
/// sparsity feature the workload needs. If the architecture carries a
/// non-zero [`crate::hw::faults::FaultModel`], the concrete fault map is
/// instantiated from its seed and the plan degrades gracefully around
/// the damage (see [`plan_with_faults`]).
pub fn plan(
    arch: &Architecture,
    net: &Network,
    prune: Option<&PrunePlan>,
    opts: MappingOptions,
) -> anyhow::Result<MappingPlan> {
    let fmap = if arch.faults.is_zero() {
        None
    } else {
        Some(arch.faults.instantiate(&arch.cim, &arch.org))
    };
    plan_with_faults(arch, net, prune, opts, fmap.as_ref())
}

/// The degraded usable hardware derived from a fault map.
struct Degradation {
    /// Architecture clone with the common usable macro geometry.
    arch: Architecture,
    usable_macros: usize,
    capacity_loss: f64,
    repair_fraction: f64,
    effective_geometry: (usize, usize),
}

/// Split rounds that schedule more tiles than there are surviving
/// macros into `ceil(k/usable)` sub-rounds (spilling the overflow into
/// extra temporal passes). Round totals (weight bytes, outputs, input
/// rows) are conserved exactly: each chunk takes its occupancy-weighted
/// share and the last chunk absorbs the rounding remainder.
fn split_rounds(rounds: Vec<Round>, usable: usize) -> Vec<Round> {
    let mut out = Vec::with_capacity(rounds.len());
    for r in rounds {
        let k = r.tiles.len();
        if k <= usable {
            out.push(r);
            continue;
        }
        let total_occ = r.occupied_cells().max(1);
        let n_chunks = k.div_ceil(usable);
        let (mut rem_bytes, mut rem_out, mut rem_in) = (r.weight_bytes, r.outputs, r.input_rows);
        let mut idx = 0usize;
        for ci in 0..n_chunks {
            let take = usable.min(k - idx);
            let chunk: Vec<MacroTile> = r.tiles[idx..idx + take].to_vec();
            idx += take;
            let (bytes, outs, ins) = if ci + 1 == n_chunks {
                (rem_bytes, rem_out, rem_in)
            } else {
                let occ: u64 = chunk.iter().map(|t| t.occupied).sum();
                let b = r.weight_bytes * occ / total_occ;
                let o = r.outputs * occ / total_occ;
                let i = r.input_rows * occ / total_occ;
                rem_bytes -= b;
                rem_out -= o;
                rem_in -= i;
                (b, o, i)
            };
            out.push(Round {
                tiles: chunk,
                vectors_per_macro: r.vectors_per_macro,
                weight_bytes: bytes,
                outputs: outs,
                input_rows: ins,
            });
        }
    }
    out
}

/// Build the mapping plan against an explicit fault map. `None` or a
/// clean map takes exactly the fault-free path (bit-identical plans).
///
/// With faults present, every op is tiled against the common usable
/// geometry (sub-array aligned minimum over surviving macros), rounds
/// that need more macros than survive are split into extra temporal
/// passes, utilization is re-scored against the *full* geometry so dead
/// silicon registers as loss, and the weight bytes displaced from
/// faulty regions are recorded as repair writes for the simulator.
pub fn plan_with_faults(
    arch: &Architecture,
    net: &Network,
    prune: Option<&PrunePlan>,
    opts: MappingOptions,
    faults: Option<&FaultMap>,
) -> anyhow::Result<MappingPlan> {
    arch.validate()?;
    plan_with_faults_unchecked(arch, net, prune, opts, faults)
}

/// [`plan`] for callers that have already validated the architecture —
/// the `eval::Evaluator` hoists `arch.validate()` out of the per-point
/// path and pays it once per distinct architecture instead.
pub(crate) fn plan_prevalidated(
    arch: &Architecture,
    net: &Network,
    prune: Option<&PrunePlan>,
    opts: MappingOptions,
) -> anyhow::Result<MappingPlan> {
    debug_assert!(
        arch.validate().is_ok(),
        "plan_prevalidated() expects a pre-validated architecture"
    );
    let fmap = if arch.faults.is_zero() {
        None
    } else {
        Some(arch.faults.instantiate(&arch.cim, &arch.org))
    };
    plan_with_faults_unchecked(arch, net, prune, opts, fmap.as_ref())
}

fn plan_with_faults_unchecked(
    arch: &Architecture,
    net: &Network,
    prune: Option<&PrunePlan>,
    opts: MappingOptions,
    faults: Option<&FaultMap>,
) -> anyhow::Result<MappingPlan> {
    let deg = match faults {
        // spare-repaired damage keeps full geometry but still owes
        // repair writes, so it takes the degradation path too
        Some(f) if !f.is_clean() || f.has_repairs() => {
            let (eff_r, eff_c) = f.effective_geometry();
            let usable = f.usable_macros();
            if usable == 0 || eff_r == 0 || eff_c == 0 {
                anyhow::bail!(
                    "architecture `{}` is unusable under the injected faults: \
                     {usable}/{} macros alive, effective array {eff_r}x{eff_c} \
                     (full {}x{})",
                    arch.name,
                    arch.org.n_macros(),
                    arch.cim.rows,
                    arch.cim.cols
                );
            }
            let mut darch = arch.clone();
            darch.cim = CimMacro::new(eff_r, eff_c, arch.cim.sub_rows, arch.cim.sub_cols);
            darch.validate()?;
            Some(Degradation {
                arch: darch,
                usable_macros: usable,
                capacity_loss: f.capacity_loss(),
                repair_fraction: f.repair_fraction(),
                effective_geometry: (eff_r, eff_c),
            })
        }
        _ => None,
    };
    let tile_arch: &Architecture = deg.as_ref().map(|d| &d.arch).unwrap_or(arch);
    let spatial_capacity_cells = match &deg {
        Some(d) => (d.usable_macros * tile_arch.cim.capacity_words()) as f64,
        None => (arch.org.n_macros() * arch.cim.capacity_words()) as f64,
    };
    let mut baseline_rounds = 0u64;
    let mut degraded_rounds = 0u64;
    let mut repair_bytes = 0u64;
    let mut ops = BTreeMap::new();
    for id in net.mvm_ops() {
        let dims = net
            .mvm_dims(id)
            .ok_or_else(|| anyhow::anyhow!("op {id} lost its MVM dims"))?;
        let op_name = net.ops[id].name.clone();
        let ctx = opts.flattening.layer_ctx(net, id);
        let lp = prune.and_then(|p| p.mask_for(id));
        let (fb, mut layout) = match lp {
            Some(lp) => {
                let layout = compress(&lp.fb, &lp.mask, ctx);
                (lp.fb.clone(), layout)
            }
            None => (
                FlexBlock::dense(),
                CompressedLayout::dense(dims.rows, dims.cols),
            ),
        };

        // ---- functional verification (Sec. IV-B) ----
        if !fb.is_dense() {
            if !arch.sparsity.weight_indexing {
                anyhow::bail!(
                    "op `{op_name}`: FlexBlock `{}` requires weight index support, \
                     but architecture `{}` has none",
                    fb.name,
                    arch.name
                );
            }
            if layout.routed_rows && !arch.sparsity.weight_routing {
                anyhow::bail!(
                    "op `{op_name}`: pattern `{}` needs mux-based input routing, \
                     but architecture `{}` lacks routing units",
                    fb.name,
                    arch.name
                );
            }
        }

        // ---- rearrangement ----
        let mut moved = 0u64;
        if opts.rearrange && !fb.is_dense() {
            let r = rearrange(&layout, opts.rearrange_slice, arch.weight_bits);
            moved = r.moved_bytes;
            layout = r.layout;
            if layout.routed_rows && !arch.sparsity.weight_routing {
                anyhow::bail!(
                    "op `{op_name}`: rearrangement requires input routing support"
                );
            }
        }

        // ---- strategy + tiling ----
        let fit = (layout.comp_rows * layout.comp_cols) as f64 * dims.groups as f64
            / spatial_capacity_cells;
        let strategy = opts.policy.resolve(&dims, fit);
        let mut tiling = tile_op(tile_arch, &dims, &layout, strategy);
        let mut fault_moved = 0u64;
        if let Some(d) = &deg {
            // what the same choices would have cost on the healthy chip
            baseline_rounds += tile_op(arch, &dims, &layout, strategy).rounds.len() as u64;
            // spill tiles that no longer have a live macro into extra rounds
            tiling.rounds = split_rounds(std::mem::take(&mut tiling.rounds), d.usable_macros);
            // score occupancy against the FULL geometry: dead macros and
            // quarantined rows register as utilization loss
            tiling.utilization = mean_utilization(
                &tiling.rounds,
                arch.org.n_macros(),
                arch.cim.rows,
                arch.cim.cols,
            );
            degraded_rounds += tiling.rounds.len() as u64;
            // weights displaced from faulty cells are re-staged through
            // the weight buffer: charge the lost-capacity share of this
            // op's weight traffic, plus the share remapped onto spare
            // rows/columns, as repair writes
            let op_weight_bytes: u64 = tiling.rounds.iter().map(|r| r.weight_bytes).sum();
            fault_moved =
                (op_weight_bytes as f64 * (d.capacity_loss + d.repair_fraction)).ceil() as u64;
            repair_bytes += fault_moved;
        }
        let index = index_storage(&fb, &layout, ctx);

        // ---- loopnest description ----
        let mut loops = vec![Loop {
            axis: LoopAxis::RowTile,
            trips: tiling.tiles_r,
            binding: Binding::Spatial { dim: 0 },
        }];
        match strategy {
            Strategy::Spatial => {
                loops.push(Loop {
                    axis: LoopAxis::ColTile,
                    trips: tiling.tiles_c,
                    binding: Binding::Spatial { dim: 1 },
                });
                loops.push(Loop {
                    axis: LoopAxis::Vector,
                    trips: dims.n_vectors,
                    binding: Binding::Temporal,
                });
            }
            Strategy::Duplicate => {
                loops.push(Loop {
                    axis: LoopAxis::ColTile,
                    trips: tiling.tiles_c,
                    binding: Binding::Temporal,
                });
                loops.push(Loop {
                    axis: LoopAxis::Vector,
                    trips: arch.org.col_dim(),
                    binding: Binding::Spatial { dim: 1 },
                });
            }
        }
        if dims.groups > 1 {
            loops.push(Loop {
                axis: LoopAxis::Group,
                trips: dims.groups.div_ceil(tiling.groups_per_tile),
                binding: Binding::Temporal,
            });
        }
        loops.push(Loop {
            axis: LoopAxis::Bit,
            trips: arch.input_bits,
            binding: Binding::Temporal,
        });
        let loopnest = Loopnest { loops };
        loopnest.validate(&arch.org)?;

        ops.insert(
            id,
            OpMapping {
                op: id,
                name: op_name,
                dims,
                fb,
                layout,
                tiling,
                strategy,
                index,
                rearrange_moved_bytes: moved,
                fault_moved_bytes: fault_moved,
                loopnest,
            },
        );
    }
    Ok(MappingPlan {
        arch_name: arch.name.clone(),
        ops,
        faults: deg.as_ref().map(|d| FaultPlanSummary {
            total_macros: arch.org.n_macros(),
            usable_macros: d.usable_macros,
            full_geometry: (arch.cim.rows, arch.cim.cols),
            effective_geometry: d.effective_geometry,
            capacity_loss: d.capacity_loss,
            repair_fraction: d.repair_fraction,
            baseline_rounds,
            degraded_rounds,
            repair_bytes,
        }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::pruning::workflow::PruningWorkflow;
    use crate::workload::zoo;

    #[test]
    fn dense_plan_covers_all_mvm_ops() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let p = plan(&arch, &net, None, MappingOptions::default()).unwrap();
        assert_eq!(p.ops.len(), net.mvm_ops().len());
        for m in p.ops.values() {
            assert!(!m.tiling.rounds.is_empty(), "{}", m.name);
            assert!(m.index.total_bits() == 0);
        }
    }

    #[test]
    fn sparse_plan_requires_support() {
        let mut arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let wf = PruningWorkflow::default();
        let pp = wf
            .run_uniform(&net, &FlexBlock::intra(2, 0.5), None)
            .unwrap();
        // full support: ok
        assert!(plan(&arch, &net, Some(&pp), MappingOptions::default()).is_ok());
        // no routing: intra patterns must be rejected
        arch.sparsity.weight_routing = false;
        assert!(plan(&arch, &net, Some(&pp), MappingOptions::default()).is_err());
        // no indexing at all: any sparsity rejected
        arch.sparsity.weight_indexing = false;
        let pp2 = wf
            .run_uniform(&net, &FlexBlock::row_wise(0.5), None)
            .unwrap();
        assert!(plan(&arch, &net, Some(&pp2), MappingOptions::default()).is_err());
    }

    #[test]
    fn sparsity_reduces_rounds_vs_dense() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::vgg16(32, 100);
        let wf = PruningWorkflow::default();
        let pp = wf
            .run_uniform(&net, &FlexBlock::row_wise(0.8), None)
            .unwrap();
        let dense = plan(&arch, &net, None, MappingOptions::default()).unwrap();
        let sparse = plan(&arch, &net, Some(&pp), MappingOptions::default()).unwrap();
        let rounds = |p: &MappingPlan| -> usize {
            p.ops.values().map(|m| m.tiling.rounds.len()).sum()
        };
        assert!(
            rounds(&sparse) < rounds(&dense),
            "sparse {} vs dense {}",
            rounds(&sparse),
            rounds(&dense)
        );
    }

    #[test]
    fn rearrangement_improves_utilization() {
        let arch = presets::usecase_arch(16, (4, 4));
        let net = zoo::resnet50(32, 100);
        let wf = PruningWorkflow::default();
        let pp = wf
            .run_uniform(&net, &FlexBlock::hybrid(2, 16, 0.8), None)
            .unwrap();
        let base = plan(&arch, &net, Some(&pp), MappingOptions::default()).unwrap();
        let rearr = plan(
            &arch,
            &net,
            Some(&pp),
            MappingOptions {
                rearrange: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            rearr.mean_utilization() > base.mean_utilization(),
            "rearranged {} <= base {}",
            rearr.mean_utilization(),
            base.mean_utilization()
        );
        let moved: u64 = rearr.ops.values().map(|m| m.rearrange_moved_bytes).sum();
        assert!(moved > 0, "rearrangement moved data");
    }

    #[test]
    fn duplication_policy_affects_fc_and_conv_differently() {
        let arch = presets::usecase_arch(16, (4, 4));
        let net = zoo::vgg_mini();
        let wf = PruningWorkflow::default();
        let pp = wf
            .run_uniform(&net, &FlexBlock::row_wise(0.8), None)
            .unwrap();
        let p = plan(&arch, &net, Some(&pp), MappingOptions::default()).unwrap();
        let mut saw_conv_dup = false;
        for m in p.ops.values() {
            if matches!(
                net.ops[m.op].kind,
                crate::workload::op::OpKind::Fc { .. }
            ) {
                assert_eq!(m.strategy, Strategy::Spatial, "FC stays spatial");
            } else if m.strategy == Strategy::Duplicate {
                saw_conv_dup = true;
            }
        }
        assert!(saw_conv_dup, "some conv got duplicated");
    }

    #[test]
    fn clean_fault_map_matches_fault_free_plan() {
        use crate::hw::faults::FaultModel;
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let base = plan(&arch, &net, None, MappingOptions::default()).unwrap();
        let clean = FaultModel::none().instantiate(&arch.cim, &arch.org);
        let with = plan_with_faults(&arch, &net, None, MappingOptions::default(), Some(&clean))
            .unwrap();
        assert!(with.faults.is_none());
        assert_eq!(base.ops.len(), with.ops.len());
        for (a, b) in base.ops.values().zip(with.ops.values()) {
            assert_eq!(a.tiling, b.tiling, "{}", a.name);
            assert_eq!(b.fault_moved_bytes, 0);
        }
    }

    #[test]
    fn faulty_plan_spills_and_records_overhead() {
        use crate::hw::faults::MacroHealth;
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let base = plan(&arch, &net, None, MappingOptions::default()).unwrap();
        // Hand-built damage: one macro fused off and one with only 96
        // usable rows. The weakest survivor drags the common geometry to
        // 96x32 — below resnet_mini's largest layer (288 rows) — so the
        // plan must split rows into extra rounds, not just lose capacity.
        let healthy = MacroHealth {
            dead: false,
            lost_rows: 0,
            lost_cols: 0,
            repaired_rows: 0,
            repaired_cols: 0,
        };
        let fmap = FaultMap {
            macros: vec![
                MacroHealth { dead: true, ..healthy },
                MacroHealth {
                    lost_rows: arch.cim.rows - 96,
                    ..healthy
                },
                healthy,
                healthy,
            ],
            rows: arch.cim.rows,
            cols: arch.cim.cols,
            sub_rows: arch.cim.sub_rows,
            sub_cols: arch.cim.sub_cols,
        };
        let degraded =
            plan_with_faults(&arch, &net, None, MappingOptions::default(), Some(&fmap)).unwrap();
        let f = degraded.faults.as_ref().expect("degradation recorded");
        assert_eq!(f.total_macros, 4);
        assert_eq!(f.usable_macros, 3);
        assert_eq!(f.full_geometry, (arch.cim.rows, arch.cim.cols));
        assert_eq!(f.effective_geometry, (96, arch.cim.cols));
        let cell = |r: usize, c: usize| (r * c) as f64;
        let expected_loss = 1.0
            - (cell(96, arch.cim.cols) + 2.0 * cell(arch.cim.rows, arch.cim.cols))
                / (4.0 * cell(arch.cim.rows, arch.cim.cols));
        assert!((f.capacity_loss - expected_loss).abs() < 1e-12);
        // 288-row convs fit one round on the healthy chip but need >= 2
        // at 96 effective rows: the degradation must cost extra rounds.
        assert!(
            f.extra_rounds() > 0,
            "degraded {} vs baseline {}",
            f.degraded_rounds,
            f.baseline_rounds
        );
        assert!(f.repair_bytes > 0);
        let rounds = |p: &MappingPlan| -> usize {
            p.ops.values().map(|m| m.tiling.rounds.len()).sum()
        };
        assert!(rounds(&degraded) > rounds(&base));
        // occupancy is conserved but spread over strictly more rounds and
        // re-scored against the FULL geometry, so dead silicon must
        // register as a utilization drop
        assert!(degraded.mean_utilization() < base.mean_utilization());
    }

    #[test]
    fn repaired_only_damage_keeps_geometry_but_charges_repair_writes() {
        use crate::hw::faults::MacroHealth;
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let base = plan(&arch, &net, None, MappingOptions::default()).unwrap();
        // every lost row fit the spare budget: full geometry survives,
        // but the remapped weights still owe repair-write traffic
        let repaired = MacroHealth {
            dead: false,
            lost_rows: 0,
            lost_cols: 0,
            repaired_rows: 2,
            repaired_cols: 1,
        };
        let fmap = FaultMap {
            macros: vec![repaired; 4],
            rows: arch.cim.rows,
            cols: arch.cim.cols,
            sub_rows: arch.cim.sub_rows,
            sub_cols: arch.cim.sub_cols,
        };
        assert!(fmap.is_clean() && fmap.has_repairs());
        let p =
            plan_with_faults(&arch, &net, None, MappingOptions::default(), Some(&fmap)).unwrap();
        let f = p.faults.as_ref().expect("repairs recorded in the summary");
        assert_eq!(f.usable_macros, 4);
        assert_eq!(f.effective_geometry, f.full_geometry);
        assert_eq!(f.capacity_loss, 0.0);
        assert!(f.repair_fraction > 0.0);
        assert!(f.repair_bytes > 0);
        assert_eq!(f.extra_rounds(), 0, "no capacity lost, no spilled rounds");
        let rounds = |p: &MappingPlan| -> usize {
            p.ops.values().map(|m| m.tiling.rounds.len()).sum()
        };
        assert_eq!(rounds(&p), rounds(&base));
    }

    #[test]
    fn unusable_chip_is_rejected() {
        use crate::hw::faults::{FaultModel, FaultSpatial};
        let mut arch = presets::usecase_arch(4, (2, 2));
        arch.faults = FaultModel {
            seed: 1,
            stuck_cell_rate: 0.0,
            spatial: FaultSpatial::Uniform,
            dead_column_rate: 0.0,
            dead_macro_rate: 1.0,
            spare_rows: 0,
            spare_cols: 0,
        };
        let net = zoo::resnet_mini();
        let err = plan(&arch, &net, None, MappingOptions::default()).unwrap_err();
        assert!(err.to_string().contains("unusable"), "{err}");
    }

    #[test]
    fn split_rounds_conserves_totals() {
        let t = MacroTile {
            rows_used: 8,
            cols_used: 8,
            occupied: 64,
        };
        let r = Round {
            tiles: vec![t; 7],
            vectors_per_macro: 10,
            weight_bytes: 448,
            outputs: 560,
            input_rows: 56,
        };
        let split = split_rounds(vec![r.clone()], 3);
        assert_eq!(split.len(), 3); // ceil(7/3)
        assert_eq!(split.iter().map(|x| x.tiles.len()).sum::<usize>(), 7);
        assert_eq!(split.iter().map(|x| x.weight_bytes).sum::<u64>(), r.weight_bytes);
        assert_eq!(split.iter().map(|x| x.outputs).sum::<u64>(), r.outputs);
        assert_eq!(split.iter().map(|x| x.input_rows).sum::<u64>(), r.input_rows);
        for s in &split {
            assert!(s.tiles.len() <= 3);
            assert_eq!(s.vectors_per_macro, 10);
        }
        // rounds already fitting are untouched
        let untouched = split_rounds(vec![r.clone()], 7);
        assert_eq!(untouched.len(), 1);
        assert_eq!(untouched[0], r);
    }

    #[test]
    fn index_bytes_grow_with_finer_patterns() {
        let arch = presets::usecase_arch(4, (2, 2));
        let net = zoo::resnet_mini();
        let wf = PruningWorkflow::default();
        let coarse = wf
            .run_uniform(&net, &FlexBlock::row_wise(0.8), None)
            .unwrap();
        let fine = wf
            .run_uniform(&net, &FlexBlock::hybrid(2, 16, 0.8), None)
            .unwrap();
        let pc = plan(&arch, &net, Some(&coarse), MappingOptions::default()).unwrap();
        let pf = plan(&arch, &net, Some(&fine), MappingOptions::default()).unwrap();
        assert!(pf.total_index_bytes() > pc.total_index_bytes());
    }
}
