//! Data reshaping (Sec. IV-C Mapping ①): flattening sequence for
//! conv filters → 2-D matrices and the compression orientation.
//!
//! The reshaped orientation is fixed by the weight-stationary dataflow
//! (rows = input-patch dims on array rows, cols = output channels on
//! bitlines); the *flattening sequence* chooses the row ordering, which
//! determines which FlexBlock patterns align with contiguous row groups
//! (channel-major makes channel-wise pruning a contiguous row block).

use crate::sparsity::mask::LayerCtx;
use crate::workload::graph::Network;
use crate::workload::op::{OpId, OpKind};

/// Row-ordering of the flattened conv filter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flattening {
    /// (c, kh, kw): rows of one channel are contiguous (kh·kw rows per
    /// channel). Default; required for channel-wise FlexBlock binding.
    ChannelMajor,
    /// (kh, kw, c): spatial-major; channels interleave.
    SpatialMajor,
}

impl Flattening {
    /// Layer context for FlexBlock symbolic-dim binding under this
    /// flattening (per-channel contiguous rows or not).
    pub fn layer_ctx(&self, net: &Network, id: OpId) -> LayerCtx {
        match (&net.ops[id].kind, self) {
            (OpKind::Conv2d { kh, kw, .. }, Flattening::ChannelMajor) => LayerCtx {
                per_channel: kh * kw,
            },
            // spatial-major: channel rows are strided; a "channel block"
            // degenerates to single rows
            (OpKind::Conv2d { .. }, Flattening::SpatialMajor) => LayerCtx { per_channel: 1 },
            _ => LayerCtx::fc(),
        }
    }
}

/// Compression orientation (Sec. IV-C ①): which direction zero regions
/// are squeezed out of the reshaped matrix. Derived automatically from
/// the FlexBlock pattern by `sparsity::compress`; recorded here for the
/// mapping description.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressOrientation {
    RowWise,
    ColumnWise,
}

/// Weight bytes of an MVM op at `weight_bits` precision (per group,
/// all groups).
pub fn weight_bytes(net: &Network, id: OpId, weight_bits: usize) -> u64 {
    net.mvm_dims(id)
        .map(|d| d.params() * weight_bits as u64 / 8)
        .unwrap_or(0)
}

/// Input-feature bytes streamed through an MVM op (im2col vectors ×
/// patch length), at `input_bits` precision.
pub fn input_bytes(net: &Network, id: OpId, input_bits: usize) -> u64 {
    net.mvm_dims(id)
        .map(|d| (d.rows * d.n_vectors * d.groups) as u64 * input_bits as u64 / 8)
        .unwrap_or(0)
}

/// Output bytes produced by an MVM op (before post-processing), at
/// `input_bits` precision (outputs re-quantized to activation width).
pub fn output_bytes(net: &Network, id: OpId, input_bits: usize) -> u64 {
    net.mvm_dims(id)
        .map(|d| (d.cols * d.n_vectors * d.groups) as u64 * input_bits as u64 / 8)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn channel_major_ctx() {
        let net = zoo::resnet_mini();
        let conv_id = net.mvm_ops()[1]; // a 3x3 conv
        let ctx = Flattening::ChannelMajor.layer_ctx(&net, conv_id);
        assert_eq!(ctx.per_channel, 9);
        let ctx_s = Flattening::SpatialMajor.layer_ctx(&net, conv_id);
        assert_eq!(ctx_s.per_channel, 1);
    }

    #[test]
    fn byte_accounting() {
        let net = zoo::vgg_mini();
        let fc_id = *net.mvm_ops().last().unwrap(); // fc2: 128→10
        assert_eq!(weight_bytes(&net, fc_id, 8), 128 * 10);
        assert_eq!(input_bytes(&net, fc_id, 8), 128);
        assert_eq!(output_bytes(&net, fc_id, 8), 10);
        // 4-bit weights halve storage
        assert_eq!(weight_bytes(&net, fc_id, 4), 128 * 10 / 2);
    }
}
