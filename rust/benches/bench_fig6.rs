//! Fig. 6: validation against MARS and SDP — reported vs estimated
//! speedups/energy savings, SDP power breakdown, and the error margin.
use ciminus::report;
use ciminus::util::bench::{bench_header, Bencher};
use ciminus::validate::{error_stats, run_validation, sdp_power_breakdown};

fn main() {
    bench_header("Fig. 6 — validation vs MARS/SDP");
    let points = run_validation().expect("validation runs");
    println!("{}", report::fig6_table(&points).render());
    let (mean, max) = error_stats(&points);
    println!("margin: mean {mean:.2}% max {max:.2}% (paper: all within 5.27%)\n");
    let bd = sdp_power_breakdown().expect("breakdown");
    println!("{}", report::fig6c_table(&bd).render());
    let b = Bencher::quick();
    let s = b.run("full_validation_suite", || run_validation().unwrap().len());
    println!("{}", s.report_line());
}
