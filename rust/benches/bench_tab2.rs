//! Table II: FlexBlock representations of the named sparsity patterns,
//! plus mask-generation throughput per pattern.
use ciminus::report;
use ciminus::sparsity::mask::{random_mask, LayerCtx};
use ciminus::util::bench::{bench_header, Bencher};
use ciminus::util::rng::Pcg32;

fn main() {
    bench_header("Table II — FlexBlock representations");
    println!("{}", report::tab2().render());
    let b = Bencher::quick();
    for fb in [
        ciminus::sparsity::flexblock::FlexBlock::row_wise(0.8),
        ciminus::sparsity::flexblock::FlexBlock::row_block(16, 0.8),
        ciminus::sparsity::flexblock::FlexBlock::column_block(16, 0.8),
        ciminus::sparsity::flexblock::FlexBlock::hybrid(2, 16, 0.8),
    ] {
        let s = b.run(&format!("mask_4608x512_{}", fb.name), || {
            let mut rng = Pcg32::new(1);
            random_mask(&fb, 4608, 512, LayerCtx { per_channel: 9 }, &mut rng)
        });
        println!("{}", s.report_line());
    }
}
