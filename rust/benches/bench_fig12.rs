//! Fig. 12: weight-data rearrangement on/off — energy breakdown,
//! latency and utilization on the 4x4 organization.
use ciminus::explore::mapping_study::run_fig12;
use ciminus::hw::units::UnitKind;
use ciminus::report;
use ciminus::util::bench::{bench_header, Bencher};
use ciminus::workload::zoo;

fn main() {
    bench_header("Fig. 12 — rearrangement");
    let r50 = zoo::resnet50(32, 100);
    let pts = run_fig12(&r50, 0).expect("fig12");
    println!("{}", report::rearrange_table(&pts).render());
    println!("normalized energy breakdown:");
    for p in &pts {
        let e = &p.report.energy;
        let buf = e.of(UnitKind::WeightBuf) + e.of(UnitKind::GlobalInBuf) + e.of(UnitKind::GlobalOutBuf);
        let array = e.of(UnitKind::CimArray) + e.of(UnitKind::AdderTree) + e.of(UnitKind::ShiftAdd);
        println!(
            "  {:<10} R={} array {:>5.1}%  buffers {:>5.1}%  other {:>5.1}%",
            p.strategy,
            p.rearranged,
            array / e.total_pj * 100.0,
            buf / e.total_pj * 100.0,
            (e.total_pj - array - buf) / e.total_pj * 100.0
        );
    }
    let b = Bencher::quick();
    let s = b.run("fig12_four_configs", || run_fig12(&r50, 0).unwrap().len());
    println!("{}", s.report_line());
}
