//! Fig. 8: speedup / energy saving / (accuracy) across the Table II
//! sparsity patterns and ratios 0.5–0.9 on ResNet50.
use ciminus::explore::sparsity_study::{run_fig8, RATIOS};
use ciminus::report;
use ciminus::util::bench::{bench_header, Bencher};
use ciminus::workload::zoo;

fn main() {
    bench_header("Fig. 8 — sparsity exploitation on ResNet50");
    let net = zoo::resnet50(32, 100);
    let pts = run_fig8(&net, &RATIOS, 0).expect("sweep");
    println!("{}", report::sparsity_table("Fig. 8 (accuracy via e2e_pipeline/sparsity_explorer)", &pts).render());
    let b = Bencher::quick();
    let s = b.run("fig8_full_sweep_resnet50", || {
        run_fig8(&net, &RATIOS, 0).unwrap().len()
    });
    println!("{}", s.report_line());
}
