//! Fig. 11: spatial mapping vs weight duplication across 16-macro
//! organizations for ResNet50 and VGG16.
use ciminus::explore::mapping_study::run_fig11;
use ciminus::report;
use ciminus::util::bench::{bench_header, Bencher};
use ciminus::workload::zoo;

fn main() {
    bench_header("Fig. 11 — mapping strategies");
    let r50 = zoo::resnet50(32, 100);
    let v16 = zoo::vgg16(32, 100);
    let pts = run_fig11(&[&r50, &v16], 0).expect("fig11");
    println!("{}", report::mapping_table(&pts).render());
    let b = Bencher::quick();
    let s = b.run("fig11_grid", || run_fig11(&[&r50], 0).unwrap().len());
    println!("{}", s.report_line());
}
