//! Ablation benches (DESIGN.md §6): modeling-choice sensitivity.
//!
//! All four groups share one [`EvalCtx`], so the closing `cache hits`
//! line is nonzero whenever the staged evaluator reuses planning
//! artifacts across points (CI asserts on it).
use ciminus::eval::EvalCtx;
use ciminus::explore::ablation_study::{
    bit_width, pipeline_overlap, policy_comparison, subarray_granularity,
};
use ciminus::util::bench::bench_header;
use ciminus::util::table::Table;
use ciminus::workload::zoo;

fn print_points(title: &str, pts: &[ciminus::explore::ablation_study::AblationPoint]) {
    let mut t = Table::new(&["config", "cycles", "energy(uJ)", "skip%"]).with_title(title);
    for p in pts {
        t.row(vec![
            p.label.clone(),
            p.cycles.to_string(),
            format!("{:.3}", p.energy_pj / 1e6),
            format!("{:.1}", p.skip_ratio * 100.0),
        ]);
    }
    println!("{}", t.render());
}

fn main() {
    bench_header("ablations");
    let net = zoo::resnet50(32, 100);
    let ctx = EvalCtx::default();
    print_points(
        "ablation 1: zero-detect granularity (sub-array rows)",
        &subarray_granularity(&net, &ctx).unwrap(),
    );
    print_points(
        "ablation 2: double buffering (Eq. 3 overlap)",
        &pipeline_overlap(&net, &ctx).unwrap(),
    );
    print_points(
        "ablation 3: mapping policy @ hybrid 0.8, 16 macros",
        &policy_comparison(&net, &ctx).unwrap(),
    );
    print_points("ablation 4: activation bit width", &bit_width(&net, &ctx).unwrap());
    let stats = ctx.evaluator.stats();
    println!("artifact cache: {stats}");
    println!("cache hits: {}", stats.total_hits());
}
