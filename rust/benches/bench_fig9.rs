//! Fig. 9: block-size sweep @80% (a) and cross-model comparison (b).
use ciminus::explore::sparsity_study::{run_fig9a, run_fig9b};
use ciminus::report;
use ciminus::util::bench::{bench_header, Bencher};
use ciminus::workload::zoo;

fn main() {
    bench_header("Fig. 9 — block sizes and architectures @80%");
    let r50 = zoo::resnet50(32, 100);
    let pts = run_fig9a(&r50, 0).expect("fig9a");
    println!("{}", report::sparsity_table("Fig. 9(a): block sizes", &pts).render());

    let v16 = zoo::vgg16(32, 100);
    let mb = zoo::mobilenetv2(32, 100);
    let pts_b = run_fig9b(&[&r50, &v16, &mb], 0).expect("fig9b");
    let flat: Vec<_> = pts_b
        .into_iter()
        .map(|(m, mut p)| {
            p.pattern = format!("{m}/{}", p.pattern);
            p
        })
        .collect();
    println!("{}", report::sparsity_table("Fig. 9(b): models", &flat).render());

    let b = Bencher::quick();
    let s = b.run("fig9a_sweep", || run_fig9a(&r50, 0).unwrap().len());
    println!("{}", s.report_line());
}
