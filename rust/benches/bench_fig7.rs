//! Fig. 7: framework runtime & scalability — how long CIMinus itself
//! takes across models, sparsity patterns, ratios and macro counts.
//! (The paper reports <100 s per configuration; see EXPERIMENTS.md.)
use ciminus::hw::presets;
use ciminus::sim::engine::simulate_network_default;
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::util::bench::{bench_header, Bencher};
use ciminus::workload::zoo;

fn main() {
    bench_header("Fig. 7 — framework runtime & scalability");
    let b = Bencher::quick();
    let hybrid = FlexBlock::hybrid(2, 16, 0.8);

    // across models (4-macro, 80% hybrid + input sparsity)
    for model in ["mobilenetv2", "resnet18", "resnet50", "vgg16"] {
        let net = zoo::by_name(model, 32, 100).unwrap();
        let arch = presets::usecase_arch(4, (2, 2));
        let s = b.run(&format!("simulate_{model}_4m_hybrid0.8"), || {
            simulate_network_default(&arch, &net, Some(&hybrid)).unwrap().total_cycles
        });
        println!("{}", s.report_line());
    }

    // across sparsity patterns on resnet50
    let net = zoo::resnet50(32, 100);
    for fb in [
        FlexBlock::row_wise(0.8),
        FlexBlock::row_block(16, 0.8),
        FlexBlock::column_block(16, 0.8),
        FlexBlock::hybrid(2, 16, 0.8),
    ] {
        let arch = presets::usecase_arch(4, (2, 2));
        let s = b.run(&format!("simulate_resnet50_{}", fb.name), || {
            simulate_network_default(&arch, &net, Some(&fb)).unwrap().total_cycles
        });
        println!("{}", s.report_line());
    }

    // across sparsity ratios
    for r in [0.5, 0.7, 0.9] {
        let fb = FlexBlock::hybrid(2, 16, r);
        let arch = presets::usecase_arch(4, (2, 2));
        let s = b.run(&format!("simulate_resnet50_ratio{r}"), || {
            simulate_network_default(&arch, &net, Some(&fb)).unwrap().total_cycles
        });
        println!("{}", s.report_line());
    }

    // across macro counts (scalability: runtime tracks workload, not hw)
    for (n, org) in [(4, (2, 2)), (16, (4, 4)), (64, (8, 8))] {
        let arch = presets::usecase_arch(n, org);
        let s = b.run(&format!("simulate_resnet50_{n}macros"), || {
            simulate_network_default(&arch, &net, Some(&hybrid)).unwrap().total_cycles
        });
        println!("{}", s.report_line());
    }
}
