//! Engine micro-benchmarks (the §Perf profiling targets): mask
//! generation, compression, mapping, simulation on the largest layers.
use ciminus::hw::presets;
use ciminus::mapping::duplication::Strategy;
use ciminus::mapping::planner::{plan, MappingOptions};
use ciminus::mapping::tiling::tile_op;
use ciminus::pruning::workflow::PruningWorkflow;
use ciminus::sim::engine::{simulate, SimOptions};
use ciminus::sim::input_sparsity::InputProfiles;
use ciminus::sparsity::compress::{compress, CompressedLayout};
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::sparsity::mask::{random_mask, LayerCtx};
use ciminus::util::bench::{bench_header, black_box, Bencher};
use ciminus::util::rng::Pcg32;
use ciminus::workload::zoo;

fn main() {
    bench_header("engine micro-benchmarks");
    let b = Bencher::quick();
    let ctx = LayerCtx { per_channel: 9 };
    let fb = FlexBlock::hybrid(2, 16, 0.8);

    // L3 hot path 1: mask generation on the largest resnet50 layer (4608x512)
    let s = b.run("mask_gen_4608x512_hybrid", || {
        let mut rng = Pcg32::new(7);
        random_mask(&fb, 4608, 512, ctx, &mut rng)
    });
    println!("{}", s.report_line());

    // hot path 2: compression analysis
    let mut rng = Pcg32::new(7);
    let mask = random_mask(&fb, 4608, 512, ctx, &mut rng);
    let s = b.run("compress_4608x512_hybrid", || compress(&fb, &mask, ctx));
    println!("{}", s.report_line());

    // hot path 3: tiling of a big compressed layout
    let arch = presets::usecase_arch(16, (4, 4));
    let layout = compress(&fb, &mask, ctx);
    let dims = ciminus::workload::op::MvmDims { rows: 4608, cols: 512, n_vectors: 1024, groups: 1 };
    let s = b.run("tile_op_16macros", || {
        tile_op(&arch, &dims, &layout, Strategy::Duplicate).rounds.len()
    });
    println!("{}", s.report_line());

    // hot path 4: whole-network plan+simulate (the Fig. 7 unit)
    let net = zoo::resnet50(32, 100);
    let wf = PruningWorkflow::default();
    let prune = wf.run_uniform(&net, &fb, None).unwrap();
    let profiles = InputProfiles::synthetic(&net, 8, 0.55, 1);
    let s = b.run("plan_resnet50", || {
        plan(&arch, &net, Some(&prune), MappingOptions::default()).unwrap().ops.len()
    });
    println!("{}", s.report_line());
    let mapping = plan(&arch, &net, Some(&prune), MappingOptions::default()).unwrap();
    let s = b.run("simulate_resnet50", || {
        simulate(&arch, &net, &mapping, Some(&profiles), SimOptions::default())
            .unwrap()
            .total_cycles
    });
    println!("{}", s.report_line());

    // baseline: dense layout sanity
    let s = b.run("dense_layout_alloc", || {
        black_box(CompressedLayout::dense(4608, 512)).comp_rows
    });
    println!("{}", s.report_line());
}
