//! Fig. 10: input-sparsity exploitation across models, weight patterns
//! and ratios (with/without skip support).
use ciminus::explore::input_study::{run_dense_models, run_ratio_sweep, run_weight_patterns};
use ciminus::report;
use ciminus::util::bench::{bench_header, Bencher};
use ciminus::workload::zoo;

fn main() {
    bench_header("Fig. 10 — input sparsity");
    let r50 = zoo::resnet50(32, 100);
    let v16 = zoo::vgg16(32, 100);
    let mb = zoo::mobilenetv2(32, 100);
    let dense = run_dense_models(&[&r50, &v16, &mb], 0.55, 0).expect("dense");
    println!("{}", report::input_sparsity_table("dense models", &dense).render());
    let pats = run_weight_patterns(&r50, 0).expect("patterns");
    println!("{}", report::input_sparsity_table("weight patterns @80% (resnet50)", &pats).render());
    let ratios = run_ratio_sweep(&r50, &[0.5, 0.6, 0.7, 0.8, 0.9], 0).expect("ratios");
    println!("{}", report::input_sparsity_table("row-wise ratio sweep", &ratios).render());
    let b = Bencher::quick();
    let s = b.run("fig10_dense_models", || {
        run_dense_models(&[&r50], 0.55, 0).unwrap().len()
    });
    println!("{}", s.report_line());
}
