//! Table I: validation-architecture summary + preset construction cost.
use ciminus::report;
use ciminus::util::bench::{bench_header, Bencher};

fn main() {
    bench_header("Table I — validation architectures");
    println!("{}", report::tab1().render());
    let b = Bencher::quick();
    let s = b.run("arch_preset_construction", || {
        (ciminus::hw::presets::mars(), ciminus::hw::presets::sdp())
    });
    println!("{}", s.report_line());
}
