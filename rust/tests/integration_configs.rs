//! The shipped example configs in configs/ must load and simulate.

use ciminus::hw::arch::Architecture;
use ciminus::sim::engine::simulate_network_default;
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::util::json::Json;
use ciminus::workload::import;
use std::path::Path;

#[test]
fn example_arch_config_loads_and_simulates() {
    let arch = Architecture::from_json(
        &Json::parse_file(Path::new("configs/custom_arch_example.json")).unwrap(),
    )
    .unwrap();
    assert_eq!(arch.name, "example_custom");
    assert_eq!(arch.org.n_macros(), 8);
    assert_eq!(arch.cim.rows, 512);
    assert!(arch.global_in_buf.ping_pong);
    assert_eq!(arch.energy.cim_cell.dynamic_pj, 0.005);
    let net = ciminus::workload::zoo::resnet_mini();
    let rep = simulate_network_default(&arch, &net, Some(&FlexBlock::row_wise(0.7))).unwrap();
    assert!(rep.total_cycles > 0);
}

#[test]
fn example_net_config_loads_and_simulates() {
    let net = import::network_from_file(Path::new("configs/custom_net_example.json")).unwrap();
    assert_eq!(net.name, "custom_cnn");
    assert_eq!(net.mvm_ops().len(), 3);
    let arch = ciminus::hw::presets::usecase_arch(4, (2, 2));
    let rep = simulate_network_default(&arch, &net, Some(&FlexBlock::hybrid(2, 16, 0.8))).unwrap();
    assert!(rep.total_cycles > 0);
    assert!(rep.mean_utilization > 0.0);
}

#[test]
fn cli_accepts_config_files() {
    let code = ciminus::cli::run(
        [
            "simulate",
            "--arch",
            "configs/custom_arch_example.json",
            "--model",
            "configs/custom_net_example.json",
            "--pattern",
            "row_block:16",
            "--ratio",
            "0.6",
        ]
        .iter()
        .map(|s| s.to_string()),
    )
    .unwrap();
    assert_eq!(code, 0);
}
