//! Integration: workload DAGs ↔ JSON interchange ↔ artifact graphs.

use ciminus::util::json::Json;
use ciminus::workload::{import, zoo};

#[test]
fn zoo_networks_all_verify_and_roundtrip() {
    for name in zoo::ZOO_NAMES {
        for px in [32, 224] {
            if name.ends_with("_mini") && px != 32 {
                continue; // minis are fixed-size; constructor ignores px
            }
            let net = zoo::by_name(name, px, 100).unwrap();
            let j = import::network_to_json(&net);
            let net2 = import::network_from_json(&j).unwrap();
            assert_eq!(net.stats(), net2.stats(), "{name}@{px}");
            assert_eq!(net.mvm_ops(), net2.mvm_ops(), "{name}@{px}");
        }
    }
}

#[test]
fn artifact_graphs_match_zoo_minis() {
    // the Python exporter and the rust zoo must describe the same DAG
    let dir = ciminus::runtime::Artifacts::default_dir();
    if !ciminus::runtime::Artifacts::available(&dir) {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for name in ["resnet_mini", "vgg_mini", "mobilenet_mini"] {
        let path = dir.join(format!("graph_{name}.json"));
        let imported = import::network_from_file(&path).unwrap();
        let native = zoo::by_name(name, 16, 10).unwrap();
        assert_eq!(
            imported.stats(),
            native.stats(),
            "{name}: python-exported graph != rust zoo"
        );
        // MVM op names must match exactly (the pruning contract)
        let mvm_names = |n: &ciminus::workload::graph::Network| -> Vec<String> {
            n.mvm_ops().iter().map(|&i| n.ops[i].name.clone()).collect()
        };
        assert_eq!(mvm_names(&imported), mvm_names(&native), "{name}");
    }
}

#[test]
fn imported_network_rejects_cycles_and_bad_shapes() {
    let cyclic = r#"{"name":"c","ops":[
        {"name":"x","kind":"input","shape":[3,8,8]},
        {"name":"a","kind":"relu","inputs":[2]},
        {"name":"b","kind":"relu","inputs":[1]}
    ]}"#;
    assert!(import::network_from_json(&Json::parse(cyclic).unwrap()).is_err());
    let bad_shape = r#"{"name":"b","ops":[
        {"name":"x","kind":"input","shape":[3,8,8]},
        {"name":"f","kind":"fc","inputs":[0],"in_features":10,"out_features":2}
    ]}"#;
    assert!(import::network_from_json(&Json::parse(bad_shape).unwrap()).is_err());
}

#[test]
fn macs_scale_with_input_resolution() {
    let small = zoo::resnet18(32, 100).stats().macs;
    let big = zoo::resnet18(224, 100).stats().macs;
    assert!(big > small * 2, "{big} vs {small}");
}
