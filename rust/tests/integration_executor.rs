//! Integration: the resilient sweep executor's failure-handling
//! contract — panic isolation, watchdog timeouts, retries,
//! checkpoint/resume, and determinism under thread-count variation.

use ciminus::explore::executor::smoke_codec;
use ciminus::explore::{run_sweep, Codec, Job, Sweep, SweepConfig};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn jobs_of(n: usize) -> Vec<Job<usize>> {
    (0..n)
        .map(|i| Job {
            key: format!("j{i}"),
            input: i,
        })
        .collect()
}

fn num_codec() -> Codec<f64> {
    smoke_codec()
}

fn tmp_journal(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ciminus-it-exec-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    let path = dir.join(format!("{name}.jsonl"));
    let _ = std::fs::remove_file(&path);
    path
}

#[test]
fn panic_is_isolated_and_order_preserved() {
    let report = run_sweep(jobs_of(16), &SweepConfig::default(), None, |&i: &usize| {
        if i == 4 {
            panic!("injected panic at {i}");
        }
        Ok(i as f64 * 2.0)
    })
    .unwrap();
    assert_eq!(report.outcomes.len(), 16);
    for (i, o) in report.outcomes.iter().enumerate() {
        assert_eq!(o.key, format!("j{i}"), "outcomes stay in input order");
        assert_eq!(o.index, i);
        if i == 4 {
            let e = o.result.as_ref().unwrap_err();
            assert_eq!(e.kind(), "panic");
            assert!(e.to_string().contains("injected panic"), "{e}");
        } else {
            assert_eq!(*o.result.as_ref().unwrap(), i as f64 * 2.0, "sibling {i} survived");
        }
    }
}

#[test]
fn timeout_fires_without_blocking_the_sweep() {
    let mut cfg = SweepConfig::with_threads(4);
    cfg.job_timeout = Some(Duration::from_millis(150));
    let t0 = Instant::now();
    let report = run_sweep(jobs_of(4), &cfg, None, |&i: &usize| {
        if i == 2 {
            // far beyond the timeout: only the watchdog can end this job
            std::thread::sleep(Duration::from_secs(5));
        }
        Ok(i as f64)
    })
    .unwrap();
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(3),
        "sweep must not wait out the hang (took {elapsed:?})"
    );
    let sweep = Sweep::from_report(report);
    assert_eq!(sweep.points, vec![0.0, 1.0, 3.0]);
    assert_eq!(sweep.failures.len(), 1);
    assert_eq!(sweep.failures[0].key, "j2");
    assert_eq!(sweep.failures[0].error.kind(), "timeout");
}

#[test]
fn transient_errors_retry_then_succeed() {
    let calls = Arc::new(AtomicUsize::new(0));
    let calls2 = Arc::clone(&calls);
    let mut cfg = SweepConfig::with_threads(1);
    cfg.max_retries = 2;
    cfg.retry_backoff = Duration::from_millis(1);
    let report = run_sweep(jobs_of(1), &cfg, None, move |&i: &usize| {
        let n = calls2.fetch_add(1, Ordering::SeqCst);
        if n < 2 {
            anyhow::bail!("transient failure #{n}");
        }
        Ok(i as f64 + 100.0)
    })
    .unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 3, "two failures + one success");
    let o = &report.outcomes[0];
    assert_eq!(o.attempts, 3);
    assert_eq!(*o.result.as_ref().unwrap(), 100.0);
}

#[test]
fn panics_are_not_retried() {
    let calls = Arc::new(AtomicUsize::new(0));
    let calls2 = Arc::clone(&calls);
    let mut cfg = SweepConfig::with_threads(1);
    cfg.max_retries = 3;
    cfg.retry_backoff = Duration::from_millis(1);
    let report = run_sweep(jobs_of(1), &cfg, None, move |_: &usize| -> anyhow::Result<f64> {
        calls2.fetch_add(1, Ordering::SeqCst);
        panic!("always panics");
    })
    .unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 1, "a panic is never retried");
    assert_eq!(report.outcomes[0].result.as_ref().unwrap_err().kind(), "panic");
}

#[test]
fn checkpoint_resume_skips_completed_points_bit_identically() {
    let path = tmp_journal("roundtrip");
    let mut cfg = SweepConfig::with_threads(2);
    cfg.checkpoint = Some(path.clone());

    let first = Sweep::from_report(
        run_sweep(jobs_of(10), &cfg, Some(num_codec()), |&i: &usize| Ok(i as f64 * 3.0))
            .unwrap(),
    );
    assert_eq!(first.failures.len(), 0);
    assert_eq!(first.resumed, 0);
    let journal = std::fs::read_to_string(&path).unwrap();
    assert_eq!(journal.lines().count(), 10, "one line per completed point");

    // resume: no job function call may happen at all
    let calls = Arc::new(AtomicUsize::new(0));
    let calls2 = Arc::clone(&calls);
    let mut cfg2 = cfg.clone();
    cfg2.resume = true;
    let second = Sweep::from_report(
        run_sweep(jobs_of(10), &cfg2, Some(num_codec()), move |&i: &usize| {
            calls2.fetch_add(1, Ordering::SeqCst);
            Ok(i as f64 * 3.0)
        })
        .unwrap(),
    );
    assert_eq!(calls.load(Ordering::SeqCst), 0, "fully-journaled run recomputes nothing");
    assert_eq!(second.resumed, 10);
    assert_eq!(second.points, first.points, "resumed results are bit-identical");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn resume_recomputes_only_missing_points() {
    let path = tmp_journal("partial");
    let mut cfg = SweepConfig::with_threads(2);
    cfg.checkpoint = Some(path.clone());

    // first run: job 7 fails, the other 9 are journaled
    let first = Sweep::from_report(
        run_sweep(jobs_of(10), &cfg, Some(num_codec()), |&i: &usize| {
            if i == 7 {
                anyhow::bail!("flaky point");
            }
            Ok(i as f64 * 3.0)
        })
        .unwrap(),
    );
    assert_eq!(first.failures.len(), 1);
    assert_eq!(std::fs::read_to_string(&path).unwrap().lines().count(), 9);

    // resumed run with the flake gone: exactly one recomputation, and
    // the final results equal an uninterrupted successful sweep
    let calls = Arc::new(AtomicUsize::new(0));
    let calls2 = Arc::clone(&calls);
    let mut cfg2 = cfg.clone();
    cfg2.resume = true;
    let second = Sweep::from_report(
        run_sweep(jobs_of(10), &cfg2, Some(num_codec()), move |&i: &usize| {
            calls2.fetch_add(1, Ordering::SeqCst);
            Ok(i as f64 * 3.0)
        })
        .unwrap(),
    );
    assert_eq!(calls.load(Ordering::SeqCst), 1, "only the missing point runs");
    assert_eq!(second.resumed, 9);
    assert_eq!(second.failures.len(), 0);
    let expected: Vec<f64> = (0..10).map(|i| i as f64 * 3.0).collect();
    assert_eq!(second.points, expected);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn results_deterministic_across_thread_counts() {
    let run_with = |threads: usize| -> Vec<f64> {
        let report = run_sweep(
            jobs_of(32),
            &SweepConfig::with_threads(threads),
            None,
            |&i: &usize| {
                // stagger completion order so scheduling actually varies
                std::thread::sleep(Duration::from_millis((i % 3) as u64));
                Ok(i as f64 * 1.5)
            },
        )
        .unwrap();
        Sweep::from_report(report).points
    };
    let one = run_with(1);
    let two = run_with(2);
    let eight = run_with(8);
    assert_eq!(one, two);
    assert_eq!(one, eight);
    assert_eq!(one.len(), 32);
}

#[test]
fn max_failures_aborts_remaining_queue() {
    let mut cfg = SweepConfig::with_threads(2);
    cfg.max_failures = Some(2);
    let report = run_sweep(jobs_of(16), &cfg, None, |&_i: &usize| -> anyhow::Result<f64> {
        std::thread::sleep(Duration::from_millis(20));
        anyhow::bail!("doomed");
    })
    .unwrap();
    let sweep = Sweep::from_report(report);
    assert!(sweep.points.is_empty());
    assert_eq!(sweep.failures.len(), 16, "every job resolves, none is lost");
    let aborted = sweep
        .failures
        .iter()
        .filter(|f| f.error.kind() == "aborted")
        .count();
    let failed = sweep
        .failures
        .iter()
        .filter(|f| f.error.kind() == "error")
        .count();
    assert!(aborted > 0, "breaker drained the queue");
    assert_eq!(aborted + failed, 16);
}

#[test]
fn smoke_sweep_shape() {
    let sweep = ciminus::explore::executor::smoke_sweep(&SweepConfig::default()).unwrap();
    assert_eq!(sweep.total, 8);
    assert_eq!(sweep.points.len(), 6, "panicking + hanging points drop out");
    let kinds: Vec<&str> = sweep.failures.iter().map(|f| f.error.kind()).collect();
    assert!(kinds.contains(&"panic"), "{kinds:?}");
    assert!(kinds.contains(&"timeout"), "{kinds:?}");
}
