//! Integration: end-to-end simulation invariants across the stack.

use ciminus::hw::presets;
use ciminus::hw::units::UnitKind;
use ciminus::sim::engine::simulate_network_default;
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::util::proptest::{check, ensure};
use ciminus::workload::zoo;

#[test]
fn efficiency_ordering_coarse_beats_fine() {
    // Fig. 8's headline: coarse full-dimension patterns are more
    // efficient than fine-grained hybrids at the same overall sparsity.
    // In our cycle model the gap is carried by *energy* (mux routing,
    // index traffic, reduced input skipping); latency can tie because
    // hybrids also compress both matrix dimensions.
    let net = zoo::resnet50(32, 100);
    let dense_arch = presets::usecase_dense_baseline(4, (2, 2));
    let dense = simulate_network_default(&dense_arch, &net, None).unwrap();
    let arch = presets::usecase_arch(4, (2, 2));
    let coarse =
        simulate_network_default(&arch, &net, Some(&FlexBlock::row_wise(0.8))).unwrap();
    let fine =
        simulate_network_default(&arch, &net, Some(&FlexBlock::hybrid(2, 16, 0.8))).unwrap();
    let e_coarse = coarse.energy_saving_vs(&dense);
    let e_fine = fine.energy_saving_vs(&dense);
    // near-tie is acceptable (hybrids also compress both dims); what must
    // hold is that the fine pattern never *beats* coarse by a margin —
    // its mux/index/skip overheads keep it at or below coarse + ε
    // (EXPERIMENTS.md §Fig8 documents the divergence from the paper's
    // larger gap).
    assert!(
        e_coarse > e_fine * 0.93,
        "coarse saving {e_coarse:.2} far below fine {e_fine:.2}"
    );
    assert!(fine.speedup_vs(&dense) > 1.0);
    assert!(coarse.speedup_vs(&dense) > 1.0);
    // the fine pattern skips fewer input bits (broadcast groups widen)...
    assert!(fine.mean_skip_ratio <= coarse.mean_skip_ratio + 1e-12);
    // ...pays mux routing energy the coarse pattern does not...
    use ciminus::hw::units::UnitKind;
    assert!(fine.counters.compute_of(UnitKind::Mux) > 0);
    assert_eq!(coarse.counters.compute_of(UnitKind::Mux), 0);
    // ...and stores strictly more index state (Eq. 8)
    assert!(fine.index_bytes > coarse.index_bytes);
}

#[test]
fn prop_sparse_never_slower_than_dense_same_arch() {
    check("sparse_wins", 12, 0x51A, |g| {
        let ratio = g.f64_in(0.55, 0.9);
        let fb = match g.usize_in(0, 2) {
            0 => FlexBlock::row_wise(ratio),
            1 => FlexBlock::channel_wise(ratio),
            _ => FlexBlock::hybrid_row_wise(2, ratio),
        };
        let net = zoo::resnet_mini();
        let arch = presets::usecase_arch(4, (2, 2));
        let dense = simulate_network_default(&arch, &net, None).map_err(|e| e.to_string())?;
        let sparse =
            simulate_network_default(&arch, &net, Some(&fb)).map_err(|e| e.to_string())?;
        ensure(
            sparse.total_cycles <= dense.total_cycles,
            format!(
                "{} @{ratio:.2}: sparse {} > dense {}",
                fb.name, sparse.total_cycles, dense.total_cycles
            ),
        )
    });
}

#[test]
fn energy_conservation_dynamic_plus_static() {
    let net = zoo::vgg_mini();
    let arch = presets::usecase_arch(4, (2, 2));
    let r = simulate_network_default(&arch, &net, None).unwrap();
    let sum = r.energy.dynamic_total() + r.energy.static_pj;
    assert!((sum - r.energy.total_pj).abs() < 1e-6 * r.energy.total_pj);
}

#[test]
fn pipeline_latency_at_least_compute() {
    // Eq. 3 lower bound: total latency ≥ Σ compute cycles of any op chain
    let net = zoo::resnet_mini();
    let arch = presets::usecase_arch(4, (2, 2));
    let r = simulate_network_default(&arch, &net, None).unwrap();
    let max_op = r.ops.iter().map(|o| o.cycles).max().unwrap();
    assert!(r.total_cycles >= max_op);
}

#[test]
fn sdp_architecture_skips_more_than_mars() {
    // SDP's 1-row sub-arrays make zero-bit skipping far more effective
    // than MARS's 64-row groups (Sec. III-B / our model).
    let net = zoo::resnet18(32, 100);
    let mars = simulate_network_default(&presets::mars(), &net, None).unwrap();
    let sdp = simulate_network_default(&presets::sdp(), &net, None).unwrap();
    assert!(
        sdp.mean_skip_ratio > mars.mean_skip_ratio,
        "SDP {} <= MARS {}",
        sdp.mean_skip_ratio,
        mars.mean_skip_ratio
    );
}

#[test]
fn index_memory_energy_only_with_sparsity() {
    let net = zoo::resnet_mini();
    let arch = presets::usecase_arch(4, (2, 2));
    let dense = simulate_network_default(&arch, &net, None).unwrap();
    assert_eq!(dense.counters.reads_of(UnitKind::IndexMem), 0);
    let sparse =
        simulate_network_default(&arch, &net, Some(&FlexBlock::row_wise(0.8))).unwrap();
    assert!(sparse.counters.reads_of(UnitKind::IndexMem) > 0);
}

#[test]
fn depthwise_layers_underutilize_arrays() {
    // MobileNet's depthwise convs map poorly (Fig. 9(b) driver)
    let net = zoo::mobilenet_mini();
    let arch = presets::usecase_arch(4, (2, 2));
    let r = simulate_network_default(&arch, &net, None).unwrap();
    let dw = r
        .ops
        .iter()
        .find(|o| o.kind == "dwconv")
        .expect("has depthwise");
    let conv = r
        .ops
        .iter()
        .filter(|o| o.kind == "conv")
        .max_by(|a, b| a.utilization.partial_cmp(&b.utilization).unwrap())
        .unwrap();
    assert!(
        dw.utilization < conv.utilization,
        "dw {} >= conv {}",
        dw.utilization,
        conv.utilization
    );
}

#[test]
fn bigger_networks_cost_more() {
    let arch = presets::usecase_arch(4, (2, 2));
    let mini = simulate_network_default(&arch, &zoo::resnet_mini(), None).unwrap();
    let r18 = simulate_network_default(&arch, &zoo::resnet18(32, 100), None).unwrap();
    let r50 = simulate_network_default(&arch, &zoo::resnet50(32, 100), None).unwrap();
    assert!(mini.total_cycles < r18.total_cycles);
    assert!(r18.total_cycles < r50.total_cycles);
    assert!(mini.energy.total_pj < r18.energy.total_pj);
    assert!(r18.energy.total_pj < r50.energy.total_pj);
}
