//! Integration + property tests across the sparsity stack:
//! FlexBlock → mask → compression → index accounting invariants.

use ciminus::sparsity::compress::compress;
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::sparsity::index::index_storage;
use ciminus::sparsity::mask::{mask_stats, random_mask, LayerCtx};
use ciminus::util::proptest::{check, ensure};
use ciminus::util::rng::Pcg32;

fn arbitrary_flexblock(g: &mut ciminus::util::proptest::Gen) -> FlexBlock {
    let r = g.f64_in(0.2, 0.9);
    match g.usize_in(0, 7) {
        0 => FlexBlock::row_wise(r),
        1 => FlexBlock::row_block(*g.choose(&[8, 16, 32]), r),
        2 => FlexBlock::column_wise(r),
        3 => FlexBlock::channel_wise(r),
        4 => FlexBlock::column_block(*g.choose(&[4, 8, 16]), r),
        5 => FlexBlock::intra(*g.choose(&[2, 4]), 0.5),
        6 => FlexBlock::hybrid(2, 16, r.max(0.55)),
        _ => FlexBlock::hybrid_row_wise(2, r.max(0.55)),
    }
}

#[test]
fn prop_compressed_footprint_never_exceeds_original() {
    check("footprint", 120, 0xA11CE, |g| {
        let rows = g.usize_in(2, 64) * 4;
        let cols = g.usize_in(1, 16) * 8;
        let fb = arbitrary_flexblock(g);
        let ctx = LayerCtx {
            per_channel: *g.choose(&[1, 9]),
        };
        let mut rng = g.rng.fork(1);
        let mask = random_mask(&fb, rows, cols, ctx, &mut rng);
        let l = compress(&fb, &mask, ctx);
        ensure(
            l.comp_rows <= rows.max(1),
            format!("{}: comp_rows {} > {rows}", fb.name, l.comp_rows),
        )?;
        ensure(
            l.comp_cols <= cols,
            format!("{}: comp_cols {} > {cols}", fb.name, l.comp_cols),
        )
    });
}

#[test]
fn prop_mask_respects_flexblock_sparsity_level() {
    check("structure", 80, 0xBEEF, |g| {
        let fb = arbitrary_flexblock(g);
        // rows a multiple of 36 = lcm(4, 9) so symbolic per-channel blocks
        // tile exactly (partial edge blocks skew realized sparsity)
        let rows = g.usize_in(1, 8) * 36;
        let cols = g.usize_in(2, 8) * 16;
        let ctx = LayerCtx { per_channel: 9 };
        let mut rng = g.rng.fork(2);
        let mask = random_mask(&fb, rows, cols, ctx, &mut rng);
        let s = mask_stats(&mask);
        let want = fb.overall_sparsity();
        ensure(
            (s.sparsity - want).abs() < 0.2,
            format!("{}: sparsity {} vs {}", fb.name, s.sparsity, want),
        )
    });
}

#[test]
fn prop_index_storage_bounded() {
    check("index_bound", 80, 0xCAFE, |g| {
        let fb = arbitrary_flexblock(g);
        let rows = g.usize_in(4, 32) * 4;
        let cols = g.usize_in(2, 8) * 8;
        let ctx = LayerCtx { per_channel: 9 };
        let mut rng = g.rng.fork(3);
        let mask = random_mask(&fb, rows, cols, ctx, &mut rng);
        let l = compress(&fb, &mask, ctx);
        let idx = index_storage(&fb, &l, ctx);
        // elem indices never exceed nnz; block indices never exceed grid
        ensure(
            idx.n_elem_indices <= l.nnz,
            format!("{}: elem idx {} > nnz {}", fb.name, idx.n_elem_indices, l.nnz),
        )?;
        ensure(
            idx.n_block_indices <= (rows * cols) as u64,
            format!("{}: block idx", fb.name),
        )
    });
}

#[test]
fn prop_higher_ratio_never_increases_footprint() {
    check("ratio_monotone", 40, 0xD00D, |g| {
        let rows = 256;
        let cols = 64;
        let ctx = LayerCtx::fc();
        let lo_r = g.f64_in(0.2, 0.5);
        let hi_r = lo_r + 0.3;
        let seed = g.rng.next_u64();
        let lo = FlexBlock::row_wise(lo_r);
        let hi = FlexBlock::row_wise(hi_r);
        let ml = random_mask(&lo, rows, cols, ctx, &mut Pcg32::new(seed));
        let mh = random_mask(&hi, rows, cols, ctx, &mut Pcg32::new(seed));
        let fl = compress(&lo, &ml, ctx);
        let fh = compress(&hi, &mh, ctx);
        ensure(
            fh.comp_rows <= fl.comp_rows,
            format!("rows {} > {}", fh.comp_rows, fl.comp_rows),
        )
    });
}

#[test]
fn hybrid_index_overhead_exceeds_pure_fullblock() {
    // the paper's "finer granularity → more indexing overhead"
    let ctx = LayerCtx::fc();
    let mut rng = Pcg32::new(5);
    let rows = 512;
    let cols = 128;
    let coarse = FlexBlock::row_wise(0.8);
    let fine = FlexBlock::hybrid(2, 16, 0.8);
    let cm = random_mask(&coarse, rows, cols, ctx, &mut rng);
    let fm = random_mask(&fine, rows, cols, ctx, &mut rng);
    let ci = index_storage(&coarse, &compress(&coarse, &cm, ctx), ctx);
    let fi = index_storage(&fine, &compress(&fine, &fm, ctx), ctx);
    assert!(fi.total_bits() > ci.total_bits());
}
