//! Golden-report regression suite: [`SimReport::content_digest`]s for a
//! small dense/sparse × skip/no-skip matrix are snapshotted under
//! `tests/golden/` and must stay bit-identical across fresh,
//! warm-memory and warm-disk evaluations.
//!
//! Regenerate the snapshot after an intentional model change with
//! `UPDATE_GOLDEN=1 cargo test --test integration_golden` and commit
//! the updated `tests/golden/sim_digests.json`.

use ciminus::eval::cache::StageHit;
use ciminus::eval::diskcache::DiskStore;
use ciminus::eval::{Evaluator, Scenario};
use ciminus::hw::presets;
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::util::json::Json;
use ciminus::workload::zoo;
use std::path::Path;
use std::sync::Arc;

const SNAPSHOT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/sim_digests.json");

/// The golden matrix: small enough for tier-1, wide enough to cover
/// the dense/sparse weight paths × input-skip on/off planning paths.
const MATRIX: [&str; 4] = ["dense-skip", "dense-noskip", "sparse-skip", "sparse-noskip"];

fn scenario(id: &str) -> Scenario {
    let mut arch = presets::usecase_arch(4, (2, 2));
    let bits = arch.input_bits;
    let skip = !id.ends_with("-noskip");
    if !skip {
        arch.sparsity.input_skipping = false;
    }
    let mut s = Scenario::new(arch, zoo::resnet_mini());
    if id.starts_with("sparse") {
        s = s.prune_uniform(&FlexBlock::hybrid(2, 16, 0.8));
    }
    if skip {
        s = s.synthetic_profiles(bits, 0.55, 0xE7A1);
    }
    s
}

fn digests() -> Vec<(String, String)> {
    let ev = Evaluator::new();
    MATRIX
        .iter()
        .map(|id| {
            let rep = ev.evaluate(&scenario(id)).unwrap();
            (id.to_string(), format!("{:032x}", rep.content_digest()))
        })
        .collect()
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "ciminus-golden-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The one test that owns snapshot I/O: bootstraps the snapshot when
/// it is missing (or `UPDATE_GOLDEN=1`), otherwise asserts the current
/// digests match it exactly.
#[test]
fn digests_match_golden_snapshot() {
    let fresh = digests();
    let path = Path::new(SNAPSHOT);
    let update = std::env::var("UPDATE_GOLDEN").map(|v| v == "1").unwrap_or(false);
    if update || !path.exists() {
        let mut j = Json::obj();
        for (id, d) in &fresh {
            j.set(id, Json::Str(d.clone()));
        }
        std::fs::write(path, format!("{}\n", j.pretty())).unwrap();
        eprintln!(
            "golden: wrote {} digest(s) to {} — commit the snapshot",
            fresh.len(),
            path.display()
        );
        return;
    }
    let j = Json::parse_file(path).unwrap();
    for (id, d) in &fresh {
        let want = j.get(id).and_then(|v| v.as_str()).unwrap_or_else(|| {
            panic!("snapshot missing entry `{id}` — regenerate with UPDATE_GOLDEN=1")
        });
        assert_eq!(
            d.as_str(),
            want,
            "content digest for `{id}` drifted from tests/golden/sim_digests.json; \
             if the model change is intentional, regenerate with UPDATE_GOLDEN=1"
        );
    }
}

/// Digests are invariant to *where* each stage artifact came from:
/// recomputed, memory-cached, or restored from the disk store.
#[test]
fn memory_and_disk_cached_reports_are_bit_identical() {
    let dir = tmp_dir("identity");
    let store = Arc::new(DiskStore::open(&dir, 0).unwrap());
    let warm = Evaluator::with_disk(store.clone());
    for id in MATRIX {
        let s = scenario(id);
        let fresh = Evaluator::new().evaluate(&s).unwrap();
        // computes and spills every stage to the shared store
        let first = warm.evaluate(&s).unwrap();
        // same evaluator again: pure memory hits
        let memory = warm.evaluate(&s).unwrap();
        // fresh memory caches, shared disk: restores instead of computing
        let disk = Evaluator::with_disk(store.clone()).evaluate(&s).unwrap();
        assert_eq!(fresh.content_digest(), first.content_digest(), "{id}: fresh vs spill");
        assert_eq!(fresh.content_digest(), memory.content_digest(), "{id}: fresh vs memory");
        assert_eq!(fresh.content_digest(), disk.content_digest(), "{id}: fresh vs disk");
        // provenance notes record where each report actually came from
        assert!(!first.cache.unwrap().sim_hit.hit(), "{id}: first run computes");
        assert_eq!(memory.cache.unwrap().sim_hit, StageHit::Memory, "{id}");
        assert_eq!(disk.cache.unwrap().sim_hit, StageHit::Disk, "{id}");
        assert_eq!(disk.cache.unwrap().mapping_hit, StageHit::Disk, "{id}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
