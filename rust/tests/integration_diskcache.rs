//! Integration: the persistent `--cache-dir` artifact store survives
//! process boundaries — a second evaluator (or a second *process*)
//! pointed at the same directory restores every unchanged stage from
//! disk instead of recomputing, a schema bump invalidates everything,
//! and the store never exceeds its byte bound or serves a corrupted
//! entry.

use ciminus::eval::diskcache::{DiskStore, Stage};
use ciminus::eval::hash::HASH_SCHEMA_VERSION;
use ciminus::eval::{Evaluator, Scenario};
use ciminus::hw::presets;
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::util::proptest::{check, ensure, ensure_eq};
use ciminus::workload::zoo;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const BIN: &str = env!("CARGO_BIN_EXE_ciminus");

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "ciminus-diskcache-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn scenario() -> Scenario {
    let arch = presets::usecase_arch(4, (2, 2));
    let bits = arch.input_bits;
    Scenario::new(arch, zoo::resnet_mini())
        .prune_uniform(&FlexBlock::hybrid(2, 16, 0.8))
        .synthetic_profiles(bits, 0.55, 0xE7A1)
}

/// Total bytes of real entries currently on disk under a store root.
fn disk_usage(root: &Path) -> u64 {
    let mut total = 0;
    for stage in Stage::ALL {
        if let Ok(dir) = std::fs::read_dir(root.join(stage.dir())) {
            for e in dir.flatten() {
                total += e.metadata().map(|m| m.len()).unwrap_or(0);
            }
        }
    }
    total
}

#[test]
fn second_evaluator_restores_everything_from_disk() {
    let dir = tmp_dir("restore");
    let s = scenario();
    let first = Evaluator::with_disk(Arc::new(DiskStore::open(&dir, 0).unwrap()));
    let rep_a = first.evaluate(&s).unwrap();
    assert!(first.stats().mapping.misses > 0, "first run computes");
    // a brand-new store handle over the same directory — nothing in
    // memory, everything restored from disk
    let second = Evaluator::with_disk(Arc::new(DiskStore::open(&dir, 0).unwrap()));
    let rep_b = second.evaluate(&s).unwrap();
    let stats = second.stats();
    assert_eq!(stats.mapping.misses, 0, "nothing replans: {stats}");
    assert_eq!(stats.sim.misses, 0, "nothing resimulates: {stats}");
    assert!(stats.total_disk_hits() > 0, "{stats}");
    assert_eq!(rep_a.content_digest(), rep_b.content_digest());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn schema_bump_invalidates_the_whole_store() {
    let dir = tmp_dir("schema");
    let s = scenario();
    let old = Evaluator::with_disk(Arc::new(
        DiskStore::open_with_schema(&dir, 0, HASH_SCHEMA_VERSION).unwrap(),
    ));
    old.evaluate(&s).unwrap();
    // same directory, bumped schema: the store namespaces itself under
    // a new versioned root, so every lookup is a clean miss
    let bumped = Evaluator::with_disk(Arc::new(
        DiskStore::open_with_schema(&dir, 0, HASH_SCHEMA_VERSION + 1).unwrap(),
    ));
    bumped.evaluate(&s).unwrap();
    let stats = bumped.stats();
    assert_eq!(stats.total_disk_hits(), 0, "no cross-schema restores: {stats}");
    assert!(stats.mapping.misses > 0, "everything recomputes: {stats}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_gc_never_leaves_store_over_its_byte_bound() {
    check("gc_byte_bound", 16, 0xD15C, |g| {
        let bound = *g.choose(&[256u64, 1024, 4096]);
        let dir = tmp_dir(&format!("bound-{}", g.case));
        let store = DiskStore::open(&dir, bound).unwrap();
        let n = g.usize_in(1, 12);
        for i in 0..n {
            let payload: Vec<u8> = vec![0xAB; g.usize_in(0, 2000)];
            store.put(*g.choose(&Stage::ALL), i as u128, &payload);
        }
        store.gc().map_err(|e| format!("gc failed: {e:#}"))?;
        let used = disk_usage(store.root());
        let _ = std::fs::remove_dir_all(&dir);
        ensure(
            used <= bound,
            format!("{used} bytes on disk exceeds the {bound}-byte bound"),
        )
    });
}

#[test]
fn prop_corrupted_or_truncated_entries_are_always_misses() {
    check("corruption_is_a_miss", 32, 0xBADC, |g| {
        let dir = tmp_dir(&format!("corrupt-{}", g.case));
        let store = DiskStore::open(&dir, 0).unwrap();
        let stage = *g.choose(&Stage::ALL);
        let payload: Vec<u8> = (0..g.usize_in(1, 512)).map(|i| i as u8).collect();
        store.put(stage, 42, &payload);
        let path = std::fs::read_dir(store.root().join(stage.dir()))
            .ok()
            .and_then(|mut d| d.next())
            .and_then(|e| e.ok())
            .map(|e| e.path())
            .ok_or("entry file not written")?;
        let mut raw = std::fs::read(&path).map_err(|e| e.to_string())?;
        if g.bool_with(0.5) {
            // flip one byte anywhere — header fields and payload alike
            let at = g.usize_in(0, raw.len() - 1);
            raw[at] ^= 0xFF;
        } else {
            // tear the file at an arbitrary point short of its full length
            raw.truncate(g.usize_in(0, raw.len() - 1));
        }
        std::fs::write(&path, &raw).map_err(|e| e.to_string())?;
        let got: Option<Vec<u8>> = store.get(stage, 42);
        let gone = !path.exists();
        let _ = std::fs::remove_dir_all(&dir);
        ensure_eq(got, None, "a damaged entry must read as a miss")?;
        ensure(gone, "a damaged entry must be deleted on first read")
    });
}

/// End-to-end: two *process-isolated* sweeps over one shared
/// `--cache-dir`. The second run restores every stage from disk (zero
/// replans) and the workers' counters flow back over the frame
/// protocol into the supervisor's `artifact cache:` summary.
#[test]
fn process_sweep_warm_cache_replans_nothing() {
    let dir = tmp_dir("process");
    let run = || {
        std::process::Command::new(BIN)
            .args([
                "faults",
                "--model",
                "resnet_mini",
                "--arch",
                "usecase4",
                "--rates",
                "0,0.05",
                "--isolation",
                "process",
                "--shards",
                "2",
                "--cache-dir",
            ])
            .arg(&dir)
            .output()
            .expect("spawning ciminus")
    };
    let cold = run();
    assert!(cold.status.success(), "cold run failed: {cold:?}");
    let warm = run();
    assert!(warm.status.success(), "warm run failed: {warm:?}");
    let stderr = String::from_utf8_lossy(&warm.stderr).into_owned();
    let line = stderr
        .lines()
        .find(|l| l.contains("artifact cache:"))
        .unwrap_or_else(|| panic!("no artifact-cache summary in stderr:\n{stderr}"));
    assert!(
        line.contains(", 0 replans"),
        "warm run must not replan anything: {line}"
    );
    let head = &line[..line.find(" disk hits").unwrap_or_else(|| panic!("no disk-hit count: {line}"))];
    let hits: u64 = head
        .rsplit(' ')
        .next()
        .and_then(|t| t.parse().ok())
        .unwrap_or_else(|| panic!("unparseable disk-hit count: {line}"));
    assert!(hits > 0, "warm run must restore from disk: {line}");
    let _ = std::fs::remove_dir_all(&dir);
}
