//! Integration: the CLI surface (in-process, no subprocess spawning).

fn run(args: &[&str]) -> i32 {
    ciminus::cli::run(args.iter().map(|s| s.to_string())).expect("cli runs")
}

#[test]
fn help_and_zoo() {
    assert_eq!(run(&["help"]), 0);
    assert_eq!(run(&["zoo"]), 0);
    assert_eq!(run(&["zoo", "resnet18"]), 0);
}

#[test]
fn simulate_patterns_and_strategies() {
    assert_eq!(
        run(&["simulate", "--model", "resnet_mini", "--pattern", "dense"]),
        0
    );
    assert_eq!(
        run(&[
            "simulate",
            "--model",
            "vgg_mini",
            "--pattern",
            "hybrid:2:16",
            "--ratio",
            "0.7",
            "--strategy",
            "dp",
            "--rearrange",
            "--detail"
        ]),
        0
    );
    assert_eq!(
        run(&[
            "simulate",
            "--model",
            "resnet_mini",
            "--arch",
            "mars",
            "--pattern",
            "rb:16",
            "--no-input-sparsity"
        ]),
        0
    );
}

#[test]
fn simulate_bad_input_errors() {
    let r = ciminus::cli::run(
        ["simulate", "--model", "resnet_mini", "--pattern", "wat"]
            .iter()
            .map(|s| s.to_string()),
    );
    assert!(r.is_err());
    let r2 = ciminus::cli::run(
        ["simulate", "--model", "no_such_model"]
            .iter()
            .map(|s| s.to_string()),
    );
    assert!(r2.is_err());
}

#[test]
fn explore_fig12_small() {
    assert_eq!(
        run(&["explore", "--study", "fig12", "--model", "resnet_mini"]),
        0
    );
}

#[test]
fn report_static_tables() {
    let out = std::env::temp_dir().join("ciminus_cli_report");
    assert_eq!(
        run(&["report", "--out", out.to_str().unwrap()]),
        0
    );
    assert!(out.join("tab1.csv").exists());
    assert!(out.join("tab2.csv").exists());
    std::fs::remove_dir_all(&out).ok();
}
