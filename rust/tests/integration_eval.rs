//! Integration: the staged evaluation pipeline is deterministic and
//! actually reuses cached artifacts across scenarios (ROADMAP: unified
//! eval pipeline).

use ciminus::eval::{Evaluator, Scenario};
use ciminus::hw::presets;
use ciminus::sim::engine::SimOptions;
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::workload::zoo;
use std::sync::Arc;

fn scenario(zero_frac: f64) -> Scenario {
    let arch = presets::usecase_arch(4, (2, 2));
    let bits = arch.input_bits;
    Scenario::new(arch, zoo::resnet_mini())
        .prune_uniform(&FlexBlock::hybrid(2, 16, 0.8))
        .synthetic_profiles(bits, zero_frac, 0xE7A1)
}

#[test]
fn cached_and_fresh_evaluations_are_bit_identical() {
    let s = scenario(0.55);
    let ev = Evaluator::new();
    let first = ev.evaluate(&s).unwrap();
    // same evaluator again: full sim-cache hit
    let cached = ev.evaluate(&s).unwrap();
    // fresh evaluator: everything recomputed from scratch
    let fresh = Evaluator::new().evaluate(&s).unwrap();
    assert_eq!(first.content_digest(), cached.content_digest());
    assert_eq!(first.content_digest(), fresh.content_digest());
    // only the cache-provenance note differs between the runs
    assert!(!first.cache.unwrap().sim_hit.hit());
    assert!(cached.cache.unwrap().sim_hit.hit());
    let stats = ev.stats();
    assert_eq!(stats.sim.misses, 1, "{stats}");
    assert_eq!(stats.sim.hits, 1, "{stats}");
}

#[test]
fn sim_only_change_replans_nothing() {
    let ev = Evaluator::new();
    let s = scenario(0.55);
    ev.evaluate(&s).unwrap();
    let planned = ev.stats().mapping.misses;
    let tweaked = s.with_sim(SimOptions {
        postproc_throughput: 1,
    });
    let rep = ev.evaluate(&tweaked).unwrap();
    let stats = ev.stats();
    assert_eq!(
        stats.mapping.misses, planned,
        "a sim-only change must not replan: {stats}"
    );
    assert_eq!(stats.mapping.hits, 1, "{stats}");
    assert_eq!(stats.prune.misses, 1, "{stats}");
    assert_eq!(stats.sim.misses, 2, "different SimOptions resimulate: {stats}");
    let note = rep.cache.unwrap();
    assert!(note.mapping_hit.hit());
    assert!(!note.sim_hit.hit());
}

#[test]
fn input_skip_pair_shares_planning_artifacts() {
    // the fig10/fig11-style skip vs no-skip pair: `input_skipping` is
    // canonicalized out of the planning-stage cache key
    let ev = Evaluator::new();
    let mut arch = presets::usecase_arch(4, (2, 2));
    let bits = arch.input_bits;
    let net = Arc::new(zoo::resnet_mini());
    let fb = FlexBlock::hybrid(2, 16, 0.8);
    for skip in [false, true] {
        arch.sparsity.input_skipping = skip;
        let s = Scenario::new(arch.clone(), net.clone())
            .prune_uniform(&fb)
            .synthetic_profiles(bits, 0.6, 0xE7A2);
        ev.evaluate(&s).unwrap();
    }
    let stats = ev.stats();
    assert_eq!(stats.mapping.misses, 1, "one plan for the pair: {stats}");
    assert_eq!(stats.mapping.hits, 1, "{stats}");
    assert_eq!(stats.prune.misses, 1, "{stats}");
    assert_eq!(stats.prune.hits, 1, "{stats}");
    assert_eq!(stats.sim.misses, 2, "both legs simulate: {stats}");
}
