//! Integration: the PJRT runtime path against built artifacts.
//! All tests skip gracefully when `make artifacts` has not run.

use ciminus::pruning::workflow::PruningWorkflow;
use ciminus::runtime::{input_profiles_for, Artifacts, ModelSession, Runtime};
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::workload::zoo;

fn artifacts() -> Option<Artifacts> {
    let dir = Artifacts::default_dir();
    if !Artifacts::available(&dir) {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Artifacts::load(&dir).expect("manifest parses"))
}

#[test]
fn manifest_layout_matches_zoo_mvm_dims() {
    let Some(arts) = artifacts() else { return };
    for (name, ma) in &arts.models {
        let net = zoo::by_name(name, 16, 10).unwrap();
        for p in &ma.params {
            let op = net
                .ops
                .iter()
                .find(|o| &o.name == &p.name)
                .unwrap_or_else(|| panic!("{name}: artifact param `{}` not in zoo graph", p.name));
            let d = net.mvm_dims(op.id).unwrap();
            if p.groups == 1 {
                assert_eq!((p.rows, p.cols), (d.rows, d.cols), "{name}/{}", p.name);
            } else {
                // depthwise stores (kh·kw, channels)
                assert_eq!(p.rows, d.rows, "{name}/{}", p.name);
                assert_eq!(p.cols, d.groups, "{name}/{}", p.name);
            }
        }
    }
}

#[test]
fn kernel_smoke_executes_via_pjrt() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().expect("PJRT cpu client");
    let exe = rt
        .load_hlo(&arts.dir.join("kernel_smoke.hlo.txt"))
        .expect("kernel smoke compiles");
    // x[8,64] @ (w*m)[64,32]: use identity-ish values for a checkable result
    let x: Vec<f32> = (0..8 * 64).map(|i| (i % 7) as f32 * 0.25).collect();
    let w: Vec<f32> = (0..64 * 32).map(|i| ((i % 5) as f32 - 2.0) * 0.5).collect();
    let ones = vec![1.0f32; 64 * 32];
    let zeros = vec![0.0f32; 64 * 32];
    let arg = |d: &[f32], dims: &[i64]| {
        ciminus::runtime::ArrayArg::new(d.to_vec(), dims.to_vec()).unwrap()
    };
    let full = exe
        .run_f32(&[arg(&x, &[8, 64]), arg(&w, &[64, 32]), arg(&ones, &[64, 32])])
        .unwrap();
    let masked = exe
        .run_f32(&[arg(&x, &[8, 64]), arg(&w, &[64, 32]), arg(&zeros, &[64, 32])])
        .unwrap();
    // zero mask → all-zero output; reference check on one element
    assert!(masked[0].iter().all(|&v| v == 0.0));
    let mut want = 0f32;
    for k in 0..64 {
        want += x[k] * w[k * 32];
    }
    assert!(
        (full[0][0] - want).abs() < 1e-3,
        "pallas kernel vs host ref: {} vs {want}",
        full[0][0]
    );
}

#[test]
fn dense_accuracy_matches_manifest() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    for name in ["resnet_mini", "vgg_mini"] {
        let session = ModelSession::new(&rt, &arts, name).unwrap();
        let ma = arts.model(name).unwrap();
        let acc = session.eval_blob(&ma.blob).unwrap();
        assert!(
            (acc - ma.dense_eval_acc).abs() < 0.02,
            "{name}: PJRT accuracy {acc} vs manifest {}",
            ma.dense_eval_acc
        );
    }
}

#[test]
fn pruning_degrades_gracefully_and_coarse_hurts_more() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let session = ModelSession::new(&rt, &arts, "resnet_mini").unwrap();
    let net = zoo::resnet_mini();
    let wf = PruningWorkflow::default();
    let mild = session
        .prune_and_eval(&net, &FlexBlock::hybrid(2, 16, 0.5), &wf)
        .unwrap();
    let harsh = session
        .prune_and_eval(&net, &FlexBlock::row_wise(0.9), &wf)
        .unwrap();
    assert!(mild.accuracy > harsh.accuracy, "mild {} vs harsh {}", mild.accuracy, harsh.accuracy);
    assert!(mild.accuracy <= mild.dense_accuracy + 0.02);
    assert!(harsh.weight_sparsity > 0.8);
}

#[test]
fn activation_profiles_are_meaningful() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let session = ModelSession::new(&rt, &arts, "resnet_mini").unwrap();
    let ma = arts.model("resnet_mini").unwrap();
    let profiles = session.profile_activations(&ma.blob, 8).unwrap();
    assert_eq!(profiles.len(), ma.taps.len());
    for (name, p) in &profiles {
        let skip1 = p.skip_ratio(1);
        assert!(
            (0.0..=1.0).contains(&skip1),
            "{name}: skip {skip1}"
        );
        // ReLU'd layers (not the raw input) have real zero bits
        if name != "stem" {
            assert!(skip1 > 0.1, "{name}: post-ReLU inputs skip: {skip1}");
        }
    }
    // rekeying to op ids covers every MVM op
    let net = zoo::resnet_mini();
    let ip = input_profiles_for(&net, &profiles);
    for id in net.mvm_ops() {
        assert!(ip.per_layer.contains_key(&id), "op {id} missing profile");
    }
}

#[test]
fn measured_profiles_feed_simulation() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let session = ModelSession::new(&rt, &arts, "resnet_mini").unwrap();
    let ma = arts.model("resnet_mini").unwrap();
    let net = zoo::resnet_mini();
    let profiles = input_profiles_for(&net, &session.profile_activations(&ma.blob, 8).unwrap());
    let arch = ciminus::hw::presets::usecase_arch(4, (2, 2));
    let mapping = ciminus::mapping::planner::plan(
        &arch,
        &net,
        None,
        ciminus::mapping::planner::MappingOptions::default(),
    )
    .unwrap();
    let rep = ciminus::sim::engine::simulate(
        &arch,
        &net,
        &mapping,
        Some(&profiles),
        ciminus::sim::engine::SimOptions::default(),
    )
    .unwrap();
    assert!(rep.mean_skip_ratio > 0.0, "measured profiles produce skipping");
}
