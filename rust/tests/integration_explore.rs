//! Integration: exploration studies reproduce the paper's findings in
//! direction (the "shape" contract of DESIGN.md §6).

use ciminus::explore::input_study;
use ciminus::explore::mapping_study;
use ciminus::explore::sparsity_study;
use ciminus::workload::zoo;

#[test]
fn finding1_efficiency_accuracy_tradeoff_shape() {
    // cost side of Finding 1: coarse > fine in speedup at fixed ratio
    let net = zoo::resnet_mini();
    let pts = sparsity_study::run_fig8(&net, &[0.8], 0).unwrap();
    let by = |name: &str| {
        pts.iter()
            .find(|p| p.pattern == name)
            .unwrap_or_else(|| panic!("{name} missing"))
    };
    let row_wise = by("Row-wise");
    let hybrid = by("1:2+Row-block(16)");
    assert!(
        row_wise.speedup >= hybrid.speedup,
        "coarse {} < fine {}",
        row_wise.speedup,
        hybrid.speedup
    );
    // everything should still beat dense
    for p in &pts {
        assert!(p.speedup > 1.0, "{}: {}", p.pattern, p.speedup);
    }
}

#[test]
fn fig9a_misaligned_blocks_fragment() {
    // block sizes that are not multiples of the array dims lose speedup
    let net = zoo::resnet50(32, 100);
    let pts = sparsity_study::run_fig9a(&net, 0).unwrap();
    let rb = |w: usize| {
        pts.iter()
            .find(|p| p.pattern == format!("Row-block({w})"))
            .unwrap()
    };
    // aligned 16/32 vs misaligned 24/48: aligned at least as good
    let aligned = rb(16).speedup.max(rb(32).speedup);
    let misaligned = rb(24).speedup.min(rb(48).speedup);
    assert!(
        aligned >= misaligned * 0.98,
        "aligned {aligned} vs misaligned {misaligned}"
    );
}

#[test]
fn fig10_input_sparsity_helps_dense_models() {
    let nets = [zoo::resnet_mini(), zoo::vgg_mini()];
    let refs: Vec<&_> = nets.iter().collect();
    let pts = input_study::run_dense_models(&refs, 0.55, 0).unwrap();
    for p in &pts {
        assert!(
            p.speedup_from_input > 1.0,
            "{}: {}",
            p.label,
            p.speedup_from_input
        );
        assert!(p.energy_saving_from_input > 1.0);
    }
}

#[test]
fn fig11_duplication_helps_resnet_hurts_vgg_relatively() {
    // Finding 2 shape: duplication gains more utilization on Conv-heavy
    // ResNet than on FC-heavy VGG.
    let r50 = zoo::resnet50(32, 100);
    let v16 = zoo::vgg16(32, 100);
    let pts = mapping_study::run_fig11(&[&r50, &v16], 0).unwrap();
    let util_gain = |model: &str| -> f64 {
        let sp: f64 = pts
            .iter()
            .filter(|p| p.model.starts_with(model) && p.strategy == "spatial")
            .map(|p| p.utilization)
            .sum();
        let dp: f64 = pts
            .iter()
            .filter(|p| p.model.starts_with(model) && p.strategy == "duplicate")
            .map(|p| p.utilization)
            .sum();
        dp / sp
    };
    let resnet_gain = util_gain("resnet50");
    let vgg_gain = util_gain("vgg16");
    assert!(
        resnet_gain > vgg_gain,
        "resnet util gain {resnet_gain:.2} <= vgg {vgg_gain:.2}"
    );
    assert!(resnet_gain > 1.1, "duplication helps resnet: {resnet_gain:.2}");
}

#[test]
fn fig12_rearrangement_utilization_up_buffer_cost_up() {
    let r50 = zoo::resnet50(32, 100);
    let pts = mapping_study::run_fig12(&r50, 0).unwrap();
    for strat in ["spatial", "duplicate"] {
        let base = pts
            .iter()
            .find(|p| p.strategy == strat && !p.rearranged)
            .unwrap();
        let rearr = pts
            .iter()
            .find(|p| p.strategy == strat && p.rearranged)
            .unwrap();
        assert!(rearr.utilization >= base.utilization - 1e-9, "{strat}");
        // weight-buffer traffic rises with the shuffle
        assert!(
            rearr.weight_buf_accesses >= base.weight_buf_accesses,
            "{strat}: rearranged buffer traffic {} < base {}",
            rearr.weight_buf_accesses,
            base.weight_buf_accesses
        );
    }
}

#[test]
fn validation_scenarios_within_sane_band() {
    // full Fig. 6 run: errors are finite and the direction (speedup > 1,
    // saving > 1) matches every published point
    let points = ciminus::validate::run_validation().unwrap();
    assert_eq!(points.len(), 8);
    for p in &points {
        assert!(
            p.estimated > 1.0,
            "{} {} {}: estimated {}",
            p.design,
            p.workload,
            p.metric,
            p.estimated
        );
        assert!(p.err_pct().is_finite());
    }
}
