//! Integration: fault injection and graceful degradation end-to-end —
//! the zero-fault identity contract, monotone overhead vs. fault
//! density, determinism, the unusable-chip error, and the `faults` CLI.

use ciminus::hw::faults::{FaultModel, FaultSpatial};
use ciminus::hw::presets;
use ciminus::mapping::planner::{plan, MappingOptions};
use ciminus::sim::engine::{simulate, simulate_network_default, SimOptions};
use ciminus::sim::input_sparsity::InputProfiles;
use ciminus::sim::report::SimReport;
use ciminus::workload::zoo;

/// Row-quarantine-only model: all four macros stay usable, so overhead
/// comes purely from shrinking geometry + repair traffic. (With macro /
/// column deaths the curve is still computed, but a dying macro can
/// *relax* the weakest-survivor geometry, so per-sample latency is not
/// structurally monotone — rows-only is where the contract is exact.)
fn rows_only(rate: f64, spatial: FaultSpatial, seed: u64) -> FaultModel {
    FaultModel {
        seed,
        stuck_cell_rate: rate,
        spatial,
        dead_column_rate: 0.0,
        dead_macro_rate: 0.0,
        spare_rows: 0,
        spare_cols: 0,
    }
}

fn simulate_with(model: FaultModel) -> anyhow::Result<SimReport> {
    let mut arch = presets::usecase_arch(4, (2, 2));
    arch.faults = model;
    let net = zoo::resnet_mini();
    let mapping = plan(&arch, &net, None, MappingOptions::default())?;
    let profiles = InputProfiles::synthetic(&net, arch.input_bits, 0.55, 0xC1A0);
    simulate(&arch, &net, &mapping, Some(&profiles), SimOptions::default())
}

/// The acceptance contract: an all-zero FaultModel must be bit-identical
/// to the fault-free path — same cycles, same energy, no faults summary.
#[test]
fn zero_fault_model_is_bit_identical_to_fault_free_path() {
    let clean_arch = presets::usecase_arch(4, (2, 2));
    let net = zoo::resnet_mini();
    let clean = simulate_network_default(&clean_arch, &net, None).unwrap();

    let mut zeroed = clean_arch.clone();
    zeroed.faults = FaultModel {
        seed: 42, // a non-default seed must not matter when all rates are 0
        ..FaultModel::none()
    };
    let report = simulate_network_default(&zeroed, &net, None).unwrap();

    assert_eq!(report.total_cycles, clean.total_cycles);
    assert_eq!(report.energy.total_pj.to_bits(), clean.energy.total_pj.to_bits());
    assert_eq!(report.mean_utilization.to_bits(), clean.mean_utilization.to_bits());
    assert!(report.faults.is_none(), "zero model must not produce a degradation summary");
}

/// Latency, energy and capacity loss are non-decreasing in fault density
/// (fixed seed; dense weights so tiling-shape slack cannot mask growth).
#[test]
fn overhead_grows_monotonically_with_fault_density() {
    // Rates sized to the usecase macro (1024x32, 32x32 sub-arrays):
    // uniform row-quarantine saturates fast (p_row = 1-(1-p)^32), so its
    // axis stays below 0.08; cluster needs larger p to bite at all.
    for (spatial, rates) in [
        (FaultSpatial::Uniform, [0.0, 0.01, 0.03, 0.08]),
        (FaultSpatial::Cluster, [0.0, 0.05, 0.1, 0.2]),
    ] {
        let reports: Vec<SimReport> = rates
            .iter()
            .map(|&r| simulate_with(rows_only(r, spatial, 0xD1E)).unwrap())
            .collect();
        for (prev, next) in reports.iter().zip(reports.iter().skip(1)) {
            assert!(
                next.total_cycles >= prev.total_cycles,
                "{spatial:?}: cycles {} -> {} not monotone",
                prev.total_cycles,
                next.total_cycles
            );
            assert!(
                next.energy.total_pj >= prev.energy.total_pj,
                "{spatial:?}: energy {} -> {} not monotone",
                prev.energy.total_pj,
                next.energy.total_pj
            );
            let loss = |r: &SimReport| r.faults.as_ref().map(|f| f.capacity_loss).unwrap_or(0.0);
            assert!(loss(next) >= loss(prev), "{spatial:?}: capacity loss not monotone");
        }
        let worst = reports.last().unwrap();
        assert!(
            worst.total_cycles > reports[0].total_cycles,
            "{spatial:?}: the top fault density must cost latency"
        );
        let f = worst.faults.as_ref().expect("degradation summary present");
        assert!(f.capacity_loss > 0.0);
        assert!(f.repair_bytes > 0);
    }
}

#[test]
fn same_seed_is_deterministic_and_seeds_differ() {
    let a = simulate_with(rows_only(0.05, FaultSpatial::Uniform, 7)).unwrap();
    let b = simulate_with(rows_only(0.05, FaultSpatial::Uniform, 7)).unwrap();
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.energy.total_pj.to_bits(), b.energy.total_pj.to_bits());
    assert_eq!(a.faults, b.faults);
    // seed independence checked at the fault-map level, where counts are
    // fine-grained enough that distinct seeds essentially never collide
    let arch = presets::usecase_arch(4, (2, 2));
    let m7 = rows_only(0.05, FaultSpatial::Uniform, 7).instantiate(&arch.cim, &arch.org);
    let m8 = rows_only(0.05, FaultSpatial::Uniform, 8).instantiate(&arch.cim, &arch.org);
    assert_ne!(m7, m8, "independent seeds should draw different fault maps");
}

#[test]
fn fully_faulted_chip_is_a_planning_error() {
    let mut arch = presets::usecase_arch(4, (2, 2));
    arch.faults = FaultModel {
        dead_macro_rate: 1.0,
        ..FaultModel::none()
    };
    let net = zoo::resnet_mini();
    let err = plan(&arch, &net, None, MappingOptions::default()).unwrap_err();
    assert!(err.to_string().contains("unusable"), "{err}");
}

/// Degraded runs must surface in the report text so users see the loss.
#[test]
fn summary_reports_degradation() {
    let rep = simulate_with(rows_only(0.05, FaultSpatial::Uniform, 3)).unwrap();
    let s = rep.summary();
    assert!(s.contains("faults"), "summary missing faults line:\n{s}");
    assert!(s.contains("capacity loss"));
}

fn run_cli(args: &[&str]) -> i32 {
    ciminus::cli::run(args.iter().map(|s| s.to_string())).expect("cli runs")
}

/// Acceptance: the `faults` subcommand emits resilience curves for at
/// least two preset architectures, in table and JSON form.
#[test]
fn faults_cli_covers_two_presets() {
    assert_eq!(
        run_cli(&[
            "faults",
            "--model",
            "resnet_mini",
            "--arch",
            "usecase4,mars",
            "--rates",
            "0,0.05",
        ]),
        0
    );
    assert_eq!(
        run_cli(&[
            "faults",
            "--model",
            "resnet_mini",
            "--arch",
            "usecase4",
            "--rates",
            "0,0.02",
            "--spatial",
            "cluster",
            "--pattern",
            "row_wise",
            "--ratio",
            "0.8",
            "--json",
        ]),
        0
    );
}
