//! Integration: process-isolated sweep shards end-to-end, driving the
//! real `ciminus` binary. Thread-mode isolation cannot survive a job
//! that calls `std::process::abort()`; these tests prove process mode
//! does — the sweep completes with a structured `crashed` failure, a
//! hard-killed hang, partial results in the canonical journal, and a
//! clean `--resume`. Also covers the offline `journal merge` command.

use std::path::{Path, PathBuf};
use std::process::Command;

const BIN: &str = env!("CARGO_BIN_EXE_ciminus");

struct Run {
    code: i32,
    stdout: String,
    stderr: String,
}

fn run(args: &[&str]) -> Run {
    let out = Command::new(BIN)
        .args(args)
        .output()
        .expect("spawning the ciminus binary");
    Run {
        code: out.status.code().unwrap_or(-1),
        stdout: String::from_utf8_lossy(&out.stdout).into_owned(),
        stderr: String::from_utf8_lossy(&out.stderr).into_owned(),
    }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "ciminus-itest-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&dir).expect("creating temp dir");
    dir
}

fn shard_files(ckpt: &Path) -> Vec<PathBuf> {
    let parent = ckpt.parent().expect("checkpoint has a parent dir");
    let prefix = format!(
        "{}.shard-",
        ckpt.file_name().and_then(|s| s.to_str()).expect("file name")
    );
    std::fs::read_dir(parent)
        .expect("reading temp dir")
        .flatten()
        .filter(|e| e.file_name().to_str().is_some_and(|n| n.starts_with(&prefix)))
        .map(|e| e.path())
        .collect()
}

/// The ISSUE acceptance scenario: under `--isolation=process` the smoke
/// study grows a ninth point that calls `std::process::abort()`. The
/// sweep must survive the abort (as a `crashed` failure), hard-kill the
/// hanging point past `--job-timeout`, journal the six good points, and
/// replay them all on `--resume`.
#[test]
fn process_smoke_survives_abort_and_hang_and_resumes() {
    let dir = temp_dir("process-smoke");
    let ckpt = dir.join("smoke.jsonl");
    let ckpt_s = ckpt.to_str().expect("utf-8 path");

    let first = run(&[
        "explore", "--study", "smoke", "--isolation", "process", "--shards", "2",
        "--job-timeout", "1", "--checkpoint", ckpt_s,
    ]);
    let log = format!("stdout:\n{}\nstderr:\n{}", first.stdout, first.stderr);
    assert_eq!(first.code, 3, "partial exit code\n{log}");
    assert!(
        first.stderr.contains("crashed"),
        "the aborting point must surface as a crashed failure\n{log}"
    );
    assert!(
        first.stderr.contains("timeout"),
        "the hanging point must be hard-killed and reported\n{log}"
    );
    assert!(
        first.stderr.contains("panic"),
        "the panicking point survives inside the worker\n{log}"
    );
    assert!(
        first.stderr.contains("3 failed"),
        "exactly panic + timeout + abort fail\n{log}"
    );
    let journal = std::fs::read_to_string(&ckpt).expect("canonical journal written");
    assert_eq!(
        journal.lines().count(),
        6,
        "6 of 9 process-mode smoke points completed and were merged:\n{journal}"
    );
    assert!(
        shard_files(&ckpt).is_empty(),
        "shard journals are folded into the canonical journal and removed"
    );

    // resume: the six journaled points replay without recomputation,
    // the three bad ones fail again
    let second = run(&[
        "explore", "--study", "smoke", "--isolation", "process", "--shards", "2",
        "--job-timeout", "1", "--checkpoint", ckpt_s, "--resume",
    ]);
    let log = format!("stdout:\n{}\nstderr:\n{}", second.stdout, second.stderr);
    assert_eq!(second.code, 3, "{log}");
    assert!(
        second.stderr.contains("6 resumed"),
        "all completed points replay from the journal\n{log}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Same sweep, thread mode, for contrast: thread isolation has no abort
/// point (it would kill the test process), so the canonical smoke sweep
/// stays at 8 points with 2 failures. Guards the default path against
/// regressions from the process-mode plumbing.
#[test]
fn thread_smoke_is_unchanged_by_process_plumbing() {
    let dir = temp_dir("thread-smoke");
    let ckpt = dir.join("smoke.jsonl");
    let r = run(&[
        "explore", "--study", "smoke", "--job-timeout", "0.3",
        "--checkpoint", ckpt.to_str().expect("utf-8 path"),
    ]);
    assert_eq!(r.code, 3, "stderr:\n{}", r.stderr);
    assert!(r.stdout.contains("6 of 8 points completed"), "{}", r.stdout);
    assert!(!r.stderr.contains("crashed"), "{}", r.stderr);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A sized, failure-free smoke sweep in process mode: every point
/// lands, the journal is complete, and a re-run resumes everything.
#[test]
fn sized_process_smoke_completes_cleanly() {
    let dir = temp_dir("sized-smoke");
    let ckpt = dir.join("clean.jsonl");
    let ckpt_s = ckpt.to_str().expect("utf-8 path");
    let r = run(&[
        "explore", "--study", "smoke", "--isolation", "process", "--shards", "3",
        "--smoke-points", "12", "--checkpoint", ckpt_s,
    ]);
    assert_eq!(r.code, 0, "stderr:\n{}", r.stderr);
    let journal = std::fs::read_to_string(&ckpt).expect("journal written");
    assert_eq!(journal.lines().count(), 12, "{journal}");
    let again = run(&[
        "explore", "--study", "smoke", "--isolation", "process", "--shards", "3",
        "--smoke-points", "12", "--checkpoint", ckpt_s, "--resume",
    ]);
    assert_eq!(again.code, 0, "stderr:\n{}", again.stderr);
    assert!(again.stderr.contains("12 resumed"), "{}", again.stderr);
    let _ = std::fs::remove_dir_all(&dir);
}

/// `journal merge` folds shard journals into a canonical checkpoint
/// with last-writer-wins keys, and is idempotent.
#[test]
fn journal_merge_cli_is_last_writer_wins() {
    let dir = temp_dir("journal-merge");
    let canon = dir.join("canon.jsonl");
    let s0 = dir.join("s0.jsonl");
    let s1 = dir.join("s1.jsonl");
    std::fs::write(&canon, "{\"key\":\"a\",\"ok\":1}\n").expect("seed canonical");
    std::fs::write(&s0, "{\"key\":\"a\",\"ok\":1}\n{\"key\":\"b\",\"ok\":2}\n").expect("shard 0");
    std::fs::write(&s1, "{\"key\":\"b\",\"ok\":3}\n").expect("shard 1");
    let canon_s = canon.to_str().expect("utf-8 path");
    let r = run(&[
        "journal", "merge", "--into", canon_s,
        s0.to_str().expect("utf-8 path"),
        s1.to_str().expect("utf-8 path"),
    ]);
    assert_eq!(r.code, 0, "stderr:\n{}", r.stderr);
    assert!(r.stdout.contains("merged 1 new entries"), "{}", r.stdout);
    let map = ciminus::explore::executor::Journal::load_map(&canon).expect("canonical loads");
    assert_eq!(map.len(), 2);
    assert_eq!(
        map.get("b").and_then(|v| v.as_f64()),
        Some(3.0),
        "later shard wins the duplicate key"
    );
    // merging the same shards again appends nothing
    let again = run(&[
        "journal", "merge", "--into", canon_s,
        s0.to_str().expect("utf-8 path"),
        s1.to_str().expect("utf-8 path"),
    ]);
    assert_eq!(again.code, 0);
    assert!(again.stdout.contains("merged 0 new entries"), "{}", again.stdout);
    let _ = std::fs::remove_dir_all(&dir);
}
