//! Failure injection: corrupted artifacts, inconsistent configs, and
//! malformed inputs must produce errors, not wrong numbers.

use ciminus::hw::arch::Architecture;
use ciminus::runtime::Artifacts;
use ciminus::util::json::Json;
use std::fs;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ciminus_fail_{tag}_{}", std::process::id()));
    fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_detected() {
    let d = tmpdir("nomanifest");
    assert!(!Artifacts::available(&d));
    assert!(Artifacts::load(&d).is_err());
    fs::remove_dir_all(&d).ok();
}

#[test]
fn corrupt_manifest_rejected() {
    let d = tmpdir("corrupt");
    fs::write(d.join("manifest.json"), "{not json").unwrap();
    assert!(Artifacts::load(&d).is_err());
    fs::write(d.join("manifest.json"), r#"{"img": 16}"#).unwrap();
    let err = Artifacts::load(&d).unwrap_err().to_string();
    assert!(err.contains("models"), "{err}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn truncated_weights_blob_rejected() {
    let d = tmpdir("truncated");
    let manifest = r#"{
        "format_version": 1, "img": 16, "classes": 10,
        "fwd_batch": 4, "acts_batch": 2, "eval_n": 8,
        "models": {"m": {
            "params": [{"name": "fc", "rows": 4, "cols": 4, "groups": 1,
                        "w_offset": 0, "b_offset": 16}],
            "total_floats": 20,
            "weights_sha": "x",
            "dense_eval_acc": 0.5,
            "taps": ["fc"],
            "fwd_hlo": "f.hlo.txt", "acts_hlo": "a.hlo.txt",
            "weights_bin": "w.bin", "graph_json": "g.json"
        }}
    }"#;
    fs::write(d.join("manifest.json"), manifest).unwrap();
    // 10 floats instead of 20
    fs::write(d.join("w.bin"), vec![0u8; 40]).unwrap();
    let err = Artifacts::load(&d).unwrap_err().to_string();
    assert!(err.contains("mismatch"), "{err}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn misaligned_binary_rejected() {
    let d = tmpdir("misaligned");
    let manifest = r#"{
        "format_version": 1, "img": 16, "classes": 10,
        "fwd_batch": 4, "acts_batch": 2, "eval_n": 8,
        "models": {"m": {
            "params": [], "total_floats": 0, "weights_sha": "x",
            "dense_eval_acc": 0.5, "taps": [],
            "fwd_hlo": "f.hlo.txt", "acts_hlo": "a.hlo.txt",
            "weights_bin": "w.bin", "graph_json": "g.json"
        }}
    }"#;
    fs::write(d.join("manifest.json"), manifest).unwrap();
    fs::write(d.join("w.bin"), vec![0u8; 7]).unwrap(); // not /4
    let err = Artifacts::load(&d).unwrap_err().to_string();
    assert!(err.contains("aligned"), "{err}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn invalid_architecture_configs_rejected() {
    for bad in [
        r#"{"macro": {"rows": 0}}"#,
        r#"{"macro": {"rows": 100, "sub_rows": 64}}"#,
        r#"{"org": [0, 4]}"#,
        r#"{"org": [2, 2, 2]}"#,
        r#"{"clock_ghz": -1}"#,
        r#"{"input_bits": 99}"#,
        r#"{"energy": {"mux": {"dynamic_pj": -5}}}"#,
    ] {
        let j = Json::parse(bad).unwrap();
        assert!(
            Architecture::from_json(&j).is_err(),
            "config accepted but invalid: {bad}"
        );
    }
}

#[test]
fn runtime_missing_hlo_file_errors() {
    let rt = match ciminus::runtime::Runtime::cpu() {
        Ok(rt) => rt,
        Err(_) => return, // no PJRT in this environment
    };
    assert!(rt.load_hlo(std::path::Path::new("/no/such/file.hlo.txt")).is_err());
}

#[test]
fn garbage_hlo_text_errors() {
    let rt = match ciminus::runtime::Runtime::cpu() {
        Ok(rt) => rt,
        Err(_) => return,
    };
    let d = tmpdir("badhlo");
    let p = d.join("bad.hlo.txt");
    fs::write(&p, "this is not hlo").unwrap();
    assert!(rt.load_hlo(&p).is_err());
    fs::remove_dir_all(&d).ok();
}
