//! Integration: mapping legality across architectures × workloads ×
//! sparsity patterns, and strategy/rearrangement effects.

use ciminus::hw::presets;
use ciminus::mapping::duplication::{Strategy, StrategyPolicy};
use ciminus::mapping::planner::{plan, MappingOptions};
use ciminus::pruning::workflow::PruningWorkflow;
use ciminus::sparsity::flexblock::FlexBlock;
use ciminus::util::proptest::{check, ensure};
use ciminus::workload::zoo;

#[test]
fn every_zoo_model_maps_on_every_preset() {
    let archs = [
        presets::mars(),
        presets::sdp(),
        presets::usecase_arch(4, (2, 2)),
        presets::usecase_arch(16, (4, 4)),
    ];
    for name in zoo::ZOO_NAMES {
        let net = zoo::by_name(name, 32, 100).unwrap();
        for arch in &archs {
            let p = plan(arch, &net, None, MappingOptions::default())
                .unwrap_or_else(|e| panic!("{name} on {}: {e}", arch.name));
            assert_eq!(p.ops.len(), net.mvm_ops().len());
            for m in p.ops.values() {
                assert!(m.tiling.utilization > 0.0, "{name}/{}", m.name);
                assert!(m.tiling.utilization <= 1.0 + 1e-9);
                m.loopnest.validate(&arch.org).unwrap();
            }
        }
    }
}

#[test]
fn prop_mapping_conserves_work() {
    // every round's occupancy summed over rounds ≥ nnz of each layer
    // (duplication may multiply it; spatial keeps it exact)
    check("work_conservation", 30, 0x30B, |g| {
        let ratio = g.f64_in(0.5, 0.9);
        let fb = match g.usize_in(0, 2) {
            0 => FlexBlock::row_wise(ratio),
            1 => FlexBlock::row_block(16, ratio),
            _ => FlexBlock::hybrid(2, 16, ratio.max(0.55)),
        };
        let net = zoo::resnet_mini();
        let arch = presets::usecase_arch(4, (2, 2));
        let wf = PruningWorkflow {
            seed: g.rng.next_u64(),
            ..Default::default()
        };
        let prune = wf.run_uniform(&net, &fb, None).map_err(|e| e.to_string())?;
        let p = plan(
            &arch,
            &net,
            Some(&prune),
            MappingOptions {
                policy: StrategyPolicy::Fixed(Strategy::Spatial),
                ..Default::default()
            },
        )
        .map_err(|e| e.to_string())?;
        for m in p.ops.values() {
            let mapped: u64 = m
                .tiling
                .rounds
                .iter()
                .map(|r| r.occupied_cells())
                .sum();
            // physical occupancy covers at least the compressed payload
            let payload: usize = m.layout.row_lengths.iter().sum();
            ensure(
                mapped >= payload as u64,
                format!("{}: mapped {mapped} < payload {payload}", m.name),
            )?;
        }
        Ok(())
    });
}

#[test]
fn duplication_improves_utilization_on_small_conv_layers() {
    let net = zoo::resnet50(32, 100);
    let arch = presets::usecase_arch(16, (4, 4));
    let wf = PruningWorkflow::default();
    let prune = wf
        .run_uniform(&net, &FlexBlock::row_wise(0.8), None)
        .unwrap();
    let sp = plan(
        &arch,
        &net,
        Some(&prune),
        MappingOptions {
            policy: StrategyPolicy::Fixed(Strategy::Spatial),
            ..Default::default()
        },
    )
    .unwrap();
    let dp = plan(
        &arch,
        &net,
        Some(&prune),
        MappingOptions {
            policy: StrategyPolicy::Fixed(Strategy::Duplicate),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(
        dp.mean_utilization() > sp.mean_utilization(),
        "dup {} <= sp {}",
        dp.mean_utilization(),
        sp.mean_utilization()
    );
}

#[test]
fn rearrangement_never_hurts_utilization() {
    let net = zoo::resnet50(32, 100);
    let arch = presets::usecase_arch(16, (4, 4));
    let wf = PruningWorkflow::default();
    for fb in [FlexBlock::row_block(16, 0.8), FlexBlock::hybrid(2, 16, 0.8)] {
        let prune = wf.run_uniform(&net, &fb, None).unwrap();
        let base = plan(&arch, &net, Some(&prune), MappingOptions::default()).unwrap();
        let rearr = plan(
            &arch,
            &net,
            Some(&prune),
            MappingOptions {
                rearrange: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            rearr.mean_utilization() >= base.mean_utilization() - 1e-9,
            "{}: {} < {}",
            fb.name,
            rearr.mean_utilization(),
            base.mean_utilization()
        );
    }
}

#[test]
fn verification_rejects_missing_hw_support() {
    // Sec. IV-B functional verification: needs indexing/routing hardware
    let net = zoo::resnet_mini();
    let wf = PruningWorkflow::default();
    let prune_intra = wf
        .run_uniform(&net, &FlexBlock::intra(2, 0.5), None)
        .unwrap();
    let mut arch = presets::usecase_arch(4, (2, 2));
    arch.sparsity.weight_routing = false;
    let err = plan(&arch, &net, Some(&prune_intra), MappingOptions::default())
        .expect_err("intra without routing must fail verification");
    assert!(err.to_string().contains("routing"), "{err}");
}
