"""SynthCIFAR: a deterministic synthetic image-classification dataset.

Stands in for CIFAR-100/ImageNet (unavailable offline — DESIGN.md §3).
Ten classes, 16x16 RGB. Each class is a distinct oriented sinusoidal
grating with a class-specific color balance; samples add Gaussian noise
and random phase so the task is learnable but not trivial. Everything is
seeded: the same arrays are regenerated bit-for-bit at every build.
"""

from __future__ import annotations

import numpy as np

IMG = 16
CHANNELS = 3
NUM_CLASSES = 10
TRAIN_N = 4096
EVAL_N = 1024
SEED = 0xC1A05


def _class_template(cls: int, phase: float) -> np.ndarray:
    """Oriented grating + color signature for one class."""
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32) / IMG
    angle = np.pi * cls / NUM_CLASSES
    freq = 2.0 + (cls % 3)
    wave = np.sin(2 * np.pi * freq * (np.cos(angle) * xx + np.sin(angle) * yy) + phase)
    color = np.array(
        [
            0.6 + 0.4 * np.cos(2 * np.pi * cls / NUM_CLASSES),
            0.6 + 0.4 * np.cos(2 * np.pi * cls / NUM_CLASSES + 2.1),
            0.6 + 0.4 * np.cos(2 * np.pi * cls / NUM_CLASSES + 4.2),
        ],
        dtype=np.float32,
    )
    return wave[:, :, None] * color[None, None, :]


def make_split(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` (image, label) pairs. Images are NHWC float32 in
    roughly [-1.5, 1.5]; labels int32."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    images = np.empty((n, IMG, IMG, CHANNELS), dtype=np.float32)
    for i, cls in enumerate(labels):
        phase = rng.uniform(0, 2 * np.pi)
        img = _class_template(int(cls), phase)
        # heavy noise keeps dense accuracy off the ceiling so the
        # pruning-accuracy trade-off curves (Fig. 8/9) have dynamic range
        img = 0.6 * img + rng.normal(0, 0.85, size=img.shape).astype(np.float32)
        images[i] = img
    return images, labels


def train_split() -> tuple[np.ndarray, np.ndarray]:
    return make_split(TRAIN_N, SEED)


def eval_split() -> tuple[np.ndarray, np.ndarray]:
    return make_split(EVAL_N, SEED + 1)
