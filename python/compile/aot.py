"""AOT artifact builder (the entire Python lifetime of the system).

`python -m compile.aot --out ../artifacts` trains the three mini models
on SynthCIFAR, then writes everything the rust runtime needs:

- weights_<model>.bin      flat f32 LE blobs in param_spec order (w then b
                           per MVM op) — the reshaped 2-D matrices rust
                           prunes directly
- model_<model>_fwd.hlo.txt   fwd(params..., x[B,16,16,3]) -> (logits,)
                              with the Pallas FlexBlock matmul on the FC
                              path (interpret-lowered to plain HLO)
- model_<model>_acts.hlo.txt  fwd returning per-MVM-op input activations
                              (input-sparsity profiling taps)
- graph_<model>.json       workload-DAG interchange (ONNX substitute)
- eval_images.bin / eval_labels.bin / calib_images.bin  SynthCIFAR splits
- kernel_smoke.hlo.txt     standalone Pallas kernel for runtime checks
- manifest.json            shapes, offsets, op names, accuracies, hashes

HLO *text* is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, models, train
from .kernels.flexblock_matmul import flexblock_matmul

FWD_BATCH = 256
ACTS_BATCH = 64
TRAIN_STEPS = {"resnet_mini": 400, "vgg_mini": 400, "mobilenet_mini": 500}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flat_args(model: str, params) -> list[jnp.ndarray]:
    """Parameters flattened in the manifest contract order."""
    out = []
    for name, _r, _c, _g in models.param_spec(model):
        out.append(params[name]["w"])
        out.append(params[name]["b"])
    return out


def unflatten(model: str, args) -> dict:
    params = {}
    it = iter(args)
    for name, _r, _c, _g in models.param_spec(model):
        params[name] = {"w": next(it), "b": next(it)}
    return params


def lower_fwd(model: str, batch: int) -> str:
    spec = models.param_spec(model)

    def fn(*args):
        params = unflatten(model, args[:-1])
        x = args[-1]
        return (models.forward(model, params, x, use_pallas=True),)

    arg_specs = []
    for _name, r, c, _g in spec:
        arg_specs.append(jax.ShapeDtypeStruct((r, c), jnp.float32))
        arg_specs.append(jax.ShapeDtypeStruct((c,), jnp.float32))
    arg_specs.append(jax.ShapeDtypeStruct((batch, data.IMG, data.IMG, 3), jnp.float32))
    lowered = jax.jit(fn).lower(*arg_specs)
    return to_hlo_text(lowered)


def lower_acts(model: str, batch: int) -> tuple[str, list[str]]:
    spec = models.param_spec(model)
    tap_order = [name for name, _r, _c, _g in spec]

    def fn(*args):
        params = unflatten(model, args[:-1])
        x = args[-1]
        logits, taps = models.forward(
            model, params, x, use_pallas=False, collect_taps=True
        )
        # logits first (keeps every parameter live — XLA would otherwise
        # prune the classifier weights and change the argument arity),
        # then each tap flattened to 1-D so the rust side reads vectors
        return (logits,) + tuple(taps[name].reshape(-1) for name in tap_order)

    arg_specs = []
    for _name, r, c, _g in spec:
        arg_specs.append(jax.ShapeDtypeStruct((r, c), jnp.float32))
        arg_specs.append(jax.ShapeDtypeStruct((c,), jnp.float32))
    arg_specs.append(jax.ShapeDtypeStruct((batch, data.IMG, data.IMG, 3), jnp.float32))
    lowered = jax.jit(fn).lower(*arg_specs)
    return to_hlo_text(lowered), tap_order


def lower_kernel_smoke() -> str:
    def fn(x, w, m):
        return (flexblock_matmul(x, w, m, interpret=True),)

    s = jax.ShapeDtypeStruct
    lowered = jax.jit(fn).lower(
        s((8, 64), jnp.float32), s((64, 32), jnp.float32), s((64, 32), jnp.float32)
    )
    return to_hlo_text(lowered)


def write_bin(path: str, arr: np.ndarray) -> str:
    b = np.ascontiguousarray(arr).tobytes()
    with open(path, "wb") as f:
        f.write(b)
    return hashlib.sha256(b).hexdigest()[:16]


def load_params_from_blob(model: str, path: str):
    """Rebuild a params dict from an existing weights blob (lets
    `--reuse-weights` re-lower HLO without retraining)."""
    blob = np.fromfile(path, dtype=np.float32)
    params = {}
    offset = 0
    for name, r, c, _g in models.param_spec(model):
        w = blob[offset : offset + r * c].reshape(r, c)
        b = blob[offset + r * c : offset + r * c + c]
        params[name] = {"w": jnp.asarray(w), "b": jnp.asarray(b)}
        offset += r * c + c
    assert offset == blob.size, f"{model}: blob size mismatch"
    return params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=0, help="override train steps (0=default)")
    ap.add_argument(
        "--reuse-weights",
        action="store_true",
        help="skip training when weights_<model>.bin already exists",
    )
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    manifest: dict = {
        "format_version": 1,
        "img": data.IMG,
        "classes": data.NUM_CLASSES,
        "fwd_batch": FWD_BATCH,
        "acts_batch": ACTS_BATCH,
        "eval_n": data.EVAL_N,
        "models": {},
    }

    # ---- dataset ----
    ex, ey = data.eval_split()
    tx, _ty = data.train_split()
    manifest["eval_images_sha"] = write_bin(os.path.join(out, "eval_images.bin"), ex)
    manifest["eval_labels_sha"] = write_bin(os.path.join(out, "eval_labels.bin"), ey)
    manifest["calib_images_sha"] = write_bin(
        os.path.join(out, "calib_images.bin"), tx[:ACTS_BATCH]
    )

    # ---- kernel smoke ----
    with open(os.path.join(out, "kernel_smoke.hlo.txt"), "w") as f:
        f.write(lower_kernel_smoke())

    # ---- models ----
    for model in models.MODEL_NAMES:
        weights_path = os.path.join(out, f"weights_{model}.bin")
        if args.reuse_weights and os.path.exists(weights_path):
            print(f"== {model}: reusing existing weights ==")
            params = load_params_from_blob(model, weights_path)
            ex_j, ey_j = jnp.asarray(ex), jnp.asarray(ey)
            eval_acc = models.accuracy(model, params, ex_j, ey_j)
            train_acc = eval_acc
        else:
            steps = args.steps or TRAIN_STEPS[model]
            print(f"== {model}: training {steps} steps ==")
            params, train_acc, eval_acc = train.train_model(model, steps=steps)

        # weights blob + layout
        spec = models.param_spec(model)
        chunks = []
        layout = []
        offset = 0
        for name, r, c, g in spec:
            w = np.asarray(params[name]["w"], dtype=np.float32)
            b = np.asarray(params[name]["b"], dtype=np.float32)
            assert w.shape == (r, c), f"{model}/{name}: {w.shape} != {(r, c)}"
            layout.append(
                {
                    "name": name,
                    "rows": r,
                    "cols": c,
                    "groups": g,
                    "w_offset": offset,
                    "b_offset": offset + r * c,
                }
            )
            offset += r * c + c
            chunks.append(w.reshape(-1))
            chunks.append(b)
        blob = np.concatenate(chunks)
        sha = write_bin(os.path.join(out, f"weights_{model}.bin"), blob)

        print(f"== {model}: lowering fwd/acts to HLO text ==")
        fwd_hlo = lower_fwd(model, FWD_BATCH)
        with open(os.path.join(out, f"model_{model}_fwd.hlo.txt"), "w") as f:
            f.write(fwd_hlo)
        acts_hlo, tap_order = lower_acts(model, ACTS_BATCH)
        with open(os.path.join(out, f"model_{model}_acts.hlo.txt"), "w") as f:
            f.write(acts_hlo)
        with open(os.path.join(out, f"graph_{model}.json"), "w") as f:
            json.dump(models.export_graph(model), f, indent=1)

        manifest["models"][model] = {
            "params": layout,
            "total_floats": int(offset),
            "weights_sha": sha,
            "dense_train_acc": float(train_acc),
            "dense_eval_acc": float(eval_acc),
            "taps": tap_order,
            "fwd_hlo": f"model_{model}_fwd.hlo.txt",
            "acts_hlo": f"model_{model}_acts.hlo.txt",
            "weights_bin": f"weights_{model}.bin",
            "graph_json": f"graph_{model}.json",
        }

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote artifacts to {out}")


if __name__ == "__main__":
    sys.exit(main())
