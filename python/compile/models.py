"""L2 JAX mini models (resnet_mini / vgg_mini / mobilenet_mini).

These must mirror `rust/src/workload/zoo/mini.rs` op-for-op: the rust
side prunes the exported weight matrices against the same graph, so op
names, parameter order and reshaped-matrix layout are a contract
(checked by integration_runtime.rs against the artifact manifest).

Parameter layout: every MVM op stores its weights as the *reshaped 2-D
matrix* the paper maps onto CIM arrays — rows = in_ch·kh·kw in
channel-major (c, kh, kw) order, cols = out_ch (depthwise: rows = kh·kw,
cols = channels, groups recorded in the manifest). Rust therefore
consumes the blobs directly as `WeightMatrix` without any re-indexing;
the forward pass reshapes to HWIO internally.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels.flexblock_matmul import flexblock_matmul

MODEL_NAMES = ("resnet_mini", "vgg_mini", "mobilenet_mini")
NUM_CLASSES = 10


# --------------------------------------------------------------------------
# parameter specs: (name, rows, cols, groups) in rust-zoo topological order
# --------------------------------------------------------------------------

def param_spec(model: str) -> list[tuple[str, int, int, int]]:
    if model == "resnet_mini":
        spec = [("stem", 3 * 9, 16, 1)]
        for blk, (ic, oc, stride) in {
            "layer1.0": (16, 16, 1),
            "layer1.1": (16, 16, 1),
            "layer2.0": (16, 32, 2),
            "layer2.1": (32, 32, 1),
        }.items():
            spec.append((f"{blk}.conv1", ic * 9, oc, 1))
            spec.append((f"{blk}.conv2", oc * 9, oc, 1))
            if ic != oc or stride != 1:
                spec.append((f"{blk}.down", ic * 1, oc, 1))
        spec.append(("fc", 32, NUM_CLASSES, 1))
        return spec
    if model == "vgg_mini":
        return [
            ("conv1_1", 3 * 9, 16, 1),
            ("conv1_2", 16 * 9, 16, 1),
            ("conv2_1", 16 * 9, 32, 1),
            ("conv2_2", 32 * 9, 32, 1),
            ("fc1", 512, 128, 1),
            ("fc2", 128, NUM_CLASSES, 1),
        ]
    if model == "mobilenet_mini":
        return [
            ("stem", 3 * 9, 16, 1),
            ("block1.expand", 16, 32, 1),
            ("block1.dw", 9, 32, 32),
            ("block1.project", 32, 16, 1),
            ("block2.expand", 16, 32, 1),
            ("block2.dw", 9, 32, 32),
            ("block2.project", 32, 32, 1),
            ("head", 32, 64, 1),
            ("classifier", 64, NUM_CLASSES, 1),
        ]
    raise ValueError(f"unknown model {model!r}")


def init_params(model: str, seed: int = 7) -> dict[str, dict[str, jnp.ndarray]]:
    """He-init parameters in the 2-D matrix layout."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, rows, cols, _groups in param_spec(model):
        std = float(np.sqrt(2.0 / rows))
        params[name] = {
            "w": jnp.asarray(rng.normal(0, std, size=(rows, cols)).astype(np.float32)),
            "b": jnp.zeros((cols,), jnp.float32),
        }
    return params


# --------------------------------------------------------------------------
# layers
# --------------------------------------------------------------------------

def _conv(p, x, in_ch: int, k: int, stride: int, pad: int, groups: int = 1):
    """NHWC conv from the 2-D weight layout."""
    w2d, b = p["w"], p["b"]
    out_ch = w2d.shape[1]
    if groups == 1:
        w = w2d.reshape(in_ch, k, k, out_ch).transpose(1, 2, 0, 3)  # HWIO
    else:
        # depthwise: (k*k, ch) -> (k, k, 1, ch)
        w = w2d.reshape(k, k, 1, out_ch)
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    return y + b


def _fc(p, x, use_pallas: bool):
    w, b = p["w"], p["b"]
    if use_pallas:
        ones = jnp.ones_like(w)
        y = flexblock_matmul(x, w, ones, interpret=True)
    else:
        y = x @ w
    return y + b


def _maxpool(x, k: int, s: int):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, s, s, 1), "VALID"
    )


# --------------------------------------------------------------------------
# forwards (tap = input activations of each MVM op, for input-sparsity
# profiling; taps are post-ReLU feature maps exactly as broadcast to rows)
# --------------------------------------------------------------------------

def forward(
    model: str,
    params,
    x: jnp.ndarray,
    use_pallas: bool = False,
    collect_taps: bool = False,
):
    taps: dict[str, jnp.ndarray] = {}

    def tap(name, t):
        if collect_taps:
            taps[name] = t

    if model == "resnet_mini":
        tap("stem", x)
        h = jax.nn.relu(_conv(params["stem"], x, 3, 3, 1, 1))
        for blk, (ic, oc, stride) in {
            "layer1.0": (16, 16, 1),
            "layer1.1": (16, 16, 1),
            "layer2.0": (16, 32, 2),
            "layer2.1": (32, 32, 1),
        }.items():
            tap(f"{blk}.conv1", h)
            c1 = jax.nn.relu(_conv(params[f"{blk}.conv1"], h, ic, 3, stride, 1))
            tap(f"{blk}.conv2", c1)
            c2 = _conv(params[f"{blk}.conv2"], c1, oc, 3, 1, 1)
            if ic != oc or stride != 1:
                tap(f"{blk}.down", h)
                short = _conv(params[f"{blk}.down"], h, ic, 1, stride, 0)
            else:
                short = h
            h = jax.nn.relu(c2 + short)
        g = jnp.mean(h, axis=(1, 2))
        tap("fc", g)
        logits = _fc(params["fc"], g, use_pallas)
    elif model == "vgg_mini":
        tap("conv1_1", x)
        h = jax.nn.relu(_conv(params["conv1_1"], x, 3, 3, 1, 1))
        tap("conv1_2", h)
        h = jax.nn.relu(_conv(params["conv1_2"], h, 16, 3, 1, 1))
        h = _maxpool(h, 2, 2)
        tap("conv2_1", h)
        h = jax.nn.relu(_conv(params["conv2_1"], h, 16, 3, 1, 1))
        tap("conv2_2", h)
        h = jax.nn.relu(_conv(params["conv2_2"], h, 32, 3, 1, 1))
        h = _maxpool(h, 2, 2)
        flat = h.reshape(h.shape[0], -1)
        tap("fc1", flat)
        h = jax.nn.relu(_fc(params["fc1"], flat, use_pallas))
        tap("fc2", h)
        logits = _fc(params["fc2"], h, use_pallas)
    elif model == "mobilenet_mini":
        tap("stem", x)
        h = jax.nn.relu(_conv(params["stem"], x, 3, 3, 1, 1))
        # block1 (residual)
        tap("block1.expand", h)
        e = jax.nn.relu(_conv(params["block1.expand"], h, 16, 1, 1, 0))
        tap("block1.dw", e)
        d = jax.nn.relu(_conv(params["block1.dw"], e, 32, 3, 1, 1, groups=32))
        tap("block1.project", d)
        p1 = _conv(params["block1.project"], d, 32, 1, 1, 0)
        h = p1 + h
        # block2 (stride 2, no residual)
        tap("block2.expand", h)
        e = jax.nn.relu(_conv(params["block2.expand"], h, 16, 1, 1, 0))
        tap("block2.dw", e)
        d = jax.nn.relu(_conv(params["block2.dw"], e, 32, 3, 2, 1, groups=32))
        tap("block2.project", d)
        h = _conv(params["block2.project"], d, 32, 1, 1, 0)
        tap("head", h)
        h = jax.nn.relu(_conv(params["head"], h, 32, 1, 1, 0))
        g = jnp.mean(h, axis=(1, 2))
        tap("classifier", g)
        logits = _fc(params["classifier"], g, use_pallas)
    else:
        raise ValueError(f"unknown model {model!r}")

    if collect_taps:
        return logits, taps
    return logits


def loss_fn(model: str, params, x, y):
    logits = forward(model, params, x, use_pallas=False)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(model: str, params, x, y, use_pallas: bool = False) -> float:
    logits = forward(model, params, x, use_pallas=use_pallas)
    return float(jnp.mean(jnp.argmax(logits, axis=1) == y))


# --------------------------------------------------------------------------
# graph export (the ONNX-substitute JSON interchange; DESIGN.md §3)
# --------------------------------------------------------------------------

def export_graph(model: str) -> dict:
    """Emit the workload-DAG JSON mirroring rust's zoo builders.

    Only used for cross-checking the import path; rust has native
    builders for these graphs.
    """
    ops: list[dict] = []

    def add(name, kind, inputs=None, **kw):
        o = {"name": name, "kind": kind}
        if inputs is not None:
            o["inputs"] = inputs
        o.update(kw)
        ops.append(o)
        return len(ops) - 1

    def conv(name, src, ic, oc, k, s, p, groups=1):
        return add(name, "conv2d", [src], in_ch=ic, out_ch=oc, kh=k, kw=k,
                   stride=s, pad=p, groups=groups)

    x = add("input", "input", shape=[3, 16, 16])
    if model == "resnet_mini":
        c0 = conv("stem", x, 3, 16, 3, 1, 1)
        h = add("stem_relu", "relu", [c0])
        for blk, (ic, oc, stride) in {
            "layer1.0": (16, 16, 1),
            "layer1.1": (16, 16, 1),
            "layer2.0": (16, 32, 2),
            "layer2.1": (32, 32, 1),
        }.items():
            c1 = conv(f"{blk}.conv1", h, ic, oc, 3, stride, 1)
            r1 = add(f"{blk}.relu1", "relu", [c1])
            c2 = conv(f"{blk}.conv2", r1, oc, oc, 3, 1, 1)
            short = h
            if ic != oc or stride != 1:
                short = conv(f"{blk}.down", h, ic, oc, 1, stride, 0)
            a = add(f"{blk}.add", "add", [c2, short])
            h = add(f"{blk}.relu2", "relu", [a])
        g = add("gap", "gap", [h])
        add("fc", "fc", [g], in_features=32, out_features=NUM_CLASSES)
    elif model == "vgg_mini":
        c = conv("conv1_1", x, 3, 16, 3, 1, 1)
        r = add("relu1_1", "relu", [c])
        c = conv("conv1_2", r, 16, 16, 3, 1, 1)
        r = add("relu1_2", "relu", [c])
        p = add("pool1", "pool", [r], pool="max", k=2, stride=2)
        c = conv("conv2_1", p, 16, 32, 3, 1, 1)
        r = add("relu2_1", "relu", [c])
        c = conv("conv2_2", r, 32, 32, 3, 1, 1)
        r = add("relu2_2", "relu", [c])
        p = add("pool2", "pool", [r], pool="max", k=2, stride=2)
        f = add("flatten", "flatten", [p])
        f1 = add("fc1", "fc", [f], in_features=512, out_features=128)
        rf = add("relu_fc1", "relu", [f1])
        add("fc2", "fc", [rf], in_features=128, out_features=NUM_CLASSES)
    elif model == "mobilenet_mini":
        c0 = conv("stem", x, 3, 16, 3, 1, 1)
        h = add("stem_relu", "relu", [c0])
        e = conv("block1.expand", h, 16, 32, 1, 1, 0)
        re = add("block1.expand_relu", "relu", [e])
        d = conv("block1.dw", re, 32, 32, 3, 1, 1, groups=32)
        rd = add("block1.dw_relu", "relu", [d])
        p1 = conv("block1.project", rd, 32, 16, 1, 1, 0)
        h = add("block1.add", "add", [p1, h])
        e = conv("block2.expand", h, 16, 32, 1, 1, 0)
        re = add("block2.expand_relu", "relu", [e])
        d = conv("block2.dw", re, 32, 32, 3, 2, 1, groups=32)
        rd = add("block2.dw_relu", "relu", [d])
        h = conv("block2.project", rd, 32, 32, 1, 1, 0)
        ch = conv("head", h, 32, 64, 1, 1, 0)
        rh = add("head_relu", "relu", [ch])
        g = add("gap", "gap", [rh])
        add("classifier", "fc", [g], in_features=64, out_features=NUM_CLASSES)
    else:
        raise ValueError(model)
    return {"name": model, "ops": ops}
