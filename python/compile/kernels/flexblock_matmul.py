"""L1 Pallas kernel: FlexBlock masked matmul — the CIM-array compute
hot-spot of a pruned layer.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the CIM sub-array
(32x32) becomes the BlockSpec tile resident in VMEM; the bit-serial
input broadcast becomes the K-loop; the adder-tree accumulation becomes
the MXU contraction. The mask rides along the weight tile so pruned
cells contribute exactly zero, mirroring weights that are simply absent
from the array.

Lowered with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is compiled to plain HLO for both pytest and
the rust runtime. Real-TPU tiling estimates live in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: sub-array-shaped. K tile chosen so one (BV, BK) x (BK, BN)
# step's operands fit comfortably in VMEM (see DESIGN.md §Perf).
BV = 32  # vectors per tile (output rows)
BK = 32  # contraction tile (array rows)
BN = 32  # output channels per tile (array cols)


def _kernel(x_ref, w_ref, m_ref, o_ref, *, n_k: int):
    """One (v, n) output tile; iterates the K grid axis accumulating into
    o_ref (revisiting grid semantics: K is the innermost grid axis)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = w_ref[...] * m_ref[...]
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def _pad_to(a: jnp.ndarray, r: int, c: int) -> jnp.ndarray:
    return jnp.pad(a, ((0, r - a.shape[0]), (0, c - a.shape[1])))


@functools.partial(jax.jit, static_argnames=("interpret",))
def flexblock_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    mask: jnp.ndarray,
    interpret: bool = True,
) -> jnp.ndarray:
    """x[V,K] @ (w*mask)[K,N] -> [V,N] via the Pallas tile kernel.

    Shapes need not be tile-multiples; inputs are zero-padded to the
    grid and the result is sliced back.
    """
    v, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert mask.shape == w.shape, "mask must match weights"
    vp = -(-v // BV) * BV
    kp = -(-k // BK) * BK
    np_ = -(-n // BN) * BN
    xp = _pad_to(x.astype(jnp.float32), vp, kp)
    wp = _pad_to(w.astype(jnp.float32), kp, np_)
    mp = _pad_to(mask.astype(jnp.float32), kp, np_)
    n_k = kp // BK
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(vp // BV, np_ // BN, n_k),
        in_specs=[
            pl.BlockSpec((BV, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((BV, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((vp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, mp)
    return out[:v, :n]
