"""L1 Pallas kernel: activation bit-plane OR profiling.

Computes, for each broadcast group of quantized activations, which bit
planes contain any set bit — exactly the OR-gate zero-detection network
the pre-processing units implement (Sec. III-B). The rust simulator's
input-sparsity model consumes the resulting per-plane activity rates.

Groups map to BlockSpec rows: one grid step loads a (BG, L) tile of
groups into VMEM and reduces each group's bit planes. interpret=True for
CPU-PJRT execution (see flexblock_matmul.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BG = 8  # groups per tile


def _kernel(q_ref, o_ref, *, bits: int):
    q = q_ref[...]  # [BG, L] uint32
    planes = []
    for b in range(bits):
        plane = ((q >> jnp.uint32(b)) & jnp.uint32(1)).astype(jnp.float32)
        planes.append(jnp.max(plane, axis=1))
    o_ref[...] = jnp.stack(planes, axis=1)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def bitplane_or(q: jnp.ndarray, bits: int = 8, interpret: bool = True) -> jnp.ndarray:
    """q: uint32 [G, L] -> float32 [G, bits] OR-activity per bit plane."""
    g, l = q.shape
    gp = -(-g // BG) * BG
    qp = jnp.pad(q, ((0, gp - g), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=(gp // BG,),
        in_specs=[pl.BlockSpec((BG, l), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BG, bits), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, bits), jnp.float32),
        interpret=interpret,
    )(qp)
    return out[:g]
