"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth
checked by pytest before anything is lowered)."""

from __future__ import annotations

import jax.numpy as jnp


def flexblock_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Masked (FlexBlock-pruned) matmul: x[V,K] @ (w*mask)[K,N] -> [V,N].

    The mask is the FlexBlock sparsity mask over the reshaped weight
    matrix; in the CIM array the pruned weights simply are not stored, so
    the arithmetic reference is elementwise masking.
    """
    return x @ (w * mask)


def bitplane_or_ref(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-group OR of each bit plane.

    q: uint32 [G, L] -- quantized activations, G broadcast groups of L
    values (the inputs sharing one sub-array's rows).
    Returns float32 [G, bits]: 1.0 where any value in the group has that
    bit set (the bit-serial cycle must execute), else 0.0.
    """
    planes = []
    for b in range(bits):
        plane = (q >> b) & 1  # [G, L]
        planes.append(jnp.max(plane, axis=1))
    return jnp.stack(planes, axis=1).astype(jnp.float32)


def quantize_ref(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Max-abs ReLU quantization to `bits` (matches the rust
    ActivationProfile::from_values convention)."""
    maxv = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = (2**bits - 1) / maxv
    return jnp.round(jnp.maximum(x, 0.0) * scale).astype(jnp.uint32)
