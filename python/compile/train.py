"""Build-time training for the mini models on SynthCIFAR.

Hand-rolled Adam (no optax offline). Training runs through the pure-jnp
path (the Pallas kernel has no VJP registered); the pallas path is used
for the exported inference graphs and is asserted numerically equal by
pytest.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, models


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train_model(
    model: str,
    steps: int = 400,
    batch: int = 256,
    lr: float = 2e-3,
    seed: int = 7,
    verbose: bool = True,
):
    """Train one mini model; returns (params, train_acc, eval_acc)."""
    xs, ys = data.train_split()
    params = models.init_params(model, seed=seed)
    state = adam_init(params)

    @jax.jit
    def step(params, state, bx, by):
        loss, grads = jax.value_and_grad(
            lambda p: models.loss_fn(model, p, bx, by)
        )(params)
        params, state = adam_update(params, grads, state, lr=lr)
        return params, state, loss

    rng = np.random.default_rng(seed)
    t0 = time.time()
    loss = jnp.inf
    for i in range(steps):
        idx = rng.integers(0, xs.shape[0], size=batch)
        params, state, loss = step(params, state, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
        if verbose and (i + 1) % 100 == 0:
            print(f"  [{model}] step {i + 1}/{steps} loss={float(loss):.4f}")
    ex, ey = data.eval_split()
    train_acc = models.accuracy(model, params, jnp.asarray(xs[:1024]), jnp.asarray(ys[:1024]))
    eval_acc = models.accuracy(model, params, jnp.asarray(ex), jnp.asarray(ey))
    if verbose:
        print(
            f"  [{model}] trained {steps} steps in {time.time() - t0:.1f}s: "
            f"train_acc={train_acc:.3f} eval_acc={eval_acc:.3f}"
        )
    return params, train_acc, eval_acc
