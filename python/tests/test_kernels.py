"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles, with
hypothesis sweeping shapes and sparsity configurations."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bitplane import bitplane_or
from compile.kernels.flexblock_matmul import flexblock_matmul
from compile.kernels.ref import bitplane_or_ref, flexblock_matmul_ref, quantize_ref


def rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


class TestFlexblockMatmul:
    def test_exact_small(self):
        x = jnp.array([[1.0, 2.0], [3.0, 4.0]])
        w = jnp.array([[1.0, 0.0], [0.0, 1.0]])
        m = jnp.ones((2, 2))
        out = flexblock_matmul(x, w, m)
        np.testing.assert_allclose(out, x, rtol=1e-6)

    def test_mask_zeroes_contributions(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rand(rng, 16, 32))
        w = jnp.asarray(rand(rng, 32, 8))
        m = jnp.zeros((32, 8))
        out = flexblock_matmul(x, w, m)
        np.testing.assert_allclose(out, np.zeros((16, 8)), atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        v=st.integers(1, 70),
        k=st.integers(1, 90),
        n=st.integers(1, 70),
        density=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, v, k, n, density, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rand(rng, v, k))
        w = jnp.asarray(rand(rng, k, n))
        m = jnp.asarray((rng.random((k, n)) < density).astype(np.float32))
        got = flexblock_matmul(x, w, m)
        want = flexblock_matmul_ref(x, w, m)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_tile_multiples_fast_path(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rand(rng, 64, 96))
        w = jnp.asarray(rand(rng, 96, 64))
        m = jnp.ones((96, 64))
        got = flexblock_matmul(x, w, m)
        np.testing.assert_allclose(got, x @ w, rtol=2e-4, atol=2e-4)

    def test_shape_mismatch_raises(self):
        x = jnp.zeros((4, 8))
        w = jnp.zeros((9, 4))
        with pytest.raises(AssertionError):
            flexblock_matmul(x, w, jnp.ones_like(w))


class TestBitplane:
    def test_all_zero_input(self):
        q = jnp.zeros((4, 16), jnp.uint32)
        out = bitplane_or(q, 8)
        np.testing.assert_allclose(out, np.zeros((4, 8)))

    def test_single_bits(self):
        # one value per group with a single bit set
        q = jnp.asarray(np.diag([1, 2, 4, 8]).astype(np.uint32))
        out = np.asarray(bitplane_or(q, 4))
        np.testing.assert_allclose(out, np.eye(4))

    @settings(max_examples=25, deadline=None)
    @given(
        g=st.integers(1, 40),
        l=st.integers(1, 64),
        bits=st.sampled_from([4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, g, l, bits, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.integers(0, 2**bits, size=(g, l)).astype(np.uint32))
        got = bitplane_or(q, bits)
        want = bitplane_or_ref(q, bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_quantize_roundtrip_properties(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rand(rng, 1000))
        q = np.asarray(quantize_ref(x, 8))
        assert q.max() <= 255
        # negatives are ReLU'd to zero
        assert (q[np.asarray(x) < 0] == 0).all()
