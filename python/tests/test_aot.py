"""AOT path: HLO-text lowering round-trips through the XLA client and
the artifact layout contract holds."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, models


class TestLowering:
    def test_kernel_smoke_hlo_parses(self):
        text = aot.lower_kernel_smoke()
        assert "ENTRY" in text
        # pallas interpret mode must lower to plain HLO (no mosaic custom-calls)
        assert "custom_call_target=\"Mosaic\"" not in text

    def test_fwd_hlo_small_batch(self):
        # tiny batch keeps this test fast; full batch exercised by `make artifacts`
        text = aot.lower_fwd("vgg_mini", batch=4)
        assert "ENTRY" in text
        n_params = 2 * len(models.param_spec("vgg_mini"))
        # entry parameter count: weights + biases + input (fusion bodies
        # also contain parameter() lines, so count the entry block only)
        entry = text[text.index("ENTRY"):]
        entry_block = entry[: entry.index("\n}")]
        assert entry_block.count(" parameter(") == n_params + 1

    def test_acts_hlo_returns_all_taps(self):
        text, taps = aot.lower_acts("mobilenet_mini", batch=2)
        assert "ENTRY" in text
        assert taps == [s[0] for s in models.param_spec("mobilenet_mini")]
        # every parameter stays live (logits are returned alongside taps)
        entry = text[text.index("ENTRY"):]
        entry_block = entry[: entry.index("\n}")]
        n_params = 2 * len(models.param_spec("mobilenet_mini"))
        assert entry_block.count(" parameter(") == n_params + 1

    def test_hlo_text_format(self):
        """The interchange format the rust runtime consumes: HLO text
        starting with HloModule (real PJRT execution is covered by
        rust/tests/integration_runtime.rs)."""

        def fn(a, b):
            return (a @ b + 1.0,)

        s = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        lowered = jax.jit(fn).lower(s, s)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text


class TestArtifacts:
    """Checks against built artifacts; skipped until `make artifacts`."""

    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f), os.path.dirname(path)

    def test_manifest_covers_all_models(self, manifest):
        m, _d = manifest
        assert set(m["models"].keys()) == set(models.MODEL_NAMES)

    def test_weight_blob_sizes(self, manifest):
        m, d = manifest
        for name, info in m["models"].items():
            blob = np.fromfile(os.path.join(d, info["weights_bin"]), dtype=np.float32)
            assert blob.size == info["total_floats"], name
            # layout offsets are monotone and in-bounds
            for p in info["params"]:
                assert p["w_offset"] + p["rows"] * p["cols"] <= blob.size
                assert p["b_offset"] + p["cols"] <= blob.size

    def test_param_layout_matches_spec(self, manifest):
        m, _d = manifest
        for name, info in m["models"].items():
            spec = models.param_spec(name)
            assert [p["name"] for p in info["params"]] == [s[0] for s in spec]
            for p, (_n, r, c, g) in zip(info["params"], spec):
                assert (p["rows"], p["cols"], p["groups"]) == (r, c, g)

    def test_trained_accuracy_beats_chance(self, manifest):
        m, _d = manifest
        for name, info in m["models"].items():
            assert info["dense_eval_acc"] > 0.5, f"{name}: {info['dense_eval_acc']}"

    def test_hlo_files_exist(self, manifest):
        m, d = manifest
        for info in m["models"].values():
            for key in ("fwd_hlo", "acts_hlo", "graph_json"):
                assert os.path.exists(os.path.join(d, info[key]))
        assert os.path.exists(os.path.join(d, "kernel_smoke.hlo.txt"))
