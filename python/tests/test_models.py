"""L2 model correctness: shapes, pallas/jnp path equality, graph export
consistency, trainability."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, models, train


@pytest.fixture(scope="module")
def batch():
    xs, ys = data.make_split(16, 123)
    return jnp.asarray(xs), jnp.asarray(ys)


@pytest.mark.parametrize("model", models.MODEL_NAMES)
class TestForward:
    def test_output_shape(self, model, batch):
        x, _ = batch
        p = models.init_params(model)
        logits = models.forward(model, p, x)
        assert logits.shape == (16, models.NUM_CLASSES)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_pallas_path_matches_jnp(self, model, batch):
        x, _ = batch
        p = models.init_params(model)
        a = models.forward(model, p, x, use_pallas=False)
        b = models.forward(model, p, x, use_pallas=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    def test_taps_cover_every_mvm_op(self, model, batch):
        x, _ = batch
        p = models.init_params(model)
        _logits, taps = models.forward(model, p, x, collect_taps=True)
        spec_names = {s[0] for s in models.param_spec(model)}
        assert set(taps.keys()) == spec_names

    def test_param_spec_matches_params(self, model, batch):
        p = models.init_params(model)
        for name, r, c, _g in models.param_spec(model):
            assert p[name]["w"].shape == (r, c)
            assert p[name]["b"].shape == (c,)

    def test_graph_export_schema(self, model, batch):
        g = models.export_graph(model)
        assert g["name"] == model
        kinds = [o["kind"] for o in g["ops"]]
        assert kinds[0] == "input"
        # every MVM param has a graph node of matching name
        names = {o["name"] for o in g["ops"]}
        for s in models.param_spec(model):
            assert s[0] in names, f"{s[0]} missing from exported graph"


class TestTraining:
    def test_short_training_reduces_loss(self):
        xs, ys = data.train_split()
        x, y = jnp.asarray(xs[:256]), jnp.asarray(ys[:256])
        p0 = models.init_params("vgg_mini")
        loss0 = float(models.loss_fn("vgg_mini", p0, x, y))
        p, _ta, _ea = train.train_model("vgg_mini", steps=30, verbose=False)
        loss1 = float(models.loss_fn("vgg_mini", p, x, y))
        assert loss1 < loss0, f"{loss1} !< {loss0}"

    def test_dataset_determinism(self):
        a_x, a_y = data.make_split(32, 99)
        b_x, b_y = data.make_split(32, 99)
        np.testing.assert_array_equal(a_x, b_x)
        np.testing.assert_array_equal(a_y, b_y)
        c_x, _c_y = data.make_split(32, 100)
        assert not np.array_equal(a_x, c_x)

    def test_dataset_class_balance(self):
        _x, y = data.make_split(1000, 5)
        counts = np.bincount(y, minlength=10)
        assert (counts > 50).all(), counts
